"""Preemption tolerance: windowed checkpoint/resume, elastic reshard, faults.

The contract under test: a run killed at any window boundary and resumed from
its checkpoint -- on the same mesh or an elastically resharded one -- is
bitwise identical to the uninterrupted run, and the fault harness's injected
conditions (compute jitter, transient checkpoint-write failures, simulated
preemption) are deterministic and survivable. Distributed legs run in
subprocesses with forced host device counts, per the launch contract.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import faults as faults_lib
from repro.core import schedule as schedule_lib
from repro.core.areas import mam_benchmark_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _quick_engine(**cfg_kw):
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    cfg = EngineConfig(neuron_model="lif", delivery_backend="event",
                       s_max_floor=4, **cfg_kw)
    return make_simulation(spec, cfg, net=net), net


# ---------------------------------------------------------------------------
# windowed checkpoint / resume (single device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("superstep", [True, False],
                         ids=["superstep", "legacy"])
@pytest.mark.parametrize("adaptive", [False, True],
                         ids=["static", "adaptive"])
def test_checkpoint_roundtrip_counters_and_ring_phase(
        tmp_path, superstep, adaptive):
    """SimState round-trips whole: neuron state, phase-aligned rings and the
    counters (t, spike_count, overflow, shipped_bytes) all survive restore,
    across {superstep, legacy} x {static, adaptive} windows."""
    eng, net = _quick_engine(superstep=superstep, adaptive_exchange=adaptive)
    st = eng.init()
    for _ in range(4):
        st, _ = eng.window(st)

    ckpt = schedule_lib.SimCheckpointer(str(tmp_path), eng, net, every=0)
    ckpt.save(st)
    ckpt.close()

    restored, info = schedule_lib.restore_sim(str(tmp_path), eng, net)
    assert info["step"] == 4
    assert info["reshard"] is None
    assert int(restored.t) == int(st.t)
    assert int(restored.overflow) == int(st.overflow)
    assert float(np.asarray(restored.shipped_bytes)) == float(
        np.asarray(st.shipped_bytes))
    assert np.array_equal(np.asarray(restored.ring), np.asarray(st.ring))
    assert np.array_equal(np.asarray(restored.spike_count),
                          np.asarray(st.spike_count))
    extra = info["manifest"]["extra"]
    assert extra["ring_phase"] == int(st.t) % net.ring_len
    assert extra["window_phase"] == 0
    assert extra["seed"] == eng.config.seed

    # ... and the resumed trajectory continues bitwise-identically.
    ref, resumed = st, restored
    for _ in range(3):
        ref, blk_ref = eng.window(ref)
        resumed, blk_res = eng.window(resumed)
    assert np.array_equal(np.asarray(blk_ref), np.asarray(blk_res))
    assert np.array_equal(np.asarray(ref.ring), np.asarray(resumed.ring))


def test_kill_at_window_k_resume_equals_uninterrupted(tmp_path):
    """Preempt at window 5 of 9 through the resilient loop, resume from the
    SIGTERM-grace checkpoint: spikes and final state match the uninterrupted
    reference exactly."""
    eng, net = _quick_engine()
    ref = schedule_lib.run_windows(eng, eng.init(), 9)

    inj = faults_lib.FaultInjector(
        faults_lib.FaultConfig(preempt_after_window=5),
        n_devices=1, delay_ratio=eng.delay_ratio)
    ckpt = schedule_lib.SimCheckpointer(str(tmp_path), eng, net, every=2,
                                        injector=inj)
    with pytest.raises(faults_lib.Preempted) as exc_info:
        schedule_lib.run_windows(eng, eng.init(), 9,
                                 checkpointer=ckpt, faults=inj)
    exc = exc_info.value
    assert exc.window == 5
    assert exc.checkpoint_path == str(tmp_path)
    assert exc.result.windows_done == 5

    st, info = schedule_lib.restore_sim(str(tmp_path), eng, net)
    assert info["step"] == 5
    res = schedule_lib.run_windows(eng, st, 9 - info["step"])
    assert np.array_equal(res.spikes_per_window, ref.spikes_per_window[5:])
    assert int(res.state.t) == int(ref.state.t)
    assert np.array_equal(np.asarray(res.state.ring),
                          np.asarray(ref.state.ring))
    assert np.array_equal(np.asarray(res.state.spike_count),
                          np.asarray(ref.state.spike_count))


def test_resume_config_hash_mismatch_fails_fast(tmp_path):
    """A checkpoint from a different config (here: seed) must refuse to
    resume with a field-by-field error, before any array is loaded."""
    eng, net = _quick_engine()
    st = eng.init()
    for _ in range(2):
        st, _ = eng.window(st)
    ckpt = schedule_lib.SimCheckpointer(str(tmp_path), eng, net, every=0)
    ckpt.save(st)
    ckpt.close()

    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    other = make_simulation(spec, EngineConfig(
        neuron_model="lif", delivery_backend="event", s_max_floor=4, seed=7), net=net)
    with pytest.raises(ValueError, match=r"seed: checkpoint=42 != run=7"):
        schedule_lib.restore_sim(str(tmp_path), other, net)


def test_checkpoint_rejects_mid_window_state():
    import dataclasses

    eng, net = _quick_engine()
    ckpt = schedule_lib.SimCheckpointer("/nonexistent-never-written", eng,
                                        net, every=0)
    st = eng.init()
    bad = dataclasses.replace(st, t=st.t + 3)  # not a multiple of D
    with pytest.raises(ValueError, match="mid-window"):
        ckpt.save(bad)
    ckpt.close()


# ---------------------------------------------------------------------------
# fault harness
# ---------------------------------------------------------------------------


def test_jitter_is_deterministic_and_matches_sync_model():
    """Injected per-window straggler times are a pure function of
    (seed, window) -- resume legs replay them -- and their mean matches the
    order-statistics prediction (Blom) within 10%."""
    cfg = faults_lib.FaultConfig(jitter_mu_ms=1.0, jitter_sigma_ms=0.2,
                                 jitter_devices=8, seed=3)
    inj = faults_lib.FaultInjector(cfg, n_devices=1, delay_ratio=10)
    twin = faults_lib.FaultInjector(cfg, n_devices=1, delay_ratio=10)
    draws = [inj.window_jitter_s(w) for w in range(300)]
    assert draws[7] == twin.window_jitter_s(7)
    predicted = inj.predicted_jitter_s()
    assert abs(np.mean(draws) / predicted - 1) < 0.10
    # the straggler premium over the jitter-free D*mu floor is positive
    assert predicted > 10 * cfg.jitter_mu_ms * 1e-3


def test_jitter_inflates_measured_window_times():
    import jax

    eng, _ = _quick_engine()
    jax.block_until_ready(eng.window(eng.init())[0].ring)  # compile
    base = schedule_lib.run_windows(eng, eng.init(), 4)
    inj = faults_lib.FaultInjector(
        faults_lib.FaultConfig(jitter_mu_ms=5.0, jitter_devices=4, seed=1),
        n_devices=1, delay_ratio=eng.delay_ratio)
    jit = schedule_lib.run_windows(eng, eng.init(), 4, faults=inj)
    assert jit.injected_sleep_s > 0.15  # 4 windows x D=10 x 5 ms
    assert jit.window_times_s.sum() >= (base.window_times_s.sum()
                                        + 0.8 * jit.injected_sleep_s)
    # the fault plan rides on EngineConfig too (run_windows default)
    eng2, _ = _quick_engine(faults=faults_lib.FaultConfig(
        jitter_mu_ms=5.0, jitter_devices=4, seed=1))
    jit2 = schedule_lib.run_windows(eng2, eng2.init(), 4)
    assert jit2.injected_sleep_s == pytest.approx(jit.injected_sleep_s)


def test_transient_ckpt_failures_are_survived(tmp_path):
    """ckpt-io faults: first 2 writes fail; the run completes, the writer
    retries exactly twice, and a readable checkpoint lands."""
    from repro.checkpoint import manager as ckpt_manager

    eng, net = _quick_engine()
    inj = faults_lib.FaultInjector(
        faults_lib.FaultConfig(ckpt_write_failures=2),
        n_devices=1, delay_ratio=eng.delay_ratio)
    ckpt = schedule_lib.SimCheckpointer(str(tmp_path), eng, net, every=2,
                                        injector=inj, backoff_s=0.01)
    schedule_lib.run_windows(eng, eng.init(), 4, checkpointer=ckpt,
                             faults=inj)
    ckpt.close()
    assert ckpt.retry_count == 2
    assert inj.ckpt_failures_injected == 2
    assert ckpt_manager.latest_step(str(tmp_path)) == 4


def test_fault_spec_grammar_round_trip():
    """format_fault_specs is the exact inverse of parse_fault_specs (modulo
    seed, a CLI flag): parse(format(cfg)) == cfg, defaults emit nothing, and
    the comm-jitter options survive the trip."""
    import dataclasses

    cfg = faults_lib.parse_fault_specs(
        ["jitter:mu_ms=1.6,sigma_ms=0.3,comm_mu_ms=12.5,comm_sigma_ms=2.0,"
         "rho=0.5,devices=16",
         "ckpt-io:fails=2", "preempt:window=12"], seed=9)
    specs = faults_lib.format_fault_specs(cfg)
    assert specs == [
        "jitter:mu_ms=1.6,sigma_ms=0.3,comm_mu_ms=12.5,comm_sigma_ms=2.0,"
        "rho=0.5,devices=16",
        "ckpt-io:fails=2", "preempt:window=12"]
    assert faults_lib.parse_fault_specs(specs, seed=9) == cfg

    assert faults_lib.format_fault_specs(faults_lib.FaultConfig()) == []
    partial = faults_lib.FaultConfig(comm_mu_ms=3.0, preempt_after_window=4)
    assert faults_lib.format_fault_specs(partial) == [
        "jitter:comm_mu_ms=3.0", "preempt:window=4"]
    assert faults_lib.parse_fault_specs(
        faults_lib.format_fault_specs(partial)) == partial
    # later specs merge over earlier ones
    merged = faults_lib.parse_fault_specs(
        ["jitter:mu_ms=1.0", "jitter:sigma_ms=0.5"])
    assert merged.jitter_mu_ms == 1.0 and merged.jitter_sigma_ms == 0.5
    assert dataclasses.replace(merged, jitter_mu_ms=0, jitter_sigma_ms=0) \
        == faults_lib.FaultConfig()


def test_fault_spec_grammar_rejects_malformed():
    """Every malformed --inject-fault spec raises a ValueError that names
    the offending spec/option -- no silent misconfiguration."""
    cases = [
        (["meteor:size=large"], "unknown fault kind"),
        (["jitter:"], "sets no options"),
        (["jitter"], "sets no options"),
        (["jitter:mu_ms=1.6,turbo"], "bad fault option 'turbo'"),
        (["jitter:mu_ms=fast"], "bad value 'fast' for option 'mu_ms'"),
        (["jitter:mu=1.6"], r"unknown option\(s\) \['mu'\]"),
        (["ckpt-io:"], "missing option 'fails'"),
        (["ckpt-io:fails=two"], "bad value 'two' for option 'fails'"),
        (["preempt:"], "missing option 'window'"),
        (["preempt:window=1,when=now"], "unknown option"),
    ]
    for specs, pattern in cases:
        with pytest.raises(ValueError, match=pattern):
            faults_lib.parse_fault_specs(specs)


def test_parse_fault_specs():
    cfg = faults_lib.parse_fault_specs(
        ["jitter:mu_ms=1.6,sigma_ms=0.3,rho=0.5,devices=16",
         "ckpt-io:fails=2", "preempt:window=12"], seed=9)
    assert cfg.jitter_mu_ms == 1.6 and cfg.jitter_sigma_ms == 0.3
    assert cfg.jitter_rho == 0.5 and cfg.jitter_devices == 16
    assert cfg.ckpt_write_failures == 2 and cfg.preempt_after_window == 12
    assert cfg.seed == 9 and cfg.any_enabled
    assert not faults_lib.parse_fault_specs([]).any_enabled
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults_lib.parse_fault_specs(["meteor:size=large"])
    with pytest.raises(ValueError, match="unknown option"):
        faults_lib.parse_fault_specs(["preempt:window=1,when=now"])
    with pytest.raises(ValueError, match="missing option"):
        faults_lib.parse_fault_specs(["ckpt-io:"])


# ---------------------------------------------------------------------------
# distributed: checkpoint round-trips and elastic reshard-restart
# ---------------------------------------------------------------------------


def test_dist_checkpoint_resume_matrix(tmp_path):
    """{dense, routed} x {static, adaptive} x {superstep, legacy} on a 4x2
    mesh: preempt at window 3 of 6, resume from the grace checkpoint, and
    match the uninterrupted reference bitwise."""
    print(_run(f"""
        import numpy as np, jax
        from repro.core import faults as faults_lib
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for exchange in ("dense", "routed"):
            for adaptive in (False, True):
                for superstep in (True, False):
                    tag = f"{{exchange}}-{{adaptive}}-{{superstep}}"
                    d = r"{tmp_path}/" + tag
                    cfg = EngineConfig(
                        neuron_model="ignore_and_fire",
                        delivery_backend="event", exchange=exchange,
                        adaptive_exchange=adaptive, superstep=superstep,
                        s_max_floor=4)
                    eng = make_simulation(spec, cfg, net=net, mesh=mesh)
                    ref = schedule_lib.run_windows(eng, eng.init(), 6)
                    inj = faults_lib.FaultInjector(
                        faults_lib.FaultConfig(preempt_after_window=3),
                        n_devices=8, delay_ratio=eng.delay_ratio)
                    ck = schedule_lib.SimCheckpointer(
                        d, eng, net, every=0, n_groups=4, injector=inj)
                    try:
                        schedule_lib.run_windows(
                            eng, eng.init(), 6, checkpointer=ck, faults=inj)
                        raise AssertionError("preemption did not fire: " + tag)
                    except faults_lib.Preempted:
                        pass
                    st, info = schedule_lib.restore_sim(
                        d, eng, net, n_groups=4)
                    assert info["step"] == 3, tag
                    res = schedule_lib.run_windows(eng, st, 3)
                    assert np.array_equal(res.spikes_per_window,
                                          ref.spikes_per_window[3:]), tag
                    assert np.array_equal(
                        np.asarray(res.state.ring),
                        np.asarray(ref.state.ring)), tag
                    assert int(res.state.t) == int(ref.state.t), tag
                    assert int(res.state.overflow) == 0, tag
                    print("OK", tag)
        print("MATRIX DONE")
    """))


@pytest.mark.parametrize("new_devices,new_groups", [(2, 2), (8, 8)])
def test_elastic_reshard_restart(tmp_path, new_devices, new_groups):
    """Checkpoint on 4 groups, kill, resume on a different group count:
    the spike train must equal the unkilled reference exactly."""
    common = """
        import numpy as np, jax
        from repro.core import faults as faults_lib
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        cfg = EngineConfig(neuron_model="ignore_and_fire",
                           delivery_backend="event", exchange="routed",
                           s_max_floor=4)
        n_groups = jax.device_count()
        mesh = jax.make_mesh((n_groups, 1), ("data", "model"))
        eng = make_simulation(spec, cfg, net=net, mesh=mesh)
    """
    # Leg 1 (4 groups): reference trajectory + preempted checkpoint.
    _run(common + f"""
        ref = schedule_lib.run_windows(eng, eng.init(), 8)
        np.savez(r"{tmp_path}/ref.npz",
                 spikes=np.asarray(ref.state.spike_count),
                 per_win=ref.spikes_per_window)
        inj = faults_lib.FaultInjector(
            faults_lib.FaultConfig(preempt_after_window=4),
            n_devices=4, delay_ratio=eng.delay_ratio)
        ck = schedule_lib.SimCheckpointer(
            r"{tmp_path}/ckpt", eng, net, every=0, n_groups=n_groups,
            injector=inj)
        try:
            schedule_lib.run_windows(eng, eng.init(), 8,
                                     checkpointer=ck, faults=inj)
            raise AssertionError("preemption did not fire")
        except faults_lib.Preempted as e:
            assert e.window == 4
        print("LEG1 OK")
    """, n_devices=4)
    # Leg 2 (different group count): elastic resume to completion.
    _run(common + f"""
        st, info = schedule_lib.restore_sim(
            r"{tmp_path}/ckpt", eng, net, n_groups=n_groups)
        assert info["step"] == 4
        resh = info["reshard"]
        assert resh is not None and resh["old_n_groups"] == 4
        assert resh["new_n_groups"] == {new_groups}
        res = schedule_lib.run_windows(eng, st, 8 - info["step"])
        ref = np.load(r"{tmp_path}/ref.npz")
        assert np.array_equal(np.asarray(res.state.spike_count),
                              ref["spikes"])
        assert np.array_equal(res.spikes_per_window, ref["per_win"][4:])
        print("LEG2 OK", resh)
    """, n_devices=new_devices)


def test_resume_across_table_layout_change(tmp_path):
    """The replicated <-> sharded inter-table layouts (and the overlapped
    flag) are execution details, not trajectory: the config-hash preflight
    treats them as compatible, and a checkpoint taken under one layout
    resumes under the other with bitwise-identical spikes, both directions,
    on a distributed event/routed engine."""
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    cfg_a = EngineConfig(neuron_model="lif", delivery_backend="event",
                         s_max_floor=4, shard_inter_tables=True)
    cfg_b = EngineConfig(neuron_model="lif", delivery_backend="event",
                         s_max_floor=4, shard_inter_tables=False,
                         overlap_exchange=True)
    h_a, pay_a = schedule_lib.resume_config_hash(cfg_a, net)
    h_b, pay_b = schedule_lib.resume_config_hash(cfg_b, net)
    assert h_a == h_b  # layout keys never enter the hash ...
    assert pay_a["shard_inter_tables"] != pay_b["shard_inter_tables"]
    assert pay_a["overlap_exchange"] != pay_b["overlap_exchange"]

    print(_run(f"""
        import numpy as np, jax
        from repro.core import faults as faults_lib
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def engine(sharded):
            return make_simulation(spec, EngineConfig(
                neuron_model="ignore_and_fire", delivery_backend="event",
                exchange="routed", s_max_floor=4,
                shard_inter_tables=sharded), net=net, mesh=mesh)

        for save_sharded in (True, False):
            tag = f"sharded={{save_sharded}}->{{not save_sharded}}"
            d = r"{tmp_path}/" + tag
            saver = engine(save_sharded)
            ref = schedule_lib.run_windows(saver, saver.init(), 6)
            inj = faults_lib.FaultInjector(
                faults_lib.FaultConfig(preempt_after_window=3),
                n_devices=8, delay_ratio=saver.delay_ratio)
            ck = schedule_lib.SimCheckpointer(
                d, saver, net, every=0, n_groups=4, injector=inj)
            try:
                schedule_lib.run_windows(saver, saver.init(), 6,
                                         checkpointer=ck, faults=inj)
                raise AssertionError("preemption did not fire: " + tag)
            except faults_lib.Preempted:
                pass
            resumer = engine(not save_sharded)   # the OTHER table layout
            st, info = schedule_lib.restore_sim(d, resumer, net, n_groups=4)
            assert info["step"] == 3, tag
            res = schedule_lib.run_windows(resumer, st, 3)
            assert np.array_equal(res.spikes_per_window,
                                  ref.spikes_per_window[3:]), tag
            assert np.array_equal(np.asarray(res.state.ring),
                                  np.asarray(ref.state.ring)), tag
            assert np.array_equal(np.asarray(res.state.spike_count),
                                  np.asarray(ref.state.spike_count)), tag
            print("OK", tag)
        print("LAYOUT RESUME DONE")
    """))


def test_resume_across_sharded_build_change(tmp_path):
    """sharded_build is a pure-layout key: it regenerates the exact same
    tables from the counter-based rules a host build draws, so it never
    enters the resume-config hash, and a mid-run checkpoint taken under a
    host-built engine resumes bitwise under a sharded-built one (and the
    reverse) on a distributed event/routed engine."""
    cfg_a = EngineConfig(neuron_model="lif", delivery_backend="event",
                         s_max_floor=4)
    cfg_b = EngineConfig(neuron_model="lif", delivery_backend="event",
                         s_max_floor=4, sharded_build=True)
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    h_a, pay_a = schedule_lib.resume_config_hash(cfg_a, net)
    h_b, pay_b = schedule_lib.resume_config_hash(cfg_b, net)
    assert h_a == h_b  # layout key, never hashed ...
    assert pay_a["sharded_build"] != pay_b["sharded_build"]  # ... but logged

    print(_run(f"""
        import numpy as np, jax
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def engine(sharded_build):
            cfg = EngineConfig(
                neuron_model="ignore_and_fire", delivery_backend="event",
                exchange="routed", s_max_floor=4,
                sharded_build=sharded_build)
            return make_simulation(spec, cfg, net=None if sharded_build else net, mesh=mesh, build_seed=12)

        for save_sharded in (False, True):
            tag = f"sharded_build={{save_sharded}}->{{not save_sharded}}"
            d = r"{tmp_path}/" + tag
            saver = engine(save_sharded)
            ref = schedule_lib.run_windows(saver, saver.init(), 6)
            ck = schedule_lib.SimCheckpointer(d, saver, net, every=0,
                                              n_groups=4)
            st = saver.init()
            for _ in range(3):
                st, _blk = saver.window(st)
            ck.save(st)
            ck.close()
            resumer = engine(not save_sharded)   # the OTHER build path
            st, info = schedule_lib.restore_sim(d, resumer, net, n_groups=4)
            assert info["step"] == 3, tag
            res = schedule_lib.run_windows(resumer, st, 3)
            assert np.array_equal(res.spikes_per_window,
                                  ref.spikes_per_window[3:]), tag
            assert np.array_equal(np.asarray(res.state.ring),
                                  np.asarray(ref.state.ring)), tag
            assert np.array_equal(np.asarray(res.state.spike_count),
                                  np.asarray(ref.state.spike_count)), tag
            print("OK", tag)
        print("SHARDED-BUILD RESUME DONE")
    """))


def test_sigterm_checkpoints_at_window_boundary(tmp_path):
    """Satellite contract: a real SIGTERM delivered mid-run lands a graceful
    grace checkpoint at the next window boundary (exit 0, resume hint), and
    the resumed trajectory is bitwise identical to an uninterrupted run."""
    import signal
    import time as time_lib

    driver = textwrap.dedent(f"""
        import sys
        from repro.core import faults as faults_lib
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation
        from repro.launch.simulate import StopFlag

        spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4,
                                  k_inter=4)
        net = build_network(spec, seed=12, outgoing=True)
        eng = make_simulation(spec, EngineConfig(
            neuron_model="lif", delivery_backend="event", s_max_floor=4,
            overlap_exchange=True), net=net)
        stop = StopFlag().install()
        inj = faults_lib.FaultInjector(
            faults_lib.FaultConfig(jitter_mu_ms=25.0, seed=1),
            n_devices=1, delay_ratio=eng.delay_ratio)
        ck = schedule_lib.SimCheckpointer(r"{tmp_path}", eng, net, every=0)
        try:
            schedule_lib.run_windows(
                eng, eng.init(), 200, checkpointer=ck, faults=inj,
                stop_requested=stop,
                on_window=lambda w, s: print(f"W{{w}}", flush=True))
        except faults_lib.Preempted as e:
            print(f"PREEMPTED {{e.window}} {{stop.name}}", flush=True)
            sys.exit(0)
        raise SystemExit("the run drained 200 windows without the signal")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.Popen([sys.executable, "-u", "-c", driver],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True, env=env)
    try:
        deadline = time_lib.monotonic() + 240
        for line in proc.stdout:
            if line.startswith("W") and int(line[1:]) >= 3:
                proc.send_signal(signal.SIGTERM)
                break
            assert time_lib.monotonic() < deadline, "no window marker seen"
        out, err = proc.communicate(timeout=120)
    finally:
        proc.kill()
    assert proc.returncode == 0, f"STDOUT:\n{out}\nSTDERR:\n{err}"
    preempted = [l for l in out.splitlines() if l.startswith("PREEMPTED")]
    assert preempted, f"no graceful preemption line in:\n{out}\n{err}"
    _, window, signame = preempted[0].split()
    assert signame == "SIGTERM"
    stopped_at = int(window)
    assert stopped_at >= 3

    # Resume from the grace checkpoint (with a *sequential* engine -- the
    # overlap flag is a layout key) and match the uninterrupted reference.
    eng, net = _quick_engine()
    ref = schedule_lib.run_windows(eng, eng.init(), stopped_at + 3)
    st, info = schedule_lib.restore_sim(str(tmp_path), eng, net)
    assert info["step"] == stopped_at
    res = schedule_lib.run_windows(eng, st, 3)
    assert np.array_equal(res.spikes_per_window,
                          ref.spikes_per_window[stopped_at:])
    assert np.array_equal(np.asarray(res.state.ring),
                          np.asarray(ref.state.ring))
    assert np.array_equal(np.asarray(res.state.spike_count),
                          np.asarray(ref.state.spike_count))


def test_reshard_plan_helpers():
    """placement_from_sizes + elastic_reshard_plan + order/moves accounting:
    contiguous plans are identity orderings; incompatible counts raise."""
    from repro.core import partition

    placement = partition.placement_from_sizes([30, 31, 32, 29], 4, n_pad=32)
    assert placement.n_groups == 4 and placement.areas_per_group == 1
    plan = partition.elastic_reshard_plan(placement, 2)
    assert plan == {0: (0, 0), 1: (1, 0), 2: (2, 1), 3: (3, 1)}
    assert np.array_equal(partition.reshard_area_order(plan), np.arange(4))
    assert partition.reshard_moves(plan) == 4  # every peer set changed
    same = partition.elastic_reshard_plan(placement, 4)
    assert partition.reshard_moves(same) == 0
    with pytest.raises(ValueError, match="cannot rebalance"):
        partition.elastic_reshard_plan(placement, 3)
    with pytest.raises(ValueError, match="not divisible"):
        partition.placement_from_sizes([30, 31, 32], 2, n_pad=32)
