"""Synthetic data pipeline: determinism, structure, restart semantics."""

import numpy as np

from repro.data.pipeline import SyntheticLM


def test_batches_deterministic():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=4, seed=3)
    a = ds.batch(17)
    b = ds.batch(17)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = ds.batch(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=4)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # labels[i] is the next token after tokens[i]
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_restart_resumes_identically():
    ds = SyntheticLM(vocab=500, seq_len=16, global_batch=2)
    it = ds.batches(start_step=0)
    first = [next(it) for _ in range(5)]
    it2 = ds.batches(start_step=3)
    again = next(it2)
    assert np.array_equal(first[3]["tokens"], again["tokens"])


def test_planted_bigram_structure():
    """Every other token is (prev + 17) % V: the stream is learnable, so CE
    can fall below log(V) in the example training runs."""
    ds = SyntheticLM(vocab=500, seq_len=64, global_batch=8)
    b = ds.batch(0)
    t = b["tokens"]
    hits = (t[:, 1::2] == (t[:, 0:-1:2] + 17) % 500).mean()
    assert hits == 1.0


def test_token_range():
    ds = SyntheticLM(vocab=77, seq_len=128, global_batch=4)
    b = ds.batch(5)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 77
