"""Checkpoint manager: atomic save/restore, async writer, garbage collection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


@pytest.fixture()
def tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones(4, jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((3, 4))}, "count": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path, tree):
    path = ckpt.save(str(tmp_path), 42, tree, extra={"note": "test"})
    assert os.path.isdir(path)
    restored, step = ckpt.restore(str(tmp_path), like=tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_and_explicit_step(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    _, step = ckpt.restore(str(tmp_path), like=tree, step=1)
    assert step == 1


def test_restore_shape_mismatch_raises(tmp_path, tree):
    ckpt.save(str(tmp_path), 0, tree)
    bad = jax.tree.map(lambda x: jnp.zeros((9,) + x.shape, x.dtype), tree)
    with pytest.raises(ValueError, match="elastic_pod_resize"):
        ckpt.restore(str(tmp_path), like=bad)


def test_no_checkpoint_raises(tmp_path, tree):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "empty"), like=tree)


def test_async_writer_and_gc(tmp_path, tree):
    w = ckpt.AsyncWriter(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        w.submit(step, tree)
    w.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4], "GC must keep only the last 2"
    restored, step = ckpt.restore(str(tmp_path), like=tree)
    assert step == 4


def test_atomicity_no_tmp_left_behind(tmp_path, tree):
    ckpt.save(str(tmp_path), 9, tree)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_save_removes_stale_tmp(tmp_path, tree):
    """A crashed writer's leftover .tmp must not leak files into a fresh
    save of the same step -- the atomic rename would promote them."""
    stale = tmp_path / "step_00000007.tmp"
    stale.mkdir()
    (stale / "stale_garbage.bin").write_bytes(b"junk")
    path = ckpt.save(str(tmp_path), 7, tree)
    assert sorted(os.listdir(path)) == ["arrays.npz", "manifest.json"]
    restored, _ = ckpt.restore(str(tmp_path), like=tree, step=7)
    assert np.array_equal(np.asarray(restored["params"]["w"]),
                          np.asarray(tree["params"]["w"]))


def test_async_writer_sweeps_orphaned_tmp(tmp_path, tree):
    """AsyncWriter GC removes dead .tmp dirs (crashed-writer partial output)
    so a resumed run's directory converges to `keep` clean checkpoints."""
    orphan = tmp_path / "step_00000001.tmp"
    orphan.mkdir()
    (orphan / "partial.npz").write_bytes(b"dead")
    w = ckpt.AsyncWriter(str(tmp_path), keep=2)
    w.submit(2, tree)
    w.close()
    assert not orphan.exists()
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_async_writer_retries_transient_oserror(tmp_path, tree):
    """The first two writes fail with OSError; the bounded-retry path must
    absorb them (run completes, checkpoint lands, retries counted)."""
    fails = {"left": 2}

    def flaky(directory, step, t, *, extra=None):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise OSError("transient blob-store hiccup")
        return ckpt.save(directory, step, t, extra=extra)

    w = ckpt.AsyncWriter(str(tmp_path), retries=3, backoff_s=0.01,
                         save_fn=flaky)
    w.submit(1, tree)
    w.close()
    assert w.retry_count == 2
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_writer_surfaces_exhausted_retries(tmp_path, tree):
    """Past the retry budget the error must surface on close (or the next
    submit), never pass silently."""

    def always_fail(directory, step, t, *, extra=None):
        raise OSError("disk is gone")

    w = ckpt.AsyncWriter(str(tmp_path), retries=1, backoff_s=0.01,
                         save_fn=always_fail)
    w.submit(1, tree)
    with pytest.raises(OSError, match="disk is gone"):
        w.close()
    assert w.retry_count == 1


def test_read_manifest_without_loading_arrays(tmp_path, tree):
    ckpt.save(str(tmp_path), 3, tree, extra={"config_hash": "abc123"})
    manifest, step = ckpt.read_manifest(str(tmp_path))
    assert step == 3
    assert manifest["extra"]["config_hash"] == "abc123"
    with pytest.raises(FileNotFoundError):
        ckpt.read_manifest(str(tmp_path / "missing"))


def test_snn_state_checkpoint_resume(tmp_path):
    """Simulation fault tolerance: checkpoint SimState mid-run, restore, and
    continue -- the resumed trajectory is bit-identical to an uninterrupted
    one (the drive is a pure function of absolute model time)."""
    import dataclasses

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12)
    eng = make_simulation(spec, EngineConfig(neuron_model="lif"), net=net)

    # uninterrupted reference: 10 windows
    st = eng.init()
    for _ in range(10):
        st, blk_ref = eng.window(st)

    # interrupted run: 5 windows -> checkpoint -> restore -> 5 more
    st2 = eng.init()
    for _ in range(5):
        st2, _ = eng.window(st2)
    ckpt.save(str(tmp_path), 5, dataclasses.asdict(st2))
    restored, step = ckpt.restore(
        str(tmp_path), like=dataclasses.asdict(eng.init()))
    assert step == 5
    st3 = type(st2)(**restored)
    for _ in range(5):
        st3, blk_resumed = eng.window(st3)
    assert np.array_equal(np.asarray(blk_ref), np.asarray(blk_resumed))
    assert np.array_equal(np.asarray(st.ring), np.asarray(st3.ring))
