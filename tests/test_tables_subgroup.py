"""Subgroup-sliced inbound inter tables: the memory-diet tentpole.

The contract: slicing each group's inbound inter table over the subgroup
(window-within-group) axis -- ``shard_inter_tables(..., subgroup=gsz)``
emitting ``[S, gsz, A*n_pad, K_in]``, plus the same lane cut for the
outgoing intra tables (``slice_intra_tables``) -- is a pure layout change.
Every lane's
receive scatter already masks targets outside its neuron window to -1, so
dropping those rows from its slice changes no trajectory: spikes, rings and
overflow counts stay bitwise-identical to both the per-group inbound slices
(PR 4) and the replicated reference, across exchanges, adaptive/static
packets and superstep/legacy windows, including forced per-edge overflow and
mid-run checkpoint -> resume across layouts.

Multi-device cases run in subprocesses with 8 forced host devices (per the
launch contract, the main pytest process must keep seeing one device).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_subgroup_cut_partitions_the_group_slice():
    """Synapse-exact layout check, no devices: the union of a shard's gsz
    lane slices is exactly its per-group inbound slice, every lane holds
    only targets inside its own neuron window, the narrow delay dtype
    survives the cut, and the SDS bound brackets the instantiated widths."""
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import (
        build_network, network_sds, shard_inter_tables)

    spec = mam_benchmark_spec(n_areas=4, n_per_area=64, k_intra=8, k_inter=12)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    assert net.delay_inter.dtype == np.int8  # narrow storage dtype
    n_shards, gsz = 2, 2
    cut3 = shard_inter_tables(net, n_shards, mode="group")
    cut4 = shard_inter_tables(net, n_shards, mode="group", subgroup=gsz)
    n_pad = net.n_pad
    rows = net.n_areas * n_pad
    assert cut4.tgt_inter_in.shape[:2] == (n_shards, gsz)
    assert cut4.tgt_inter_in.shape[2] == rows
    assert cut4.dout_inter_in.dtype == net.delay_inter.dtype
    win = n_pad // gsz
    for s in range(n_shards):
        t3, w3, d3 = (np.asarray(x[s]) for x in
                      (cut3.tgt_inter_in, cut3.wout_inter_in,
                       cut3.dout_inter_in))
        syn3 = {(r, int(t3[r, k]), float(w3[r, k]), int(d3[r, k]))
                for r in range(rows) for k in range(t3.shape[1])
                if t3[r, k] >= 0}
        syn4 = set()
        for lane in range(gsz):
            t4, w4, d4 = (np.asarray(x[s, lane]) for x in
                          (cut4.tgt_inter_in, cut4.wout_inter_in,
                           cut4.dout_inter_in))
            tloc = t4[t4 >= 0] % n_pad
            assert ((tloc >= lane * win) & (tloc < (lane + 1) * win)).all()
            syn4 |= {(r, int(t4[r, k]), float(w4[r, k]), int(d4[r, k]))
                     for r in range(rows) for k in range(t4.shape[1])
                     if t4[r, k] >= 0}
        assert syn3 == syn4, f"shard {s} lost/invented synapses"
    # K shrinks ~gsz x (plus per-slice jitter slack), never grows.
    assert cut4.tgt_inter_in.shape[-1] < cut3.tgt_inter_in.shape[-1]
    # The dry-run's SDS stand-in brackets the instantiated slice.
    sds = network_sds(spec, size_multiple=8, outgoing=True,
                      inter_shards=n_shards, subgroup=gsz)
    assert sds.tgt_inter_in.shape[:3] == cut4.tgt_inter_in.shape[:3]
    assert sds.tgt_inter_in.shape[-1] >= cut4.tgt_inter_in.shape[-1]
    assert sds.dout_inter_in.dtype == cut4.dout_inter_in.dtype


def test_intra_slice_partitions_the_outgoing_table():
    """The outgoing intra tables get the same lane cut
    (``slice_intra_tables``): per source row, the union of the gsz lane
    slices is exactly the full row's live synapses, each lane holds only
    targets inside its own window *in the original relative order* (the
    ring-deposit order is what makes the cut bitwise-safe), dtypes
    survive, and the SDS stand-in brackets the instantiated widths."""
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import (
        build_network, network_sds, slice_intra_tables)

    spec = mam_benchmark_spec(n_areas=4, n_per_area=64, k_intra=8, k_inter=12)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    gsz = 4
    cut = slice_intra_tables(net, gsz)
    A, n_pad, K = net.tgt_intra.shape
    n_loc = n_pad // gsz
    assert cut.tgt_intra.shape[:3] == (gsz, A, n_pad)
    assert cut.tgt_intra.shape[-1] < K  # ~gsz x narrower
    assert cut.dout_intra.dtype == net.dout_intra.dtype == np.int8
    assert cut.wout_intra.dtype == np.float32
    t3, w3, d3 = (np.asarray(x) for x in
                  (net.tgt_intra, net.wout_intra, net.dout_intra))
    t4, w4, d4 = (np.asarray(x) for x in
                  (cut.tgt_intra, cut.wout_intra, cut.dout_intra))
    for a in range(A):
        for r in range(n_pad):
            full = [(int(t3[a, r, k]), float(w3[a, r, k]), int(d3[a, r, k]))
                    for k in range(K) if t3[a, r, k] >= 0]
            union = []
            for lane in range(gsz):
                lo = lane * n_loc
                ent = [(int(t4[lane, a, r, k]), float(w4[lane, a, r, k]),
                        int(d4[lane, a, r, k]))
                       for k in range(t4.shape[-1]) if t4[lane, a, r, k] >= 0]
                assert all(lo <= e[0] < lo + n_loc for e in ent)
                # order-preserving: the lane slice IS the full row filtered
                assert ent == [e for e in full if lo <= e[0] < lo + n_loc]
                union += ent
            assert sorted(union) == sorted(full), f"row ({a},{r}) mismatch"
    # Re-slicing an already-4D table is refused, as is a bad divisor.
    with pytest.raises(ValueError):
        slice_intra_tables(cut, gsz)
    with pytest.raises(ValueError):
        slice_intra_tables(net, 7)  # 7 does not divide n_pad
    # The dry-run's SDS stand-in brackets the instantiated slice.
    sds = network_sds(spec, size_multiple=8, outgoing=True,
                      inter_shards=2, subgroup=gsz)
    assert sds.tgt_intra.shape[:3] == cut.tgt_intra.shape[:3]
    assert sds.tgt_intra.shape[-1] >= cut.tgt_intra.shape[-1]
    assert sds.dout_intra.dtype == cut.dout_intra.dtype


def test_subgroup_requires_group_mode_and_divisibility():
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network, shard_inter_tables

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    with pytest.raises(ValueError):
        shard_inter_tables(net, 4, mode="window", subgroup=2)
    with pytest.raises(ValueError):
        shard_inter_tables(net, 2, mode="group", subgroup=7)  # 7 ∤ n_pad


def test_lane_count_mismatch_rejected():
    """Pre-cut 4D tables whose lane count does not match the mesh subgroup
    must be refused at engine build, like the shard-count check."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network, shard_inter_tables
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    cut = shard_inter_tables(net, 1, mode="group", subgroup=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # gsz=1, but 2 lanes
    with pytest.raises(ValueError, match="do not match the"):
        make_simulation(spec, EngineConfig(neuron_model="ignore_and_fire",
                                             delivery_backend="event"), net=cut, mesh=mesh)


@pytest.mark.parametrize("exchange", ["dense", "routed"])
def test_subgroup_engine_bitwise_equivalence(exchange):
    """Acceptance matrix: the subgroup-sliced engine reproduces the
    single-host replicated reference bitwise -- spike blocks AND rings --
    under {static,adaptive} x {superstep,legacy}, and matches the per-group
    (non-subgroup) layout exactly, with zero overflow and ~gsz x narrower
    local slices."""
    print(_run(f"""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks = []
        for _ in range(5):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        ring_ref = np.asarray(s0.ring)
        assert sum(b.sum() for b in blocks) > 0

        def cfg(subgroup, adaptive=False, superstep=None):
            return EngineConfig(
                neuron_model="ignore_and_fire",
                schedule="structure_aware", delivery_backend="event",
                exchange={exchange!r}, s_max_floor=32,
                subgroup_inter_tables=subgroup,
                adaptive_exchange=adaptive, superstep=superstep)

        for adaptive in (False, True):
            for superstep in (None, False):
                eng = make_simulation(spec, cfg(True, adaptive, superstep), net=net, mesh=mesh)
                st = eng.init()
                for w in range(5):
                    st, blk = eng.window(st)
                    assert np.array_equal(
                        np.asarray(blk).astype(bool), blocks[w]
                    ), (adaptive, superstep, w)
                assert np.array_equal(np.asarray(st.ring), ring_ref), (
                    adaptive, superstep, "ring")
                assert int(st.overflow) == 0, (adaptive, superstep)

        # Layout A/B at identical config: subgroup vs per-group slices.
        a = make_simulation(spec, cfg(True), net=net, mesh=mesh)
        b = make_simulation(spec, cfg(False), net=net, mesh=mesh)
        sa, sb = a.init(), b.init()
        for w in range(5):
            sa, ba = a.window(sa)
            sb, bb = b.window(sb)
            assert np.array_equal(np.asarray(ba), np.asarray(bb)), w
        assert np.array_equal(np.asarray(sa.ring), np.asarray(sb.ring))
        print("matrix OK:", {exchange!r})
    """))


def test_subgroup_forced_overflow_identical():
    """Packets are formed on the *send* side, so starving the packet bound
    drops the same spikes under either receive layout: overflow counts are
    nonzero AND bitwise-equal between the subgroup-sliced and per-group
    engines, and so are the (lossy) trajectories."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=2000.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def engine(subgroup):
            return make_simulation(spec, EngineConfig(
                neuron_model="ignore_and_fire",
                schedule="structure_aware", delivery_backend="event",
                exchange="routed", s_max_headroom=0.0, s_max_floor=1,
                subgroup_inter_tables=subgroup), net=net, mesh=mesh)

        a, b = engine(True), engine(False)
        sa, sb = a.init(), b.init()
        for w in range(5):
            sa, ba = a.window(sa)
            sb, bb = b.window(sb)
            assert np.array_equal(np.asarray(ba), np.asarray(bb)), w
        assert int(sa.overflow) > 0, "bound was meant to starve"
        assert int(sa.overflow) == int(sb.overflow)
        assert np.array_equal(np.asarray(sa.ring), np.asarray(sb.ring))
        print("overflow", int(sa.overflow), "identical under both layouts")
    """))


def test_resume_across_subgroup_layout_change(tmp_path):
    """subgroup_inter_tables is a pure-layout key: it never enters the
    resume-config hash, and a mid-run checkpoint taken under one layout
    resumes bitwise under the other, both directions."""
    from repro.core import schedule as schedule_lib
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig

    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, outgoing=True)
    cfg_a = EngineConfig(neuron_model="lif", delivery_backend="event",
                         subgroup_inter_tables=True)
    cfg_b = EngineConfig(neuron_model="lif", delivery_backend="event",
                         subgroup_inter_tables=False)
    h_a, pay_a = schedule_lib.resume_config_hash(cfg_a, net)
    h_b, pay_b = schedule_lib.resume_config_hash(cfg_b, net)
    assert h_a == h_b
    assert pay_a["subgroup_inter_tables"] != pay_b["subgroup_inter_tables"]

    print(_run(f"""
        import numpy as np, jax
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def engine(subgroup):
            return make_simulation(spec, EngineConfig(
                neuron_model="ignore_and_fire", delivery_backend="event",
                exchange="routed", s_max_floor=32,
                subgroup_inter_tables=subgroup), net=net, mesh=mesh)

        for save_sub in (True, False):
            tag = f"subgroup={{save_sub}}->{{not save_sub}}"
            d = r"{tmp_path}/" + tag
            saver = engine(save_sub)
            ref = schedule_lib.run_windows(saver, saver.init(), 6)
            ck = schedule_lib.SimCheckpointer(d, saver, net, every=0,
                                              n_groups=4)
            st = saver.init()
            for _ in range(3):
                st, _blk = saver.window(st)
            ck.save(st)
            ck.close()
            resumer = engine(not save_sub)   # the OTHER table layout
            st, info = schedule_lib.restore_sim(d, resumer, net, n_groups=4)
            assert info["step"] == 3, tag
            res = schedule_lib.run_windows(resumer, st, 3)
            assert np.array_equal(res.spikes_per_window,
                                  ref.spikes_per_window[3:]), tag
            assert np.array_equal(np.asarray(res.state.ring),
                                  np.asarray(ref.state.ring)), tag
            print("resume OK", tag)
    """))
