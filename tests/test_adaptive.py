"""Adaptive two-phase exchange tests: count-then-payload must be invisible.

The tentpole invariants:

* **bit-identity** -- with ``EngineConfig.adaptive_exchange=True`` every
  exchange (local, dense, routed) reproduces the static path's spike trains
  and rings bitwise whenever the static path drops nothing;
* **overflow elimination** -- a workload that forces the static bounds to
  drop spikes (``s_max_headroom=0, s_max_floor=1``) runs with
  ``SimState.overflow == 0`` under adaptive mode, same seed and spike
  trains, because phase-1 counts size every packet and the bucket ladders
  top out at the hard population cap;
* **bucket-edge exactness** -- a window whose spike count lands exactly on
  a ladder rung selects that rung (no off-by-one), one past it selects the
  next;
* **byte savings** -- the measured ``SimState.shipped_bytes`` of an
  adaptive routed run is strictly below the static run's, and the static
  run's measured bytes equal the static accounting exactly.

Multi-device cases run in subprocesses with 8 forced host devices (per the
launch contract, the main pytest process must keep seeing one device).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_bucket_ladder_and_index_edges():
    """Host-only ladder semantics: power-of-two rungs topped by the cap
    exactly, and the boundary rule -- a count landing ON a rung selects it,
    one past it selects the next rung."""
    import jax.numpy as jnp

    from repro.core.delivery import bucket_ladder, expected_bucket
    from repro.kernels.ops import bucket_index

    ladder = bucket_ladder(4, 100)
    assert ladder == (4, 8, 16, 32, 64, 100)
    assert bucket_ladder(4, 64) == (4, 8, 16, 32, 64)   # cap on a rung
    assert bucket_ladder(7, 7) == (7,)                   # degenerate
    assert bucket_ladder(0, 5) == (1, 2, 4, 5)           # floor clamped to 1

    arr = ladder
    # Exactly on a rung -> that rung; one past -> the next.
    for i, b in enumerate(arr):
        assert int(bucket_index(arr, jnp.int32(b))) == i, b
        if i + 1 < len(arr):
            assert int(bucket_index(arr, jnp.int32(b + 1))) == i + 1, b
    assert int(bucket_index(arr, jnp.int32(0))) == 0
    # Clamped at the top (unreachable when the cap is the population bound).
    assert int(bucket_index(arr, jnp.int32(10_000))) == len(arr) - 1

    # The modelled counterpart used by the static accounting.
    assert expected_bucket(ladder, 3.2) == 4
    assert expected_bucket(ladder, 4.0) == 4
    assert expected_bucket(ladder, 4.1) == 8
    assert expected_bucket(ladder, 1e9) == 100


def test_adaptive_local_engine_bitwise_and_bucket_edge():
    """Single-host event engine under adaptive mode: bitwise-identical to
    the onehot reference, zero overflow -- including with the floor pinned
    so the busiest cycle's count lands *exactly on* a rung edge, and one
    below it (the count then overflows the floor rung onto the next)."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=1000.0)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    ref = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware"), net=net)
    s0 = ref.init()
    blocks = []
    for _ in range(4):
        s0, b = ref.window(s0)
        blocks.append(np.asarray(b))
    ring_ref = np.asarray(s0.ring)
    # The busiest cycle's whole-network count: the inter ladder's floor rung
    # boundary case.
    max_cycle = max(int(b.reshape(b.shape[0], -1).sum(1).max())
                    for b in blocks)
    assert max_cycle > 1, "workload must spike"

    for floor in (max_cycle, max_cycle - 1, 1):
        eng = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="structure_aware",
            delivery_backend="event", adaptive_exchange=True,
            s_max_headroom=0.0, s_max_floor=floor), net=net)
        st = eng.init()
        for w in range(4):
            st, blk = eng.window(st)
            assert np.array_equal(
                np.asarray(blk).astype(bool), blocks[w]), (floor, w)
        assert np.array_equal(np.asarray(st.ring), ring_ref), floor
        assert int(st.overflow) == 0, floor
    del jax


def test_adaptive_eliminates_forced_overflow_single_host():
    """The overflow failure mode, single host: ``headroom=0, floor=1``
    forces the static event bounds to drop spikes (nonzero overflow);
    adaptive mode with the *same seed and config* reports zero overflow and
    reproduces the unconstrained reference ring bitwise."""
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=1000.0)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    ref = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware"), net=net)
    s_ref = ref.init()
    for _ in range(4):
        s_ref, _ = ref.window(s_ref)

    got = {}
    for adaptive in (False, True):
        eng = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="structure_aware",
            delivery_backend="event", adaptive_exchange=adaptive,
            s_max_headroom=0.0, s_max_floor=1), net=net)
        st = eng.init()
        for _ in range(4):
            st, _ = eng.window(st)
        got[adaptive] = st
    assert int(got[False].overflow) > 0, "static floor=1 must drop spikes"
    assert int(got[True].overflow) == 0, "adaptive must never drop"
    # ignore-and-fire emission is input-independent: spike trains agree by
    # construction; the *ring* proves no delivery was lost.
    assert np.array_equal(np.asarray(got[True].ring), np.asarray(s_ref.ring))
    assert not np.array_equal(np.asarray(got[False].ring),
                              np.asarray(s_ref.ring)), (
        "static forced-overflow run should have lost deliveries")


def test_adaptive_distributed_equivalence_and_byte_savings():
    """Tentpole, 8 fake devices: adaptive == static == single-host reference
    bitwise (spike blocks AND rings) for {dense, routed} x {superstep,
    legacy} x {event, scatter-routed}, with zero overflow in every adaptive
    run; the static run's measured shipped bytes equal the static
    accounting exactly, and the adaptive routed run ships strictly fewer
    bytes than its static counterpart."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(
            n_areas=8, n_per_area=32, k_intra=4, k_inter=4, rate_hz=30.0,
            area_adjacency=ring_area_adjacency(8, width=2))
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks = []
        for _ in range(6):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        ring_ref = np.asarray(s0.ring)
        assert sum(b.sum() for b in blocks) > 0

        shipped = {}
        cells = [("dense", "event", None), ("routed", "event", None),
                 ("routed", "event", False), ("routed", "scatter", None)]
        for exch, backend, superstep in cells:
            for adaptive in (False, True):
                eng = make_simulation(spec, EngineConfig(
                    neuron_model="ignore_and_fire",
                    schedule="structure_aware", delivery_backend=backend,
                    exchange=exch, s_max_floor=8, superstep=superstep,
                    adaptive_exchange=adaptive), net=net, mesh=mesh)
                st = eng.init()
                for w in range(6):
                    st, blk = eng.window(st)
                    assert np.array_equal(
                        np.asarray(blk).astype(bool), blocks[w]
                    ), (exch, backend, superstep, adaptive, w)
                assert np.array_equal(np.asarray(st.ring), ring_ref), (
                    exch, backend, superstep, adaptive)
                assert int(st.overflow) == 0, (exch, backend, adaptive)
                shipped[(exch, backend, superstep, adaptive)] = float(
                    st.shipped_bytes)
                if not adaptive:
                    # Static runs ship exactly what the static accounting
                    # promises (6 windows of the Engine.wire_bytes total).
                    want = 6 * eng.wire_bytes["total_bytes"]
                    got = float(st.shipped_bytes)
                    assert abs(got - want) <= 1e-6 * max(want, 1), (
                        exch, backend, got, want)

        # Conventional adaptive path (per-cycle two-phase exchange).
        eng = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional",
            delivery_backend="event", s_max_floor=8,
            adaptive_exchange=True), net=net, mesh=mesh)
        st = eng.init()
        for w in range(6):
            st, blk = eng.window(st)
            assert np.array_equal(np.asarray(blk).astype(bool), blocks[w]), w
        assert np.array_equal(np.asarray(st.ring), ring_ref)
        assert int(st.overflow) == 0

        # Measured byte savings: adaptive routed < static routed.
        st_static = shipped[("routed", "event", None, False)]
        st_adapt = shipped[("routed", "event", None, True)]
        assert st_adapt < st_static, (st_adapt, st_static)
        print(f"OK routed shipped adaptive {st_adapt:,.0f} < "
              f"static {st_static:,.0f}")
    """))


def test_adaptive_eliminates_forced_overflow_distributed():
    """Satellite: the routed per-edge forced-overflow workload (rate 2000,
    headroom 0, floor 1 -- the exact config the static suite uses to prove
    spills are *visible*) runs overflow-free under adaptive mode with the
    same seed and identical spike trains, bitwise equal to the single-host
    reference."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        adj = ring_area_adjacency(8, width=1)
        spec = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=2000.0,
                                  area_adjacency=adj)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="structure_aware"), net=net)
        s_ref = ref.init()
        for _ in range(5):
            s_ref, _ = ref.window(s_ref)

        got = {}
        for adaptive in (False, True):
            eng = make_simulation(spec, EngineConfig(
                neuron_model="ignore_and_fire",
                schedule="structure_aware", exchange="routed",
                delivery_backend="event", s_max_headroom=0.0,
                s_max_floor=1, adaptive_exchange=adaptive), net=net, mesh=mesh)
            st = eng.init()
            for _ in range(5):
                st, _ = eng.window(st)
            got[adaptive] = st
        assert int(got[False].spike_count.sum()) > 0
        assert int(got[False].overflow) > 0, (
            "static floor=1 must spill on this workload")
        assert int(got[True].overflow) == 0, (
            "adaptive must eliminate the spill")
        assert np.array_equal(np.asarray(got[True].spike_count),
                              np.asarray(got[False].spike_count))
        assert np.array_equal(np.asarray(got[True].ring),
                              np.asarray(s_ref.ring)), (
            "adaptive run must match the unconstrained reference bitwise")
        print("OK")
    """))


def test_adaptive_single_group_mesh_runs_inprocess():
    """A 1x1 mesh exercises the adaptive machinery (count collectives over
    one device, ladder switches, offset-0 routed round) in-process, bitwise
    against the single-host reference."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=30.0)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ref = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="conventional"), net=net)
    s0 = ref.init()
    for exch in ("dense", "routed"):
        eng = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="structure_aware",
            delivery_backend="event", exchange=exch,
            adaptive_exchange=True, s_max_floor=4), net=net, mesh=mesh)
        assert eng.wire_bytes["adaptive_on"] is True
        assert eng.wire_bytes["adaptive"]["applies"] is True
        st = eng.init()
        s_ref = s0
        for w in range(4):
            s_ref, blk_ref = ref.window(s_ref)
            st, blk = eng.window(st)
            assert np.array_equal(np.asarray(blk).astype(bool),
                                  np.asarray(blk_ref)), (exch, w)
        assert np.array_equal(np.asarray(st.ring), np.asarray(s_ref.ring))
        assert int(st.overflow) == 0


def test_adaptive_accounting_and_two_phase_cost():
    """Host-only: the adaptive byte model reports both sizings coherently
    (worst >= expected payload, savings positive when the static headroom
    is large), and cost_model.exchange_time_s prices the two-phase trade:
    one extra alpha dispatch, won back by the byte saving at scale."""
    from repro.core import cost_model as cm
    from repro.core import delivery
    from repro.core import exchange as exchange_lib
    from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
    from repro.core.connectivity import area_adjacency, build_network

    spec = mam_benchmark_spec(n_areas=8, n_per_area=256, k_intra=8,
                              k_inter=8,
                              area_adjacency=ring_area_adjacency(8, width=2))
    net = build_network(spec, seed=12, outgoing=True)
    rep = exchange_lib.wire_report(
        net, area_adjacency(net, spec), backend="event", n_groups=8, gsz=2,
        headroom=8.0, floor=4)
    for exch in ("dense", "routed"):
        ad = rep[exch]["adaptive"]
        assert ad["applies"]
        assert ad["payload_bytes_worst"] >= ad["payload_bytes_expected"]
        assert (ad["counts_bytes"] + ad["payload_bytes_expected"]
                == ad["total_bytes_expected"])
        assert ad["static_total_bytes"] == rep[exch]["total_bytes"]
    # The routed sparse config must save (the bench assertion's twin).
    assert rep["routed"]["adaptive"]["saved_bytes"] > 0

    # Bit-packed dense backends have no id packets to size.
    rep_sc = exchange_lib.wire_report(
        net, area_adjacency(net, spec), backend="scatter", n_groups=8,
        gsz=2, headroom=8.0, floor=4)
    assert rep_sc["dense"]["adaptive"]["applies"] is False
    assert rep_sc["routed"]["adaptive"]["applies"] is True

    # Two-phase cost: an extra dispatch, cheaper overall when the payload
    # saving dominates; never cheaper when nothing is saved.
    mpi = cm.SUPERMUC_MPI
    ad = rep["routed"]["adaptive"]
    static_t = cm.exchange_time_s(0, ad["static_total_bytes"], 16, mpi)
    two_t = cm.exchange_time_s(
        ad["counts_bytes"], ad["payload_bytes_expected"], 16, mpi)
    assert two_t == pytest.approx(
        mpi.call_time_s(16, ad["counts_bytes"])
        + mpi.call_time_s(16, ad["payload_bytes_expected"]))
    assert cm.exchange_time_s(64, 1000, 16, mpi) > cm.exchange_time_s(
        0, 1000, 16, mpi)
    # At production-scale savings the two-phase exchange wins outright.
    big_static = 140 * 2**20
    big_adapt = 26 * 2**20
    assert cm.exchange_time_s(340_000, big_adapt, 256, mpi) < (
        cm.exchange_time_s(0, big_static, 256, mpi))
    del delivery, static_t, two_t
