"""Per-rule tests for EngineConfig.validate()/check().

Every constructor-time refusal now lives in one place: ``validate()``
returns the FULL list of violated rules (field, problem, remedy) and
``check()`` raises one structured :class:`ConfigError` aggregating them,
instead of the old one-raise-per-constructor-replay loop. The factories
pass the dispatch context (``distributed=True/False``) so context rules
ride the same error.
"""

import dataclasses

import pytest

from repro.core.engine import ConfigError, ConfigViolation, EngineConfig
from repro.core.factory import make_simulation


def _violations(**kw) -> list[ConfigViolation]:
    """Violations a constructor call with these fields would raise."""
    with pytest.raises(ConfigError) as exc:
        EngineConfig(**kw)
    return list(exc.value.violations)


def _single(field: str, problem_frag: str, **kw) -> ConfigViolation:
    vs = _violations(**kw)
    assert len(vs) == 1, vs
    (v,) = vs
    assert v.field == field
    assert problem_frag in v.problem, v.problem
    assert v.remedy
    return v


# ---------------------------------------------------------------------------
# construction-time rules, one test per rule


def test_unknown_neuron_model():
    v = _single("neuron_model", "unknown neuron model",
                neuron_model="hodgkin_huxley")
    assert "'lif'" in v.remedy


def test_unknown_schedule():
    _single("schedule", "unknown schedule", schedule="round_robin")


def test_unknown_delivery_backend():
    _single("delivery_backend", "unknown delivery_backend",
            delivery_backend="smoke_signals")


def test_unknown_exchange():
    _single("exchange", "unknown exchange", exchange="carrier_pigeon")


def test_s_max_burst_must_be_positive():
    v = _single("s_max_burst", "burst slack", s_max_burst=0)
    assert ">= 1" in v.remedy


def test_routed_requires_structure_aware():
    v = _single("exchange", "structure-aware",
                exchange="routed", schedule="conventional")
    assert "structure_aware" in v.remedy


def test_superstep_requires_structure_aware():
    _single("superstep", "no window to fuse",
            superstep=True, schedule="conventional")


def test_superstep_kernel_requires_structure_aware():
    _single("superstep_kernel", "no window to fuse",
            superstep_kernel=True, schedule="conventional")


def test_superstep_kernel_conflicts_with_superstep_false():
    _single("superstep_kernel", "conflicts with superstep=False",
            superstep_kernel=True, superstep=False)


def test_overlap_exchange_requires_structure_aware():
    _single("overlap_exchange", "no", schedule="conventional",
            overlap_exchange=True)


def test_sharded_build_requires_event_backend():
    _single("sharded_build", "event", sharded_build=True,
            delivery_backend="onehot")


def test_sharded_build_requires_sharded_tables():
    _single("sharded_build", "replicated", sharded_build=True,
            delivery_backend="event", shard_inter_tables=False)


def test_sharded_build_requires_structure_aware():
    _single("sharded_build", "structure-aware", sharded_build=True,
            delivery_backend="event", schedule="conventional")


# ---------------------------------------------------------------------------
# aggregation: one error reports ALL violations


def test_all_violations_reported_at_once():
    vs = _violations(neuron_model="nope", schedule="nope",
                     delivery_backend="nope", exchange="nope")
    fields = {v.field for v in vs}
    assert fields == {"neuron_model", "schedule", "delivery_backend",
                      "exchange"}


def test_error_message_lists_every_rule_with_remedy():
    with pytest.raises(ConfigError) as exc:
        EngineConfig(neuron_model="nope", schedule="conventional",
                     superstep=True)
    msg = str(exc.value)
    assert "2 rules violated" in msg
    assert "neuron_model" in msg and "superstep" in msg
    assert "remedy" in msg


def test_violation_str_has_field_problem_remedy():
    v = ConfigViolation("f", "broken", "fix it")
    assert str(v) == "f: broken [remedy: fix it]"


# ---------------------------------------------------------------------------
# context rules (validate(distributed=...) on construction-valid configs)


def test_valid_config_has_no_violations():
    cfg = EngineConfig(delivery_backend="event")
    assert cfg.validate() == []
    assert cfg.validate(distributed=False) == []
    assert cfg.validate(distributed=True) == []
    cfg.check(distributed=False)  # must not raise


def test_single_host_rejects_mesh_exchange():
    cfg = EngineConfig(exchange="dense")
    assert cfg.validate() == []  # construction-valid
    vs = cfg.validate(distributed=False)
    assert len(vs) == 1 and vs[0].field == "exchange"
    assert "needs a device mesh" in vs[0].problem
    assert "mesh=" in vs[0].remedy


def test_single_host_rejects_sharded_build():
    cfg = EngineConfig(delivery_backend="event", sharded_build=True)
    assert cfg.validate() == []
    vs = cfg.validate(distributed=False)
    assert len(vs) == 1 and vs[0].field == "sharded_build"
    assert "distributed construction mode" in vs[0].problem


def test_distributed_rejects_superstep_kernel():
    cfg = EngineConfig(superstep_kernel=True)
    assert cfg.validate() == []
    vs = cfg.validate(distributed=True)
    assert len(vs) == 1 and vs[0].field == "superstep_kernel"
    assert "single-host only" in vs[0].problem


def test_factory_surfaces_context_violations():
    """make_simulation reports the single-host context rules up front."""
    from repro.core.areas import mam_benchmark_spec

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
    with pytest.raises(ConfigError, match="needs a device mesh"):
        make_simulation(spec, EngineConfig(exchange="dense"))


def test_config_error_is_value_error():
    """Pre-refactor callers caught ValueError; that contract holds."""
    with pytest.raises(ValueError):
        EngineConfig(neuron_model="nope")


# ---------------------------------------------------------------------------
# deprecated entry points still construct working engines (with a warning)


def test_old_entry_points_warn_and_work():
    import numpy as np

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import make_engine

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12)
    cfg = EngineConfig()
    with pytest.warns(DeprecationWarning, match="make_simulation"):
        old = make_engine(net, spec, cfg)
    new = make_simulation(spec, cfg, net=net)
    st_o, blk_o = old.window(old.init())
    st_n, blk_n = new.window(new.init())
    assert np.array_equal(np.asarray(blk_o), np.asarray(blk_n))
