"""Architecture smoke tests (reduced configs): forward + one train step on
CPU, output shapes, no NaNs -- plus decode/prefill consistency per family and
the memory-critical loss/attention identities.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.common import ShapeSpec
from repro.configs.registry import arch_cells, get_arch, list_archs
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _smoke_batch(bundle, rng, b=2, s=16, vocab=64):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32),
    }
    for name, make in bundle.extra_inputs.items():
        spec = make(b, s)
        batch[name] = jnp.asarray(rng.normal(size=spec.shape), spec.dtype)
    return batch


@pytest.mark.parametrize("arch_id", list_archs())
def test_arch_smoke_forward_and_train_step(arch_id):
    """One forward + one AdamW train step on the reduced config."""
    bundle = get_arch(arch_id, reduced=True)
    rng = np.random.default_rng(0)
    params = bundle.model.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(bundle, rng)

    extras = {k: batch[k] for k in bundle.extra_inputs}
    logits, aux = jax.jit(bundle.model.forward)(params, batch["tokens"], **extras)
    assert logits.shape[:2] == batch["tokens"].shape
    assert not bool(jnp.isnan(logits).any()), "forward produced NaNs"

    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(bundle.loss)(p, b)
        p, o, m = adamw_update(g, o, p, opt_cfg)
        return p, o, loss

    p1, o1, loss1 = step(params, opt, batch)
    p2, o2, loss2 = step(p1, o1, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    assert float(loss2) < float(loss1) + 0.5, "loss exploding on repeat batch"


@pytest.mark.parametrize("arch_id", list_archs())
def test_arch_cells_defined(arch_id):
    """Every arch maps all four assigned shapes to run-or-documented-skip."""
    cells = arch_cells(arch_id)
    assert len(cells) == 4
    names = {shape.name for shape, _ in cells}
    assert names == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    for shape, skip in cells:
        if skip is not None:
            assert len(skip) > 10, "skip reasons must be substantive"


@pytest.mark.parametrize("arch_id", ["h2o-danube-1.8b", "qwen2-0.5b",
                                     "mamba2-2.7b", "zamba2-1.2b",
                                     "internvl2-76b"])
def test_decode_matches_full_forward(arch_id):
    """Prefill(cache) + decode step == full forward on the extended sequence."""
    bundle = get_arch(arch_id, reduced=True)
    model = bundle.model
    rng = np.random.default_rng(1)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _smoke_batch(bundle, rng)
    toks = batch["tokens"]
    extras = {k: batch[k] for k in bundle.extra_inputs}

    logits_full, _ = jax.jit(model.forward)(params, toks, **extras)
    cache = model.init_cache(toks.shape[0], toks.shape[1] + 8, jnp.float32)
    kwargs = dict(extras) if extras else {}
    lp, cache = jax.jit(model.forward_with_cache)(
        params, toks, cache, jnp.int32(0), **kwargs)
    rel = float(jnp.abs(lp - logits_full).max()) / max(
        float(jnp.abs(logits_full).max()), 1e-6)
    assert rel < 5e-4, f"prefill mismatch {rel}"

    nxt = jnp.argmax(lp[:, -1:], -1).astype(jnp.int32)
    ld, _ = jax.jit(model.forward_with_cache)(
        params, nxt, cache, jnp.int32(toks.shape[1]))
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    lf2, _ = jax.jit(model.forward)(params, toks2, **extras)
    rel = float(jnp.abs(ld[:, 0] - lf2[:, -1]).max()) / max(
        float(jnp.abs(lf2).max()), 1e-6)
    assert rel < 5e-4, f"decode mismatch {rel}"


def test_whisper_decode_matches_forward():
    bundle = get_arch("whisper-medium", reduced=True)
    model = bundle.model
    rng = np.random.default_rng(2)
    params = model.init_params(jax.random.PRNGKey(2))
    batch = _smoke_batch(bundle, rng)
    toks, frames = batch["tokens"], batch["frames"]
    logits_full, _ = jax.jit(model.forward)(params, toks, frames=frames)
    enc = jax.jit(model.encode)(params, frames)
    cache = model.init_cache(2, 24, jnp.float32)
    lp, cache = jax.jit(model.forward_with_cache)(
        params, toks, cache, jnp.int32(0), enc_out=enc)
    assert float(jnp.abs(lp - logits_full).max()) < 1e-4


def test_chunked_ce_equals_full_ce():
    from repro.models import layers
    rng = np.random.default_rng(3)
    b, s, d, v = 2, 32, 16, 48
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    full = layers.cross_entropy(h @ w, labels)
    chunked = layers.chunked_cross_entropy(lambda hc: hc @ w, h, labels, chunk=8)
    assert float(jnp.abs(full - chunked)) < 1e-5


def test_streaming_attention_equals_dense():
    import repro.models.layers as L
    rng = np.random.default_rng(4)
    b, s, h, hkv, dh = 2, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for window in (0, 9):
        out_s = L._streaming_attention(q, k, v, pos, pos, jnp.int32(s), window)
        out_d = L.attention_scores(
            q, k, v, L.causal_window_mask(pos, pos, None, window))
        assert float(jnp.abs(out_s - out_d).max()) < 2e-5, window


def test_moe_capacity_and_balance():
    """Top-1 dispatch: uniform router -> all tokens land; aux loss ~1."""
    from repro.models.moe import MoEConfig, moe_apply, moe_init
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=32, capacity_factor=2.0)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert 0.5 < float(aux) < 4.5  # perfectly balanced -> 1.0
    assert not bool(jnp.isnan(y).any())


def test_moe_param_and_active_counts():
    llama4 = get_arch("llama4-maverick-400b-a17b")
    assert 3.5e11 < llama4.cfg.param_count() < 4.5e11
    assert 1.0e10 < llama4.cfg.active_param_count() < 2.0e10
    grok = get_arch("grok-1-314b")
    assert 2.8e11 < grok.cfg.param_count() < 3.4e11
    assert 7.0e10 < grok.cfg.active_param_count() < 1.0e11


def test_gemma3_window_pattern():
    b = get_arch("gemma3-27b")
    w = np.asarray(b.cfg.window_array()).reshape(-1)
    assert len(w) == 62
    assert (w[:6] == [1024, 1024, 1024, 1024, 1024, 0]).all()
    th = np.asarray(b.cfg.theta_array()).reshape(-1)
    assert th[5] == 1e6 and th[0] == 10_000.0

def test_mamba2_chunked_equals_sequential():
    bundle = get_arch("mamba2-2.7b", reduced=True)
    model = bundle.model
    params = model.init_params(jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 24), 0, 64)
    logits, _ = jax.jit(model.forward)(params, toks)
    cache = model.init_cache(2, 0, jnp.float32)
    outs = []
    c = cache
    step = jax.jit(model.forward_with_cache)
    for t in range(24):
        lt, c = step(params, toks[:, t:t + 1], c, jnp.int32(t))
        outs.append(lt[:, 0])
    seq = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(seq - logits).max()) / float(jnp.abs(logits).max())
    assert rel < 5e-4, f"SSD chunked vs sequential mismatch: {rel}"


def test_pallas_attention_backend_matches_jnp():
    """Opt-in fused Pallas attention == jnp streaming path, end-to-end
    through the transformer forward (single device, interpret mode)."""
    import dataclasses
    import repro.models.layers as L
    from repro.models.transformer import Transformer, TransformerConfig

    old_thresh = L.FLASH_THRESHOLD
    L.FLASH_THRESHOLD = 16
    try:
        base = TransformerConfig(
            name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
            d_ff=128, vocab=256, window_pattern=(8, 0))
        m_jnp = Transformer(base)
        m_pal = Transformer(dataclasses.replace(base, use_pallas_attention=True))
        params = m_jnp.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 256)
        out_j, _ = jax.jit(m_jnp.forward)(params, toks)
        out_p, _ = jax.jit(m_pal.forward)(params, toks)
        rel = float(jnp.abs(out_p - out_j).max()) / float(jnp.abs(out_j).max())
        assert rel < 1e-4, rel
    finally:
        L.FLASH_THRESHOLD = old_thresh
