"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Kernels run in interpret=True on CPU (the TPU lowering is the target; the
semantics are validated here). Float comparisons are against *jitted* oracles
-- jit and eager differ by FMA contraction (1 ulp), the kernels match jit
bitwise.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

LIF_KW = dict(p11=0.8187308, p21=3.617e-4, p22=0.9900498,
              v_th=15.0, v_reset=0.0, t_ref_steps=20)


@pytest.mark.parametrize("n", [64, 129, 1000, 4096, 8192])
def test_lif_update_matches_oracle(n):
    rng = np.random.default_rng(n)
    v = jnp.asarray(rng.normal(5, 4, n), jnp.float32)
    i_syn = jnp.asarray(rng.normal(150, 80, n), jnp.float32)
    refrac = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    i_in = jnp.asarray(rng.normal(40, 30, n), jnp.float32)
    alive = jnp.asarray(rng.random(n) < 0.9)
    out_k = ops.lif_update(v, i_syn, refrac, i_in, alive, **LIF_KW)
    oracle = jax.jit(functools.partial(ref.lif_update_ref, **LIF_KW))
    out_r = oracle(v, i_syn, refrac, i_in, alive)
    for name, a, b in zip(("v", "i_syn", "refrac", "spk"), out_k, out_r):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_lif_update_2d_state():
    """The ops wrapper flattens arbitrary shapes (engines use [A, n_pad])."""
    rng = np.random.default_rng(0)
    shape = (4, 96)
    v = jnp.asarray(rng.normal(5, 4, shape), jnp.float32)
    i_syn = jnp.zeros(shape, jnp.float32)
    refrac = jnp.zeros(shape, jnp.int32)
    i_in = jnp.asarray(rng.normal(0, 10, shape), jnp.float32)
    alive = jnp.ones(shape, bool)
    out = ops.lif_update(v, i_syn, refrac, i_in, alive, **LIF_KW)
    assert out[0].shape == shape
    assert out[3].dtype == jnp.bool_


def test_lif_refractory_semantics():
    """A spiking neuron resets and stays clamped for t_ref steps."""
    kw = dict(LIF_KW, t_ref_steps=3)
    v = jnp.asarray([20.0] * 128, jnp.float32)  # above threshold after prop
    i_syn = jnp.zeros(128, jnp.float32)
    refrac = jnp.zeros(128, jnp.int32)
    alive = jnp.ones(128, bool)
    v, i_syn, refrac, spk = ops.lif_update(v, i_syn, refrac,
                                           jnp.zeros(128), alive, **kw)
    assert bool(spk.all()) and float(v.max()) == 0.0 and int(refrac[0]) == 3
    for step in range(3):
        v, i_syn, refrac, spk = ops.lif_update(
            v, i_syn, refrac, jnp.full((128,), 1e6), alive, **kw)
        assert not bool(spk.any()), f"refractory step {step} must not spike"
    v, i_syn, refrac, spk = ops.lif_update(
        v, i_syn, refrac, jnp.full((128,), 1e6), alive, **kw)
    assert bool(spk.all()), "after refractory period the huge input must fire"


@pytest.mark.parametrize("n,k,n_src,lo,span", [
    (64, 8, 128, 1, 5),
    (300, 16, 512, 10, 9),
    (256, 64, 256, 1, 30),
    (128, 3, 64, 2, 2),
    (1024, 32, 2048, 10, 91),
])
def test_spike_deliver_matches_oracle(n, k, n_src, lo, span):
    rng = np.random.default_rng(k)
    spikes = jnp.asarray(rng.random(n_src) < 0.1, jnp.float32)
    src = jnp.asarray(rng.integers(0, n_src, (n, k)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (n, k))) / 256.0, jnp.float32)
    d = jnp.asarray(rng.integers(lo, lo + span, (n, k)), jnp.int32)
    out_k = ops.spike_deliver(spikes, src, w, d, steps_lo=lo, r_span=span)
    oracle = jax.jit(functools.partial(ref.spike_deliver_ref,
                                       steps_lo=lo, r_span=span))
    assert np.array_equal(np.asarray(out_k), np.asarray(oracle(spikes, src, w, d)))


def test_spike_deliver_then_apply_contrib_equals_ring_deposit():
    """kernel contributions rolled into the ring == reference deposit."""
    from repro.core import ring_buffer
    rng = np.random.default_rng(3)
    n, k, r, lo, span = 96, 8, 16, 1, 6
    spikes = jnp.asarray(rng.random(n) < 0.3, jnp.float32)
    src = jnp.asarray(rng.integers(0, n, (n, k)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (n, k))) / 256.0, jnp.float32)
    d = jnp.asarray(rng.integers(lo, lo + span, (n, k)), jnp.int32)
    ring = jnp.asarray(np.round(rng.normal(0, 8, (n, r))) / 256.0, jnp.float32)
    t = jnp.int32(11)
    contrib = ops.spike_deliver(spikes, src, w, d, steps_lo=lo, r_span=span)
    got = ops.apply_contrib(ring, contrib, t, lo)
    want = ring_buffer.deposit(ring, w * spikes[src], d, t)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_event_deliver_equals_dense():
    """Event-driven (compaction+scatter) delivery == dense delivery."""
    from repro.core import ring_buffer
    rng = np.random.default_rng(5)
    n_src, n_tgt, k_out, r = 200, 160, 12, 24
    spikes = jnp.asarray(rng.random(n_src) < 0.15)
    tgt = jnp.asarray(rng.integers(0, n_tgt, (n_src, k_out)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (n_src, k_out))) / 256.0,
                    jnp.float32)
    d = jnp.asarray(rng.integers(1, r - 1, (n_src, k_out)), jnp.int32)
    ring = jnp.zeros((n_tgt, r), jnp.float32)
    got = ops.event_deliver(ring, spikes, tgt, w, d, jnp.int32(7), s_max=128)
    # dense oracle: scatter every synapse of every fired source
    want = np.zeros((n_tgt, r), np.float32)
    sp = np.asarray(spikes)
    for s in range(n_src):
        if sp[s]:
            for kk in range(k_out):
                want[int(tgt[s, kk]), (7 + int(d[s, kk])) % r] += float(w[s, kk])
    assert np.allclose(np.asarray(got), want)


def test_event_deliver_ids_matches_event_deliver():
    """The id-packet entry point (the sparse wire format's receive side) ==
    compacting locally and delivering: same scatter core, same result."""
    rng = np.random.default_rng(11)
    n_src, n_tgt, k_out, r, s_max = 120, 96, 6, 16, 32
    spikes = jnp.asarray(rng.random(n_src) < 0.1)
    tgt = jnp.asarray(rng.integers(0, n_tgt, (n_src, k_out)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (n_src, k_out))) / 256.0,
                    jnp.float32)
    d = jnp.asarray(rng.integers(1, r - 1, (n_src, k_out)), jnp.int32)
    ring = jnp.zeros((n_tgt, r), jnp.float32)
    t = jnp.int32(3)
    want = ops.event_deliver(ring, spikes, tgt, w, d, t, s_max=s_max)
    # hand-built packet: fired ids in arbitrary order + sentinel padding
    fired = np.flatnonzero(np.asarray(spikes))
    rng.shuffle(fired)
    packet = np.full(s_max, n_src, np.int32)
    packet[: len(fired)] = fired
    got = ops.event_deliver_ids(ring, jnp.asarray(packet), tgt, w, d, t)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_event_deliver_ids_absorbs_padding():
    """Sentinel ids (>= N_src) and table padding rows (tgt=-1, w=0) must not
    touch any real target row."""
    n = 32
    tgt = jnp.full((n, 2), -1, jnp.int32)        # all padding rows
    w = jnp.zeros((n, 2), jnp.float32)
    d = jnp.ones((n, 2), jnp.int32)
    ring = jnp.zeros((n, 4), jnp.float32)
    ids = jnp.asarray([0, 5, n, n + 7], jnp.int32)  # 2 real, 2 sentinel
    out = ops.event_deliver_ids(ring, ids, tgt, w, d, jnp.int32(0))
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize("n,size,density", [
    (64, 8, 0.1), (1000, 16, 0.0), (1000, 16, 0.9),  # overflow case included
    (257, 4, 0.02), (8192, 128, 0.001),
])
def test_sized_nonzero_matches_jnp(n, size, density):
    """The searchsorted compaction == jnp.nonzero(size=, fill_value=) exactly,
    including which indices survive under overflow (first `size` by index).
    It replaces the sized-nonzero sort in every event path (~13x faster on
    CPU at N~6k: the sort was the hidden per-cycle cost of compaction)."""
    rng = np.random.default_rng(n + size)
    mask = jnp.asarray(rng.random(n) < density)
    want = jnp.nonzero(mask, size=size, fill_value=n)[0]
    got = ops.sized_nonzero(mask, size=size, fill=n)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_event_deliver_block_matches_per_cycle_ids():
    """The single-pass blocked receive == D sequential per-cycle id scatters
    (same packets, slots offset by the implicit step), bitwise."""
    rng = np.random.default_rng(7)
    n_src, n_tgt, k_out, r, s_max, d_win = 120, 96, 6, 20, 8, 10
    tgt = jnp.asarray(rng.integers(0, n_tgt, (n_src, k_out)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (n_src, k_out))) / 256.0,
                    jnp.float32)
    d = jnp.asarray(rng.integers(1, r - 1, (n_src, k_out)), jnp.int32)
    ids = np.full((d_win, s_max), n_src, np.int32)
    for s in range(d_win):
        k = rng.integers(0, s_max + 1)
        ids[s, :k] = rng.choice(n_src, k, replace=False)
    ids = jnp.asarray(ids)
    ring = jnp.zeros((n_tgt, r), jnp.float32)
    t0 = jnp.int32(13)
    want = ring
    for s in range(d_win):
        want = ops.event_deliver_ids(want, ids[s], tgt, w, d, t0 + s)
    got = ops.event_deliver_block(ring, ids, tgt, w, d, t0)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_superstep_kernels_match_unfused_window():
    """kernels/cycle.py: one fused window (D cycles of update + intra
    delivery on a VMEM-resident live buffer) == the unfused op chain."""
    from repro.core.neuron import counter_uniform

    rng = np.random.default_rng(3)
    a, n, k, d_win, lo, span = 3, 96, 8, 5, 1, 6
    w_width = d_win + lo + span - 1
    src = jnp.asarray(rng.integers(0, n, (a, n, k)), jnp.int32)
    w = jnp.asarray(np.round(rng.normal(0, 64, (a, n, k))) / 256.0, jnp.float32)
    delay = jnp.asarray(rng.integers(lo, lo + span, (a, n, k)), jnp.int32)
    alive = jnp.asarray(rng.random((a, n)) < 0.9)
    fut0 = jnp.asarray(
        np.round(rng.normal(0, 512, (a, n, w_width))) / 256.0, jnp.float32)
    gids = jnp.arange(a * n, dtype=jnp.int32).reshape(a, n)
    drive_p = jnp.full((a, n), 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(5, 4, (a, n)), jnp.float32)
    i_syn = jnp.asarray(rng.normal(100, 50, (a, n)), jnp.float32)
    refrac = jnp.asarray(rng.integers(0, 3, (a, n)), jnp.int32)
    kw = dict(LIF_KW, t_ref_steps=3)
    t0 = jnp.int32(0)

    got = ops.superstep_lif(
        v, i_syn, refrac, fut0, drive_p, gids, alive, src, w, delay, t0,
        d_win=d_win, steps_lo=lo, r_span=span, seed=11, w_ext=88.0, **kw)

    # unfused oracle: per-cycle lif_update kernel + dense masked deposit
    @jax.jit
    def oracle(v, i_syn, refrac, fut):
        spikes = []
        for s in range(d_win):
            u = counter_uniform(11, t0 + s, gids)
            i_in = fut[..., s] + (u < drive_p).astype(jnp.float32) * 88.0
            v, i_syn, refrac, spk = ops.lif_update(
                v, i_syn, refrac, i_in, alive, **kw)
            spikes.append(spk)
            vals = w * spk.astype(jnp.float32)[
                jnp.arange(a)[:, None, None], src]
            for j in range(span):
                col = jnp.sum(
                    jnp.where(delay - lo == j, vals, 0.0), axis=-1)
                fut = fut.at[..., s + lo + j].add(col)
        return v, i_syn, refrac, fut, jnp.stack(spikes, axis=1)

    want = oracle(v, i_syn, refrac, fut0)
    names = ("v", "i_syn", "refrac", "fut", "spikes")
    for name, g, ww in zip(names, got, want):
        g = np.asarray(g)
        ww = np.asarray(ww.astype(jnp.int8) if name == "spikes" else ww)
        assert np.array_equal(g, ww), name


def test_event_deliver_s_max_bound():
    """With fewer events than s_max the result is exact; the buffer bound is
    the static analogue of NEST's spike-register resizing."""
    n = 64
    spikes = jnp.zeros(n, bool).at[:5].set(True)
    tgt = jnp.zeros((n, 2), jnp.int32)
    w = jnp.ones((n, 2), jnp.float32)
    d = jnp.ones((n, 2), jnp.int32)
    ring = jnp.zeros((n, 4), jnp.float32)
    out = ops.event_deliver(ring, spikes, tgt, w, d, jnp.int32(0), s_max=8)
    assert float(out[0, 1]) == 10.0  # 5 events x 2 synapses x w=1


@pytest.mark.parametrize("b,s,h,hkv,dh,window,klen", [
    (2, 64, 4, 2, 16, 0, 64),
    (1, 128, 8, 4, 32, 17, 128),
    (2, 64, 4, 2, 16, 0, 40),      # partially valid keys (decode-like)
    (1, 64, 2, 2, 16, 5, 64),      # MHA + tight window
])
def test_flash_attention_matches_streaming_oracle(b, s, h, hkv, dh, window, klen):
    """Fused flash kernel (VMEM-resident tiles) == jnp streaming attention."""
    import repro.models.layers as L
    from repro.kernels.flash_attention import flash_attention_pallas

    rng = np.random.default_rng(h * s + window)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out_k = flash_attention_pallas(q, k, v, jnp.int32(window),
                                   jnp.int32(klen), bq=32, bk=32)
    out_r = L._streaming_attention(q, k, v, pos, pos, jnp.int32(klen), window)
    assert float(jnp.abs(out_k - out_r).max()) < 2e-5
