"""Serving-layer tests: bitwise batch==sequential, pairing, draining.

The server folds a batch of trials into one block-diagonal super-network
(`repro.launch.serve`); exactness means every served spike train must be
*bitwise* identical to the same trial run alone through the single-trial
engine. Pairing means a handle always resolves to its own request's
trajectory, no matter how many submitter threads race.
"""

import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.areas import mam_benchmark_spec
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation
from repro.core.neuron import LIFParams
from repro.launch.serve import (
    ServerClosed,
    SimServer,
    TrialRequest,
    serve_simulation,
)


def _spec():
    return mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)


def _cfg():
    # Lowered threshold puts the tiny spec in a seed-sensitive spiking
    # regime within a window or two (the default calibration fires after
    # ~50 windows -- too slow for a unit test, and a silent network would
    # make the bitwise assertions vacuous).
    return EngineConfig(delivery_backend="event",
                        lif=LIFParams(v_th_mv=2.0))


def _sequential_reference(spec, cfg, request: TrialRequest) -> np.ndarray:
    """The trial run alone, window by window, on the single-trial engine."""
    eng = make_simulation(spec, cfg)
    st = eng.init(seed=request.seed, stim=request.stim)
    blocks = []
    for _ in range(request.windows):
        st, blk = eng.window(st)
        blocks.append(np.asarray(blk))
    return np.concatenate(blocks, axis=0)


@pytest.fixture(scope="module")
def server():
    with SimServer(_spec(), _cfg(), max_batch=4, max_windows=8) as srv:
        yield srv


def test_batch_bitwise_identical_to_sequential(server):
    """A mixed batch (seeds, stim, durations) == its N sequential runs."""
    spec, cfg = _spec(), _cfg()
    requests = [
        TrialRequest(seed=101, stim=1.0, windows=3),
        TrialRequest(seed=202, stim=0.9, windows=3),
        TrialRequest(seed=303, stim=1.1, windows=2),
        TrialRequest(seed=404, stim=1.0, windows=4),
        TrialRequest(seed=505, stim=1.2, windows=1),  # second dispatch
    ]
    handles = [server.submit(r) for r in requests]
    results = [h.result(timeout=300) for h in handles]
    D = server.delay_ratio
    A = server.spec.n_areas
    for r in results:
        assert r.overflow == 0, "overflow would break the exactness claim"
        ref = _sequential_reference(spec, cfg, r.request)
        assert r.spikes.shape == (r.request.windows * D, A, ref.shape[2])
        assert np.array_equal(r.spikes, ref), (
            f"seed={r.request.seed}: folded batch diverged from its "
            "sequential reference")
    # The assertions above must not be vacuous: trials spike, and
    # different seeds produce different trains.
    assert results[0].spikes.any() and results[1].spikes.any()
    assert not np.array_equal(results[0].spikes, results[1].spikes[: 3 * D])


def test_streaming_blocks_match_final_result(server):
    """on_block rows concatenate to exactly the final spike train."""
    streamed = []
    req = TrialRequest(seed=777, windows=3)
    h = server.submit(req, on_block=lambda w, rows: streamed.append(rows))
    res = h.result(timeout=300)
    assert len(streamed) == req.windows
    assert np.array_equal(np.concatenate(streamed, axis=0), res.spikes)


def test_concurrent_submitters_preserve_pairing(server):
    """>=16 racing submitter threads each get their own seed's trajectory."""
    n = 16
    seeds = [1000 + 7 * i for i in range(n)]
    out: dict[int, np.ndarray] = {}
    errs: list[BaseException] = []
    barrier = threading.Barrier(n)

    def tenant(seed):
        try:
            barrier.wait()
            h = server.submit(TrialRequest(seed=seed, windows=2))
            out[seed] = h.result(timeout=300).spikes
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=tenant, args=(s,)) for s in seeds]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs, errs
    assert len(out) == n
    spec, cfg = _spec(), _cfg()
    refs = {s: _sequential_reference(spec, cfg, TrialRequest(seed=s, windows=2))
            for s in seeds}
    for s in seeds:
        assert np.array_equal(out[s], refs[s]), (
            f"tenant seed={s} received another trial's spike train")
    # Distinct seeds must yield distinct trains (pairing is falsifiable).
    assert not np.array_equal(out[seeds[0]], out[seeds[1]])


def test_sigterm_drains_inflight_and_rejects_new(tmp_path):
    """SIGTERM mid-queue: accepted trials finish, new submits are refused."""
    with SimServer(_spec(), _cfg(), max_batch=2, max_windows=4,
                   checkpoint_dir=str(tmp_path / "journal")) as srv:
        srv.install_sigterm()
        handles = [srv.submit(TrialRequest(seed=10 + i, windows=2))
                   for i in range(5)]
        os.kill(os.getpid(), signal.SIGTERM)
        # The handler runs in the main thread at the next bytecode check;
        # give it a beat, then the server must refuse new work...
        deadline = time.time() + 10
        while not srv._closed and time.time() < deadline:
            time.sleep(0.01)
        with pytest.raises(ServerClosed):
            srv.submit(TrialRequest(seed=999))
        # ...while every accepted trial still drains to a full result.
        for h in handles:
            res = h.result(timeout=300)
            assert res.spikes.shape[0] == h.request.windows * srv.delay_ratio
    signal.signal(signal.SIGTERM, signal.SIG_DFL)


def test_nondraining_shutdown_journals_unserved(tmp_path):
    """shutdown(drain=False) journals queued trials for resubmission."""
    journal = str(tmp_path / "journal")
    srv = SimServer(_spec(), _cfg(), max_batch=2, max_windows=4,
                    checkpoint_dir=journal)
    # Never started: everything submitted stays queued, then is abandoned.
    h1 = srv.submit(TrialRequest(seed=5, stim=1.1, windows=3))
    h2 = srv.submit(TrialRequest(seed=6, windows=1))
    srv.shutdown(drain=False)
    for h in (h1, h2):
        with pytest.raises(ServerClosed):
            h.result(timeout=10)
    restored = SimServer.restore_unserved(journal)
    assert restored == [h1.request, h2.request]


def test_submit_after_close_raises():
    srv = SimServer(_spec(), _cfg(), max_batch=1, max_windows=2)
    srv.close()
    with pytest.raises(ServerClosed):
        srv.submit(TrialRequest(seed=1))


def test_oversized_duration_rejected(server):
    with pytest.raises(ValueError, match="max_windows"):
        server.submit(TrialRequest(seed=1, windows=512))


def test_serve_simulation_entry_point():
    srv = serve_simulation(_spec(), _cfg(), max_batch=1, max_windows=2)
    try:
        res = srv.submit(TrialRequest(seed=3, windows=1)).result(timeout=300)
        assert res.spikes.any() or res.spikes.shape[0] == srv.delay_ratio
        stats = srv.stats()
        assert stats["trials"] == 1 and stats["trials_per_s"] > 0
        assert stats["p50_ms"] is not None and stats["p99_ms"] is not None
    finally:
        srv.shutdown()
