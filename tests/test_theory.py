"""§2.2 / §2.3 theory validation against the paper's quoted numbers."""

import math

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import delivery_model as dm
from repro.core import sync_model as sm


# ------------------------------------------------------------------ §2.2


def test_norm_ppf_cdf_roundtrip():
    for p in (0.001, 0.035, 0.5, 0.9, 0.999):
        assert abs(sm.norm_cdf(sm.norm_ppf(p)) - p) < 1e-7


def test_blom_xi_monotone_and_magnitude():
    xis = [sm.blom_xi(m) for m in (2, 16, 32, 64, 128)]
    assert all(b > a for a, b in zip(xis, xis[1:]))
    assert 2.4 < sm.blom_xi(128) < 2.8  # ~2.6 sigma for M=128


def test_sync_ratio_eq11():
    assert sm.sync_time_ratio(10) == pytest.approx(1 / math.sqrt(10))
    c = sm.expected_wall_conventional(1000, 128, 1.6e-3, 0.05e-3)
    s = sm.expected_wall_structure_aware(1000, 10, 128, 1.6e-3, 0.05e-3)
    sync_c = c - 1000 * 1.6e-3
    sync_s = s - 1000 * 1.6e-3
    assert sync_s / sync_c == pytest.approx(1 / math.sqrt(10))


def test_eq12_quantile_band():
    """M=128: the upper 3.5% of cycle times hold ~99% of per-cycle maxima."""
    tail = sm.tail_for_max_coverage(0.99, 128)
    assert 0.03 < tail < 0.04
    assert sm.max_tail_probability(0.035, 128) == pytest.approx(0.99, abs=0.01)


def test_monte_carlo_iid_matches_eq11():
    model = sm.CycleTimeModel(mu=1.6e-3, sigma=0.08e-3)
    conv, struc = sm.simulate_schedules(model, m=128, s=20000, d=10, seed=0)
    assert struc.sync / conv.sync == pytest.approx(1 / math.sqrt(10), rel=0.12)
    assert struc.cv_lumped / conv.cv_lumped == pytest.approx(
        1 / math.sqrt(10), rel=0.12)
    assert struc.n_syncs == conv.n_syncs // 10


def test_monte_carlo_serial_correlation_weakens_gain():
    """The paper's §2.4.1 observation: persistent per-process slow phases
    (Fig. 12) violate CLT independence and cap the CV-ratio well above
    1/sqrt(D) (measured 0.71 vs predicted 0.32)."""
    iid = sm.CycleTimeModel(mu=1.6e-3, sigma=0.065e-3)
    corr = sm.CycleTimeModel(mu=1.6e-3, sigma=0.065e-3, rho=0.6,
                             minor_mode_shift=0.3e-3, minor_mode_weight=0.02,
                             minor_mode_dwell=5.0)
    c0, s0 = sm.simulate_schedules(iid, 128, 20000, 10, seed=1)
    c1, s1 = sm.simulate_schedules(corr, 128, 20000, 10, seed=1)
    r_iid = s0.cv_lumped / c0.cv_lumped
    r_corr = s1.cv_lumped / c1.cv_lumped
    assert r_corr > r_iid * 1.5, (r_iid, r_corr)
    assert 0.45 < r_corr < 0.9  # paper measures 0.71


# ------------------------------------------------------------------ §2.3


@pytest.mark.parametrize("m,t_m,expected_pct", [
    (32, 48, 12), (32, 128, 29), (128, 48, 37), (128, 128, 43),
])
def test_fig6b_reductions_match_paper(m, t_m, expected_pct):
    _, _, red = dm.fig6b_reduction(m, t_m)
    assert abs(100 * red - expected_pct) < 1.6, (m, t_m, red)


def test_delivery_model_advantage_grows_with_m():
    reds = [dm.fig6b_reduction(m, 48)[2] for m in (16, 32, 64, 128)]
    assert all(b > a for a, b in zip(reds, reds[1:]))


def test_f_irr_bounds():
    for m, t_m in ((16, 48), (128, 128)):
        f_c = dm.f_irr_conventional(m * 130_000, 6000, m, t_m)
        f_s = dm.f_irr_structure_aware(m * 130_000, 6000, m, t_m)
        assert 0 < f_s <= f_c <= 1.0


# ----------------------------------------------------------- cost model


def test_fig7a_weak_scaling_reproduction():
    """Calibrated model reproduces Fig. 7a within ~20%: conv 9.4 -> 22.7,
    struct 8.5 -> 15.7; struct strictly faster, gap grows with M."""
    wl = cm.WorkloadModel()
    conv16 = cm.simulate_rtf(wl, cm.SUPERMUC, 16, "conventional", seed=1).total
    conv128 = cm.simulate_rtf(wl, cm.SUPERMUC, 128, "conventional", seed=1).total
    str16 = cm.simulate_rtf(wl, cm.SUPERMUC, 16, "structure_aware", seed=1).total
    str128 = cm.simulate_rtf(wl, cm.SUPERMUC, 128, "structure_aware", seed=1).total
    assert conv16 == pytest.approx(9.4, rel=0.25)
    assert conv128 == pytest.approx(22.7, rel=0.25)
    assert str16 == pytest.approx(8.5, rel=0.25)
    assert str128 == pytest.approx(15.7, rel=0.25)
    assert str128 < conv128 and str16 <= conv16 * 1.02
    assert (conv128 - str128) > (conv16 - str16)


def test_fig7a_phase_reductions_at_m128():
    wl = cm.WorkloadModel()
    c = cm.simulate_rtf(wl, cm.SUPERMUC, 128, "conventional", seed=1)
    s = cm.simulate_rtf(wl, cm.SUPERMUC, 128, "structure_aware", seed=1)
    dlv = 1 - s.deliver / c.deliver
    comm = 1 - s.communicate / c.communicate
    sync = 1 - s.synchronize / c.synchronize
    assert 0.15 < dlv < 0.45      # paper: 25 %
    assert 0.6 < comm < 0.97      # paper: 76 %
    assert 0.25 < sync < 0.65     # paper: 48 %


def test_fig8a_area_size_heterogeneity_increases_sync():
    wl0 = cm.WorkloadModel(area_size_cv=0.0)
    wl2 = cm.WorkloadModel(area_size_cv=0.2)
    s0 = cm.simulate_rtf(wl0, cm.SUPERMUC, 64, "structure_aware", seed=2)
    s2 = cm.simulate_rtf(wl2, cm.SUPERMUC, 64, "structure_aware", seed=2)
    assert s2.synchronize > s0.synchronize * 1.5
    assert s2.total > s0.total


def test_fig8c_diminishing_returns_in_d():
    """Communication gain saturates for D > 10 (paper Fig. 8c / eq. 11)."""
    totals = {}
    for d in (1, 5, 10, 20):
        wl = cm.WorkloadModel(d=d)
        totals[d] = cm.simulate_rtf(wl, cm.SUPERMUC, 64,
                                    "structure_aware", seed=3).total
    assert totals[5] < totals[1]
    gain_1_5 = totals[1] - totals[5]
    gain_5_10 = totals[5] - totals[10]
    gain_10_20 = totals[10] - totals[20]
    assert gain_5_10 < gain_1_5, "gain must shrink past D=5"
    assert gain_10_20 < 0.5 * gain_1_5, "gain must be marginal past D=10"


def test_fig9_jureca_vs_supermuc():
    """JURECA (128 threads) is faster and less imbalance-sensitive (§2.4.3)."""
    wl = cm.WorkloadModel(area_size_cv=0.2, rate_cv=0.25, neuron_model="lif")
    sm_ = cm.simulate_rtf(wl, cm.SUPERMUC, 32, "structure_aware", seed=4)
    ju = cm.simulate_rtf(wl, cm.JURECA, 32, "structure_aware", seed=4)
    assert ju.total < sm_.total
    assert ju.deliver < sm_.deliver


def test_collective_model_sublinear():
    """Fig. 4: one D-sized message beats D unit messages (latency regime)."""
    mpi = cm.SUPERMUC_MPI
    b = 317 * 128  # buffer/rank x ranks at M=128
    ten_small = 10 * mpi.call_time_s(128, b)
    one_big = mpi.call_time_s(128, 10 * b)
    assert one_big < 0.3 * ten_small  # paper predicts 86% reduction
