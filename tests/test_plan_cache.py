"""Keyed de-duplication of the sharded-build planning pass.

`sharded_build_plan` is deterministic in (spec, seed, layout) but costs a
full streaming sweep; `cached_sharded_build_plan` must compute it once per
key (memo), publish it atomically to a shared cache_dir, and let other
processes read the file instead of repeating the sweep.
"""

import json
import os

import pytest

from repro.core import connectivity as conn
from repro.core.areas import mam_benchmark_spec


def _spec(**kw):
    kw.setdefault("n_areas", 4)
    kw.setdefault("n_per_area", 64)
    kw.setdefault("k_intra", 8)
    kw.setdefault("k_inter", 12)
    return mam_benchmark_spec(**kw)


@pytest.fixture(autouse=True)
def _fresh_memo():
    conn._PLAN_MEMO.clear()
    yield
    conn._PLAN_MEMO.clear()


def test_cached_plan_equals_direct_plan(tmp_path):
    spec = _spec()
    direct = conn.sharded_build_plan(spec, 12, 2, subgroup=2)
    cached = conn.cached_sharded_build_plan(
        spec, 12, 2, subgroup=2, cache_dir=str(tmp_path))
    assert cached == direct
    # The publish is JSON and round-trips the plan exactly.
    files = [f for f in os.listdir(tmp_path) if f.startswith("plan_")]
    assert len(files) == 1
    with open(tmp_path / files[0]) as f:
        assert conn._plan_from_json(json.load(f)) == direct


def test_memo_skips_recompute(tmp_path, monkeypatch):
    spec = _spec()
    calls = []
    real = conn.sharded_build_plan
    monkeypatch.setattr(
        conn, "sharded_build_plan",
        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    kw = dict(cache_dir=str(tmp_path))
    p1 = conn.cached_sharded_build_plan(spec, 12, 2, **kw)
    p2 = conn.cached_sharded_build_plan(spec, 12, 2, **kw)
    assert p1 == p2 and len(calls) == 1


def test_disk_cache_shared_across_processes(tmp_path, monkeypatch):
    """A second 'process' (fresh memo) must read the file, not recompute."""
    spec = _spec()
    p1 = conn.cached_sharded_build_plan(spec, 12, 2, cache_dir=str(tmp_path))
    conn._PLAN_MEMO.clear()  # simulate another process's interpreter
    monkeypatch.setattr(
        conn, "sharded_build_plan",
        lambda *a, **kw: pytest.fail("sweep repeated despite cache file"))
    p2 = conn.cached_sharded_build_plan(spec, 12, 2, cache_dir=str(tmp_path))
    assert p2 == p1


def test_key_separates_layouts():
    spec = _spec()
    k = conn.plan_cache_key
    base = k(spec, 12, 2)
    assert base == k(spec, 12, 2)  # deterministic
    assert base != k(spec, 13, 2)
    assert base != k(spec, 12, 4)
    assert base != k(spec, 12, 2, subgroup=2)
    assert base != k(spec, 12, 2, size_multiple=8)
    assert base != k(_spec(n_per_area=96), 12, 2)


def test_nonzero_process_times_out_without_publisher(tmp_path, monkeypatch):
    spec = _spec()
    monkeypatch.setattr(conn.jax, "process_count", lambda: 2)
    with pytest.raises(TimeoutError, match="REPRO_PLAN_CACHE"):
        conn.cached_sharded_build_plan(
            spec, 12, 2, cache_dir=str(tmp_path), process_index=1,
            wait_s=0.5)


def test_env_var_names_the_cache_dir(tmp_path, monkeypatch):
    spec = _spec()
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path))
    conn.cached_sharded_build_plan(spec, 12, 2)
    assert any(f.startswith("plan_") for f in os.listdir(tmp_path))
