"""End-to-end behaviour tests for the paper's system.

The central invariant: the conventional and structure-aware schedules are
*exactly* equivalent -- bit-identical spike trains and ring buffers -- because
inter-area delays >= D cycles make the lumped exchange causal (paper §2.1),
and delivery weights live on an exact 1/256 grid.
"""

import numpy as np
import pytest

from repro.core.areas import MAM_AREA_NAMES, mam_benchmark_spec, mam_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation


@pytest.fixture(scope="module")
def small_spec():
    return mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)


@pytest.fixture(scope="module")
def small_net(small_spec):
    return build_network(small_spec, seed=12)


@pytest.mark.parametrize("neuron_model", ["ignore_and_fire", "lif"])
def test_schedule_equivalence_bit_exact(small_spec, small_net, neuron_model):
    """Paper §2.1: the structure-aware schedule changes *when* spikes travel,
    never *what* arrives. 40 windows, bitwise."""
    conv = make_simulation(small_spec, EngineConfig(neuron_model=neuron_model,
                                    schedule="conventional"), net=small_net)
    struc = make_simulation(small_spec, EngineConfig(neuron_model=neuron_model,
                                     schedule="structure_aware"), net=small_net)
    sc, ss = conv.init(), struc.init()
    for w in range(40):
        sc, blk_c = conv.window(sc)
        ss, blk_s = struc.window(ss)
        assert np.array_equal(np.asarray(blk_c), np.asarray(blk_s)), f"window {w}"
        assert np.array_equal(np.asarray(sc.ring), np.asarray(ss.ring)), f"ring {w}"
    assert int(sc.spike_count.sum()) > 0, "network must actually spike"


def test_deposit_variants_equivalent(small_spec, small_net):
    """One-hot-einsum and scatter-add delivery are interchangeable."""
    a = make_simulation(small_spec, EngineConfig(schedule="structure_aware",
                                 delivery_backend="onehot"), net=small_net)
    b = make_simulation(small_spec, EngineConfig(schedule="structure_aware",
                                 delivery_backend="scatter"), net=small_net)
    sa, sb = a.init(), b.init()
    for _ in range(10):
        sa, blk_a = a.window(sa)
        sb, blk_b = b.window(sb)
        assert np.array_equal(np.asarray(blk_a), np.asarray(blk_b))


def test_legacy_delivery_knobs_removed():
    """The deprecated pre-dispatch knobs (deposit_onehot / delivery,
    deprecated in the exchange-layer PR, removed in the sharded-table PR)
    are gone: delivery_backend is the single dispatch point."""
    with pytest.raises(TypeError):
        EngineConfig(deposit_onehot=True)
    with pytest.raises(TypeError):
        EngineConfig(delivery="event")
    assert EngineConfig().backend == "onehot"
    assert EngineConfig(delivery_backend="event").backend == "event"


def test_lif_ground_state_rate(small_spec, small_net):
    """The calibrated drive puts the LIF network near the MAM ground state
    (~2.5 spikes/s; we accept a generous band at this tiny scale)."""
    eng = make_simulation(small_spec, EngineConfig(neuron_model="lif"), net=small_net)
    st = eng.init()
    st, _ = eng.run(st, 500)  # 500 ms
    t_s = float(st.t) * small_spec.dt_ms / 1000.0
    rate = float(st.spike_count.sum()) / (small_spec.n_total * t_s)
    assert 0.5 < rate < 10.0, f"ground-state rate {rate:.2f} Hz out of band"


def test_ignore_and_fire_exact_rate():
    """Ignore-and-fire emits at exactly the configured rate (paper §4.2)."""
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=10.0)
    net = build_network(spec, seed=12)
    eng = make_simulation(spec, EngineConfig(neuron_model="ignore_and_fire"), net=net)
    st = eng.init()
    st, _ = eng.run(st, 1000)  # 1 s
    rate = float(st.spike_count.sum()) / spec.n_total
    assert abs(rate - 10.0) < 0.11, rate


def test_heterogeneous_area_sizes_ghost_padding():
    """Heterogeneous areas pad to N_max with frozen ghosts that never fire."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=40, k_intra=4, k_inter=4,
                              area_size_cv=0.3, seed=7)
    net = build_network(spec, seed=12)
    sizes = spec.area_sizes()
    assert len(set(sizes.tolist())) > 1, "sizes should differ"
    eng = make_simulation(spec, EngineConfig(neuron_model="ignore_and_fire"), net=net)
    st = eng.init()
    st, _ = eng.run(st, 100)
    counts = np.asarray(st.spike_count)
    alive = np.asarray(net.alive)
    assert counts[~alive].sum() == 0, "ghost neurons must stay silent"
    assert counts[alive].sum() > 0


def test_mam_spec_properties():
    spec = mam_spec(scale=0.001)
    assert spec.n_areas == 32
    assert spec.delay_ratio == 10
    sizes = spec.area_sizes().astype(float)
    cv = sizes.std() / sizes.mean()
    assert 0.1 < cv < 0.3, f"MAM area-size CV {cv:.2f} (paper ~0.2)"
    rates = spec.area_rates()
    v2 = rates[list(MAM_AREA_NAMES).index("V2")]
    assert v2 > rates.mean() * 1.3, "V2 must be among the hottest areas"


def test_delay_tiers_respected(small_net, small_spec):
    d_intra = np.asarray(small_net.delay_intra)
    d_inter = np.asarray(small_net.delay_inter)
    assert d_intra.min() >= 1
    assert d_intra.max() <= small_spec.steps_intra_max
    assert d_inter.min() >= small_spec.delay_ratio, \
        "inter-area delays must respect the d_min_inter cutoff (eq. 1)"
    assert d_inter.max() < small_net.ring_len


@pytest.mark.parametrize("backend", ["onehot", "scatter", "pallas", "event"])
@pytest.mark.parametrize("schedule", ["conventional", "structure_aware"])
def test_delivery_backends_bit_identical(backend, schedule):
    """Tentpole invariant: every delivery backend (one-hot einsum, scatter-add,
    delay-resolved Pallas kernel, event-driven compaction) produces spike
    trains and ring buffers bit-identical to the reference -- weights on the
    1/256 grid make ring accumulation order-exact, so the backends may
    reorder sums freely."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=91856, outgoing=True)
    ref = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="conventional"), net=net)
    eng = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule=schedule,
        delivery_backend=backend, s_max_floor=64), net=net)
    s0, st = ref.init(), eng.init()
    for w in range(12):
        s0, blk_ref = ref.window(s0)
        st, blk = eng.window(st)
        assert np.array_equal(np.asarray(blk), np.asarray(blk_ref)), (backend, w)
        assert np.array_equal(np.asarray(s0.ring), np.asarray(st.ring)), (backend, w)
    assert int(st.overflow) == 0, "event packets must not drop spikes here"
    assert int(st.spike_count.sum()) > 0


@pytest.mark.parametrize("backend", ["pallas", "event"])
def test_delivery_backends_bit_identical_lif(backend):
    """The two kernel-backed backends also reproduce the LIF reference
    (float dynamics + Poisson drive) past the initial transient."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)
    net = build_network(spec, seed=12, outgoing=True)
    ref = make_simulation(spec, EngineConfig(
        neuron_model="lif", schedule="conventional"), net=net)
    eng = make_simulation(spec, EngineConfig(
        neuron_model="lif", schedule="structure_aware",
        delivery_backend=backend, s_max_floor=192), net=net)
    s0, st = ref.init(), eng.init()
    for w in range(30):
        s0, blk_ref = ref.window(s0)
        st, blk = eng.window(st)
        assert np.array_equal(np.asarray(blk), np.asarray(blk_ref)), (backend, w)
    assert int(st.overflow) == 0
    assert int(st.spike_count.sum()) > 0, "LIF must spike within 30 ms"


@pytest.mark.parametrize("backend", ["onehot", "scatter", "pallas", "event"])
def test_superstep_matches_legacy_window_bitwise(backend):
    """Tentpole: the fused D-cycle superstep (blocked ring read/clear, live
    window buffer, single-pass lumped inter delivery) is bit-identical to
    the legacy per-cycle window -- spike blocks AND rings -- for every
    backend, in both the scanned and the fully unrolled variant."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=91856, outgoing=True)
    legacy = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery_backend=backend, s_max_floor=64, superstep=False), net=net)
    fused = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery_backend=backend, s_max_floor=64), net=net)
    unroll = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery_backend=backend, s_max_floor=64, superstep_unroll=True), net=net)
    sl, sf, su = legacy.init(), fused.init(), unroll.init()
    for w in range(12):
        sl, bl = legacy.window(sl)
        sf, bf = fused.window(sf)
        su, bu = unroll.window(su)
        assert np.array_equal(np.asarray(bl), np.asarray(bf)), (backend, w)
        assert np.array_equal(np.asarray(bl), np.asarray(bu)), (backend, w)
        assert np.array_equal(np.asarray(sl.ring), np.asarray(sf.ring)), (backend, w)
        assert np.array_equal(np.asarray(sl.ring), np.asarray(su.ring)), (backend, w)
    assert int(sl.spike_count.sum()) > 0


@pytest.mark.parametrize("neuron_model", ["ignore_and_fire", "lif"])
def test_fused_superstep_kernel_matches_reference(neuron_model):
    """The fused Pallas superstep kernel (kernels/cycle.py: membrane state and
    live ring slots VMEM-resident across the D unrolled cycles) reproduces
    the conventional per-cycle reference bitwise for both neuron models."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=91856, outgoing=True)
    ref = make_simulation(spec, EngineConfig(
        neuron_model=neuron_model, schedule="conventional"), net=net)
    eng = make_simulation(spec, EngineConfig(
        neuron_model=neuron_model, schedule="structure_aware",
        delivery_backend="event", s_max_floor=64, superstep_kernel=True), net=net)
    s0, st = ref.init(), eng.init()
    for w in range(12):
        s0, blk_ref = ref.window(s0)
        st, blk = eng.window(st)
        assert np.array_equal(np.asarray(blk), np.asarray(blk_ref)), w
        assert np.array_equal(np.asarray(s0.ring), np.asarray(st.ring)), w
    assert int(st.overflow) == 0
    assert int(st.spike_count.sum()) > 0


def test_superstep_kernel_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(schedule="conventional", superstep_kernel=True)
    with pytest.raises(ValueError):
        EngineConfig(superstep=False, superstep_kernel=True)
    with pytest.raises(ValueError):
        EngineConfig(schedule="conventional", superstep=True)
    # superstep=None/False with the conventional schedule stays valid.
    assert not EngineConfig(schedule="conventional").use_superstep
    assert not EngineConfig(schedule="conventional",
                            superstep=False).use_superstep


def test_ring_len_phase_aligned():
    """The ring length is padded to a multiple of D so window starts land on
    slot-block boundaries (the blocked read/clear's alignment contract)."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)
    assert spec.ring_len % spec.delay_ratio == 0
    assert spec.ring_len >= max(spec.steps_intra_max, spec.steps_inter_max) + 1
    net = build_network(spec, seed=12)
    assert net.ring_len % net.delay_ratio == 0


def test_overflow_identical_across_schedules_and_blocked_path():
    """Overflow accounting invariant: a forced-overflow run (tiny packet
    bound, synchronized firing) reports a *nonzero* spill count identical
    between the conventional schedule, the legacy per-cycle structure-aware
    window, and the blocked (superstep) delivery -- per-cycle packing is
    preserved inside the blocked packet, so the same spikes drop."""
    spec = mam_benchmark_spec(n_areas=2, n_per_area=64, k_intra=4, k_inter=4,
                              rate_hz=2000.0)  # interval 5: massed firing
    net = build_network(spec, seed=12, outgoing=True)
    counts = {}
    for name, kw in [
        ("conventional", dict(schedule="conventional")),
        ("legacy", dict(schedule="structure_aware", superstep=False)),
        ("superstep", dict(schedule="structure_aware")),
        ("superstep_unroll", dict(schedule="structure_aware",
                                  superstep_unroll=True)),
    ]:
        eng = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", delivery_backend="event",
            s_max_headroom=0.0, s_max_floor=1, **kw), net=net)
        st = eng.init()
        for _ in range(5):
            st, _ = eng.window(st)
        counts[name] = int(st.overflow)
        assert int(st.spike_count.sum()) > 0
    assert counts["conventional"] > 0
    assert len(set(counts.values())) == 1, counts


def test_deliver_inter_block_equals_per_cycle():
    """delivery.deliver_inter_block(block) == D sequential deliver_inter
    calls, bitwise, for every backend (the single-pass lumped exchange)."""
    import jax.numpy as jnp

    from repro.core import delivery

    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=12, outgoing=True)
    A, n_pad = net.alive.shape
    D = net.delay_ratio
    rng = np.random.default_rng(0)
    block = jnp.asarray(rng.random((D, A * n_pad)) < 0.02, jnp.float32)
    ring0 = jnp.asarray(
        np.round(rng.normal(0, 8, (A, n_pad, net.ring_len))) / 256.0,
        jnp.float32)
    t0 = jnp.int32(3 * D)
    for backend in ["onehot", "scatter", "pallas", "event"]:
        want = ring0
        for s in range(D):
            want = delivery.deliver_inter(
                want, block[s], net, t0 + s, backend=backend, s_max=256)
        got = delivery.deliver_inter_block(
            ring0, block, net, t0, backend=backend, s_max=256)
        assert np.array_equal(np.asarray(got), np.asarray(want)), backend
    # The memory guard (per-cycle deposits inside the block beyond the
    # one-hot fold limit) must be bit-identical to the folded form.
    import repro.core.delivery as delivery_mod
    limit = delivery_mod.ONEHOT_FOLD_LIMIT
    try:
        delivery_mod.ONEHOT_FOLD_LIMIT = 0
        got = delivery.deliver_inter_block(ring0, block, net, t0,
                                           backend="onehot")
    finally:
        delivery_mod.ONEHOT_FOLD_LIMIT = limit
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_event_overflow_counter_reports_drops():
    """An undersized event packet drops spikes *visibly*: SimState.overflow
    counts them (the static analogue of NEST's spike-register resize)."""
    spec = mam_benchmark_spec(n_areas=2, n_per_area=64, k_intra=4, k_inter=4,
                              rate_hz=2000.0)
    net = build_network(spec, seed=12, outgoing=True)
    eng = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", delivery_backend="event",
        s_max_headroom=0.0, s_max_floor=1), net=net)
    st = eng.init()
    for _ in range(5):
        st, _ = eng.window(st)
    assert int(st.spike_count.sum()) > 0
    assert int(st.overflow) > 0


def test_fused_lif_update_matches_jnp_chain():
    """The fused Pallas LIF kernel is a drop-in for the jnp update chain:
    bit-identical trajectories under every backend."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)
    net = build_network(spec, seed=12)
    plain = make_simulation(spec, EngineConfig(
        neuron_model="lif", delivery_backend="scatter", fused_update=False), net=net)
    fused = make_simulation(spec, EngineConfig(
        neuron_model="lif", delivery_backend="scatter", fused_update=True), net=net)
    sp, sf = plain.init(), fused.init()
    for w in range(30):
        sp, blk_p = plain.window(sp)
        sf, blk_f = fused.window(sf)
        assert np.array_equal(np.asarray(blk_p), np.asarray(blk_f)), w
    assert int(sp.spike_count.sum()) > 0, "LIF must spike within 30 ms"


def test_network_delay_window_metadata():
    """build_network records the tight per-pathway delay windows that the
    delay-resolved (Pallas) backend iterates over."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)
    net = build_network(spec, seed=12)
    d_i, d_e = np.asarray(net.delay_intra), np.asarray(net.delay_inter)
    assert net.steps_lo_intra == d_i.min()
    assert net.steps_lo_intra + net.r_span_intra - 1 == d_i.max()
    assert net.steps_lo_inter == d_e.min()
    assert net.steps_lo_inter + net.r_span_inter - 1 == d_e.max()
    # the windows are what keeps the kernel narrow: both well under the ring
    assert net.r_span_intra < net.ring_len
    assert net.steps_lo_inter >= net.delay_ratio


def test_event_delivery_equals_dense_engine():
    """Beyond-paper optimization: event-driven delivery (compact fired
    neurons, scatter outgoing synapses) is bit-identical to the dense
    gather-matvec path -- weights live on the exact 1/256 grid."""
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=91856, outgoing=True)
    dense = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery_backend="onehot"), net=net)
    event = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery_backend="event"), net=net)
    sd, se = dense.init(), event.init()
    for w in range(25):
        sd, bd = dense.window(sd)
        se, be = event.window(se)
        assert np.array_equal(np.asarray(bd), np.asarray(be)), w
        assert np.array_equal(np.asarray(sd.ring), np.asarray(se.ring)), w
    assert int(sd.spike_count.sum()) > 100
