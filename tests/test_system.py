"""End-to-end behaviour tests for the paper's system.

The central invariant: the conventional and structure-aware schedules are
*exactly* equivalent -- bit-identical spike trains and ring buffers -- because
inter-area delays >= D cycles make the lumped exchange causal (paper §2.1),
and delivery weights live on an exact 1/256 grid.
"""

import numpy as np
import pytest

from repro.core.areas import MAM_AREA_NAMES, mam_benchmark_spec, mam_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig, make_engine


@pytest.fixture(scope="module")
def small_spec():
    return mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)


@pytest.fixture(scope="module")
def small_net(small_spec):
    return build_network(small_spec, seed=12)


@pytest.mark.parametrize("neuron_model", ["ignore_and_fire", "lif"])
def test_schedule_equivalence_bit_exact(small_spec, small_net, neuron_model):
    """Paper §2.1: the structure-aware schedule changes *when* spikes travel,
    never *what* arrives. 40 windows, bitwise."""
    conv = make_engine(small_net, small_spec,
                       EngineConfig(neuron_model=neuron_model,
                                    schedule="conventional"))
    struc = make_engine(small_net, small_spec,
                        EngineConfig(neuron_model=neuron_model,
                                     schedule="structure_aware"))
    sc, ss = conv.init(), struc.init()
    for w in range(40):
        sc, blk_c = conv.window(sc)
        ss, blk_s = struc.window(ss)
        assert np.array_equal(np.asarray(blk_c), np.asarray(blk_s)), f"window {w}"
        assert np.array_equal(np.asarray(sc.ring), np.asarray(ss.ring)), f"ring {w}"
    assert int(sc.spike_count.sum()) > 0, "network must actually spike"


def test_deposit_variants_equivalent(small_spec, small_net):
    """One-hot-einsum and scatter-add delivery are interchangeable."""
    a = make_engine(small_net, small_spec,
                    EngineConfig(schedule="structure_aware", deposit_onehot=True))
    b = make_engine(small_net, small_spec,
                    EngineConfig(schedule="structure_aware", deposit_onehot=False))
    sa, sb = a.init(), b.init()
    for _ in range(10):
        sa, blk_a = a.window(sa)
        sb, blk_b = b.window(sb)
        assert np.array_equal(np.asarray(blk_a), np.asarray(blk_b))


def test_lif_ground_state_rate(small_spec, small_net):
    """The calibrated drive puts the LIF network near the MAM ground state
    (~2.5 spikes/s; we accept a generous band at this tiny scale)."""
    eng = make_engine(small_net, small_spec, EngineConfig(neuron_model="lif"))
    st = eng.init()
    st, _ = eng.run(st, 500)  # 500 ms
    t_s = float(st.t) * small_spec.dt_ms / 1000.0
    rate = float(st.spike_count.sum()) / (small_spec.n_total * t_s)
    assert 0.5 < rate < 10.0, f"ground-state rate {rate:.2f} Hz out of band"


def test_ignore_and_fire_exact_rate():
    """Ignore-and-fire emits at exactly the configured rate (paper §4.2)."""
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=10.0)
    net = build_network(spec, seed=12)
    eng = make_engine(net, spec, EngineConfig(neuron_model="ignore_and_fire"))
    st = eng.init()
    st, _ = eng.run(st, 1000)  # 1 s
    rate = float(st.spike_count.sum()) / spec.n_total
    assert abs(rate - 10.0) < 0.11, rate


def test_heterogeneous_area_sizes_ghost_padding():
    """Heterogeneous areas pad to N_max with frozen ghosts that never fire."""
    spec = mam_benchmark_spec(n_areas=4, n_per_area=40, k_intra=4, k_inter=4,
                              area_size_cv=0.3, seed=7)
    net = build_network(spec, seed=12)
    sizes = spec.area_sizes()
    assert len(set(sizes.tolist())) > 1, "sizes should differ"
    eng = make_engine(net, spec, EngineConfig(neuron_model="ignore_and_fire"))
    st = eng.init()
    st, _ = eng.run(st, 100)
    counts = np.asarray(st.spike_count)
    alive = np.asarray(net.alive)
    assert counts[~alive].sum() == 0, "ghost neurons must stay silent"
    assert counts[alive].sum() > 0


def test_mam_spec_properties():
    spec = mam_spec(scale=0.001)
    assert spec.n_areas == 32
    assert spec.delay_ratio == 10
    sizes = spec.area_sizes().astype(float)
    cv = sizes.std() / sizes.mean()
    assert 0.1 < cv < 0.3, f"MAM area-size CV {cv:.2f} (paper ~0.2)"
    rates = spec.area_rates()
    v2 = rates[list(MAM_AREA_NAMES).index("V2")]
    assert v2 > rates.mean() * 1.3, "V2 must be among the hottest areas"


def test_delay_tiers_respected(small_net, small_spec):
    d_intra = np.asarray(small_net.delay_intra)
    d_inter = np.asarray(small_net.delay_inter)
    assert d_intra.min() >= 1
    assert d_intra.max() <= small_spec.steps_intra_max
    assert d_inter.min() >= small_spec.delay_ratio, \
        "inter-area delays must respect the d_min_inter cutoff (eq. 1)"
    assert d_inter.max() < small_net.ring_len


def test_event_delivery_equals_dense_engine():
    """Beyond-paper optimization: event-driven delivery (compact fired
    neurons, scatter outgoing synapses) is bit-identical to the dense
    gather-matvec path -- weights live on the exact 1/256 grid."""
    from repro.core.engine import EngineConfig, make_engine

    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8,
                              rate_hz=30.0)
    net = build_network(spec, seed=91856, outgoing=True)
    dense = make_engine(net, spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery="dense"))
    event = make_engine(net, spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        delivery="event"))
    sd, se = dense.init(), event.init()
    for w in range(25):
        sd, bd = dense.window(sd)
        se, be = event.window(se)
        assert np.array_equal(np.asarray(bd), np.asarray(be)), w
        assert np.array_equal(np.asarray(sd.ring), np.asarray(se.ring)), w
    assert int(sd.spike_count.sum()) > 100
