"""Wire-format tests: bit-packing round-trips and gather-path agreement.

``pack_bits``/``unpack_bits`` are the dense wire format (1 bit per neuron per
cycle); the gather helpers must produce identical results whether or not the
wire is packed, for any neuron count -- including ones that don't divide by 8.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("n", [1, 7, 8, 9, 13, 16, 100, 255, 256, 257])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.integers(0, 2, n), jnp.int8)
    p = comm.pack_bits(x)
    assert p.shape[-1] == (n + 7) // 8
    assert p.dtype == jnp.uint8
    out = comm.unpack_bits(p, n)
    assert out.dtype == jnp.int8
    assert np.array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("shape", [(3, 5), (2, 4, 11), (1, 9)])
def test_pack_unpack_roundtrip_batched(shape):
    rng = np.random.default_rng(sum(shape))
    x = jnp.asarray(rng.integers(0, 2, shape), jnp.int8)
    out = comm.unpack_bits(comm.pack_bits(x), shape[-1])
    assert out.shape == x.shape
    assert np.array_equal(np.asarray(out), np.asarray(x))


def test_pack_bits_wire_bytes():
    """Packing must actually deliver the 8x byte saving it claims."""
    x = jnp.ones((4, 64), jnp.int8)
    assert comm.pack_bits(x).size * 8 == x.size


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_gather_paths_packed_vs_unpacked_agree():
    """gather_area / gather_global / gather_full give identical results with
    packed=True and packed=False -- including a per-shard width (24) that is
    a multiple of 8 but whose unpadded halves exercise the reshape path, and
    a width (4) below one packed byte."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import comm

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        def check(n_loc):
            A_loc, D = 2, 3
            rng = np.random.default_rng(n_loc)

            def body_area(s):
                a = comm.gather_area(s, subgroup_axis="model", packed=True)
                b = comm.gather_area(s, subgroup_axis="model", packed=False)
                return a, b

            def body_global(blk):
                a = comm.gather_global(blk, area_axes=("pod", "data"),
                                       subgroup_axis="model", packed=True)
                b = comm.gather_global(blk, area_axes=("pod", "data"),
                                       subgroup_axis="model", packed=False)
                return a, b

            def body_full(s):
                a = comm.gather_full(s, ("pod", "data", "model"), packed=True)
                b = comm.gather_full(s, ("pod", "data", "model"), packed=False)
                return a, b

            spk = jnp.asarray(
                rng.integers(0, 2, (A_loc * 4, 2 * n_loc)), jnp.int8)
            fa = shard_map(body_area, mesh=mesh,
                           in_specs=P(("pod", "data"), "model"),
                           out_specs=(P(("pod", "data"), None),
                                      P(("pod", "data"), None)),
                           check_vma=False)
            a, b = fa(spk)
            assert np.array_equal(np.asarray(a), np.asarray(b)), "area"

            blk = jnp.asarray(
                rng.integers(0, 2, (D, A_loc * 4, 2 * n_loc)), jnp.int8)
            fg = shard_map(body_global, mesh=mesh,
                           in_specs=P(None, ("pod", "data"), "model"),
                           out_specs=(P(None, None, None),
                                      P(None, None, None)),
                           check_vma=False)
            a, b = fg(blk)
            assert np.array_equal(np.asarray(a), np.asarray(b)), "global"

            spk2 = jnp.asarray(
                rng.integers(0, 2, (A_loc, 8 * n_loc)), jnp.int8)
            ff = shard_map(body_full, mesh=mesh,
                           in_specs=P(None, ("pod", "data", "model")),
                           out_specs=(P(None, None), P(None, None)),
                           check_vma=False)
            a, b = ff(spk2)
            assert np.array_equal(np.asarray(a), np.asarray(b)), "full"

        check(24)
        check(4)
        print("OK")
    """))
