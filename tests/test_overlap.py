"""Overlapped (double-buffered) window exchange: bit-identity + jitter walls.

The tentpole contract under test: with ``EngineConfig.overlap_exchange`` the
payload exchange of window ``w`` stays in flight while window ``w+1``
computes, and the deferred receive scatter lands before ``w+1``'s first ring
read -- so the trajectory is *bitwise identical* to the sequential schedule
(spikes, rings, ``shipped_bytes``, overflow) across every exchange x
packet-mode x window-body combination, survives a mid-run checkpoint/resume
(the in-flight window drains before every save), and under injected faults
the pipelined wall follows ``max(compute, comm)`` per window while the
sequential wall pays the sum -- the closed-form quantities
``sync_model.expected_wall_overlapped`` prices.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import faults as faults_lib
from repro.core import schedule as schedule_lib
from repro.core import sync_model
from repro.core.areas import mam_benchmark_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _quick_net():
    spec = mam_benchmark_spec(n_areas=2, n_per_area=32, k_intra=4, k_inter=4)
    return spec, build_network(spec, seed=12, outgoing=True)


def _engine(spec, net, **cfg_kw):
    cfg = EngineConfig(neuron_model="lif", delivery_backend="event",
                      s_max_floor=4, **cfg_kw)
    return make_simulation(spec, cfg, net=net)


def _assert_states_equal(a, b, tag=""):
    assert int(a.t) == int(b.t), tag
    assert int(a.overflow) == int(b.overflow), tag
    assert float(np.asarray(a.shipped_bytes)) == float(
        np.asarray(b.shipped_bytes)), tag
    assert np.array_equal(np.asarray(a.ring), np.asarray(b.ring)), tag
    assert np.array_equal(np.asarray(a.spike_count),
                          np.asarray(b.spike_count)), tag


# ---------------------------------------------------------------------------
# single host: overlapped == sequential, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("superstep", [True, False],
                         ids=["superstep", "legacy"])
@pytest.mark.parametrize("adaptive", [False, True],
                         ids=["static", "adaptive"])
def test_overlap_bitwise_equals_sequential(superstep, adaptive):
    """run_windows and the jitted Engine.run pipeline both reproduce the
    sequential trajectory exactly, across {static, adaptive} packets and
    {superstep, legacy} window bodies."""
    spec, net = _quick_net()
    seq = _engine(spec, net, superstep=superstep, adaptive_exchange=adaptive)
    ovl = _engine(spec, net, superstep=superstep, adaptive_exchange=adaptive,
                  overlap_exchange=True)
    assert ovl.window_overlap is not None and seq.window_overlap is None

    ref = schedule_lib.run_windows(seq, seq.init(), 6)
    res = schedule_lib.run_windows(ovl, ovl.init(), 6)
    assert res.overlapped and res.drains == 1
    assert not ref.overlapped and ref.drains == 0
    assert np.array_equal(res.spikes_per_window, ref.spikes_per_window)
    _assert_states_equal(res.state, ref.state)

    # The jitted scan path (Engine.run carries the in-flight window through
    # the scan and drains once at the end) agrees too.
    st_r, _ = seq.run(seq.init(), 6)
    st_o, _ = ovl.run(ovl.init(), 6)
    _assert_states_equal(st_o, st_r, "Engine.run")

    # And the overlap engine's compatibility `window` (empty in-flight +
    # immediate drain) is the sequential window, usable interchangeably.
    st_a, blk_a = seq.window(seq.init())
    st_b, blk_b = ovl.window(ovl.init())
    assert np.array_equal(np.asarray(blk_a), np.asarray(blk_b))
    _assert_states_equal(st_a, st_b, "compat window")


def test_overlap_requires_structure_aware():
    with pytest.raises(ValueError, match="structure-aware"):
        EngineConfig(schedule="conventional", overlap_exchange=True)


@pytest.mark.parametrize("adaptive", [False, True], ids=["static", "adaptive"])
def test_overlap_checkpoint_midrun_resume(tmp_path, adaptive):
    """Preempt an overlapped run mid-pipeline: the in-flight window must
    drain before the grace save, so the checkpoint is the sequential-
    equivalent state -- resumable by a sequential OR an overlapped engine,
    both landing bitwise on the uninterrupted reference (the
    ``overlap_exchange`` flag is a layout key, not part of the trajectory
    hash)."""
    spec, net = _quick_net()
    seq = _engine(spec, net, adaptive_exchange=adaptive)
    ovl = _engine(spec, net, adaptive_exchange=adaptive,
                  overlap_exchange=True)
    ref = schedule_lib.run_windows(seq, seq.init(), 8)

    inj = faults_lib.FaultInjector(
        faults_lib.FaultConfig(preempt_after_window=5),
        n_devices=1, delay_ratio=ovl.delay_ratio)
    ckpt = schedule_lib.SimCheckpointer(str(tmp_path), ovl, net, every=3,
                                        injector=inj)
    with pytest.raises(faults_lib.Preempted) as exc_info:
        schedule_lib.run_windows(ovl, ovl.init(), 8,
                                 checkpointer=ckpt, faults=inj)
    exc = exc_info.value
    assert exc.window == 5
    # every save drained first: the cadence save at 3 plus the grace save
    assert exc.result.drains >= 2

    for resumer, tag in ((seq, "sequential"), (ovl, "overlapped")):
        st, info = schedule_lib.restore_sim(str(tmp_path), resumer, net)
        assert info["step"] == 5, tag
        res = schedule_lib.run_windows(resumer, st, 3)
        assert np.array_equal(res.spikes_per_window,
                              ref.spikes_per_window[5:]), tag
        _assert_states_equal(res.state, ref.state, tag)


def test_overlap_jitter_wall_max_vs_sum():
    """The acceptance criterion in closed form: under injected compute +
    exchange jitter the sequential loop's injected wall is exactly
    sum(comp_w + comm_w) while the pipelined loop pays
    comp_1 + sum(max(comp_w, comm_{w-1})) + comm_n -- strictly less -- and
    both realized walls sit within 15% of the extended sync model
    (``expected_wall_overlapped``, Clark's E[max])."""
    spec, net = _quick_net()
    seq = _engine(spec, net)
    ovl = _engine(spec, net, overlap_exchange=True)
    n = 40
    fcfg = faults_lib.FaultConfig(
        jitter_mu_ms=1.0, jitter_sigma_ms=0.1, jitter_devices=8,
        comm_mu_ms=12.0, comm_sigma_ms=1.0, seed=4)

    def injector():
        return faults_lib.FaultInjector(fcfg, n_devices=4,
                                        delay_ratio=seq.delay_ratio)

    res_seq = schedule_lib.run_windows(seq, seq.init(), n, faults=injector())
    res_ovl = schedule_lib.run_windows(ovl, ovl.init(), n, faults=injector())
    assert np.array_equal(res_ovl.spikes_per_window, res_seq.spikes_per_window)

    # Exact replay: the injector draws are a pure function of (seed, window).
    twin = injector()
    comp = [twin.window_jitter_s(w) for w in range(1, n + 1)]
    comm = [twin.window_comm_jitter_s(w) for w in range(1, n + 1)]
    want_seq = sum(c + x for c, x in zip(comp, comm))
    want_ovl = (comp[0] + sum(max(comp[w], comm[w - 1]) for w in range(1, n))
                + comm[-1])
    assert res_seq.injected_sleep_s == pytest.approx(want_seq, rel=1e-9)
    assert res_ovl.injected_sleep_s == pytest.approx(want_ovl, rel=1e-9)
    assert res_ovl.injected_sleep_s < res_seq.injected_sleep_s

    # ... and the sync model prices both walls within 15%.
    inj = injector()
    mu_comp, mu_comm = inj.predicted_jitter_s(), inj.predicted_comm_s()
    pred_seq = n * (mu_comp + mu_comm)
    pred_ovl = sync_model.expected_wall_overlapped(
        n, mu_comp,
        np.sqrt(seq.delay_ratio) * inj.model.sigma,
        mu_comm, fcfg.comm_sigma_ms * 1e-3)
    assert abs(res_seq.injected_sleep_s / pred_seq - 1) < 0.15
    assert abs(res_ovl.injected_sleep_s / pred_ovl - 1) < 0.15
    assert pred_ovl == pytest.approx(
        n * inj.predicted_overlap_s(), rel=0.25)


# ---------------------------------------------------------------------------
# XLA flag gating (the CPU build aborts on unknown --xla_gpu_* flags)
# ---------------------------------------------------------------------------


def test_xla_overlap_flags_gpu_only(monkeypatch):
    from repro.launch import simulate

    assert simulate.xla_overlap_flags("cpu") == []
    assert simulate.xla_overlap_flags("tpu") == []
    gpu = simulate.xla_overlap_flags("gpu")
    assert len(gpu) == 3 and all(f.startswith("--xla_gpu_") for f in gpu)
    # autodetect on this CPU-only container must find no GPU plugin
    assert simulate.xla_overlap_flags() == []

    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    assert simulate.enable_overlap_flags("cpu") is False
    assert os.environ["XLA_FLAGS"] == \
        "--xla_force_host_platform_device_count=8"
    assert simulate.enable_overlap_flags("gpu") is True
    for flag in gpu:
        assert flag in os.environ["XLA_FLAGS"]
    before = os.environ["XLA_FLAGS"]
    assert simulate.enable_overlap_flags("gpu") is True  # idempotent
    assert os.environ["XLA_FLAGS"] == before


# ---------------------------------------------------------------------------
# distributed: the full exchange matrix in an 8-device subprocess
# ---------------------------------------------------------------------------


def test_dist_overlap_bitwise_matrix(tmp_path):
    """{dense, routed} x {static, adaptive} x {superstep, legacy} on a 4x2
    mesh: the shard_mapped overlapped pipeline (in-flight wire sharded
    per-group for routed, replicated for dense) matches the sequential
    engine bitwise, including the measured shipped_bytes."""
    print(_run("""
        import numpy as np, jax
        from repro.core import schedule as schedule_lib
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for exchange in ("dense", "routed"):
            for adaptive in (False, True):
                for superstep in (True, False):
                    tag = f"{exchange}-{adaptive}-{superstep}"
                    kw = dict(neuron_model="ignore_and_fire",
                              delivery_backend="event", exchange=exchange,
                              adaptive_exchange=adaptive,
                              superstep=superstep, s_max_floor=4)
                    seq = make_simulation(spec, EngineConfig(**kw), net=net, mesh=mesh)
                    ovl = make_simulation(spec, EngineConfig(
                        overlap_exchange=True, **kw), net=net, mesh=mesh)
                    ref = schedule_lib.run_windows(seq, seq.init(), 4)
                    res = schedule_lib.run_windows(ovl, ovl.init(), 4)
                    assert res.overlapped and res.drains == 1, tag
                    assert np.array_equal(res.spikes_per_window,
                                          ref.spikes_per_window), tag
                    assert int(res.state.t) == int(ref.state.t), tag
                    assert int(res.state.overflow) == int(
                        ref.state.overflow), tag
                    assert float(np.asarray(res.state.shipped_bytes)) == \
                        float(np.asarray(ref.state.shipped_bytes)), tag
                    assert np.array_equal(np.asarray(res.state.ring),
                                          np.asarray(ref.state.ring)), tag
                    assert np.array_equal(
                        np.asarray(res.state.spike_count),
                        np.asarray(ref.state.spike_count)), tag
                    print("OK", tag)
        print("DIST OVERLAP MATRIX DONE")
    """))
