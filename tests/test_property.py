"""Property-based tests (hypothesis) on the system's invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.areas import mam_benchmark_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation
from repro.core.neuron import counter_uniform
from repro.core import ring_buffer
from repro.optim.compress import ef_compress, int8_decode, int8_encode

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=12, deadline=None)


@settings(**SETTINGS)
@given(
    n_areas=st.sampled_from([2, 3, 4]),
    n_per_area=st.sampled_from([16, 24, 40]),
    d_ratio=st.sampled_from([2, 5, 10]),
    seed=st.integers(0, 2**31 - 1),
    neuron=st.sampled_from(["ignore_and_fire", "lif"]),
)
def test_schedule_equivalence_property(n_areas, n_per_area, d_ratio, seed, neuron):
    """For ANY network geometry, delay ratio and seed, the two schedules
    produce bit-identical spike trains (the paper's core causality claim)."""
    spec = mam_benchmark_spec(
        n_areas=n_areas, n_per_area=n_per_area, k_intra=4, k_inter=4,
        d_min_inter_ms=0.1 * d_ratio,
    )
    net = build_network(spec, seed=seed % 100000)
    conv = make_simulation(spec, EngineConfig(
        neuron_model=neuron, schedule="conventional", seed=seed % 97), net=net)
    struc = make_simulation(spec, EngineConfig(
        neuron_model=neuron, schedule="structure_aware", seed=seed % 97), net=net)
    sc, ss = conv.init(), struc.init()
    for _ in range(6):
        sc, bc = conv.window(sc)
        ss, bs = struc.window(ss)
        assert np.array_equal(np.asarray(bc), np.asarray(bs))


@settings(**SETTINGS)
@given(
    n=st.integers(4, 64),
    r=st.integers(4, 32),
    k=st.integers(1, 8),
    t=st.integers(0, 1000),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_buffer_deposit_read_roundtrip(n, r, k, t, seed):
    """Whatever is deposited with delay d is read exactly d steps later and
    the slot is cleared after reading."""
    rng = np.random.default_rng(seed)
    ring = jnp.zeros((n, r), jnp.float32)
    vals = jnp.asarray(np.round(rng.normal(0, 64, (n, k))) / 256.0, jnp.float32)
    delays = jnp.asarray(rng.integers(1, r, (n, k)), jnp.int32)
    ring = ring_buffer.deposit(ring, vals, delays, jnp.int32(t))
    # advance the clock: at step t+d we must read sum of vals with delay d
    total_read = np.zeros(n, np.float32)
    for step in range(t + 1, t + r):
        i_in, ring = ring_buffer.read_and_clear(ring, jnp.int32(step))
        d = step - t
        want = np.asarray((vals * (np.asarray(delays) == d)).sum(axis=1))
        assert np.allclose(np.asarray(i_in), want), f"step {step}"
        total_read += np.asarray(i_in)
    assert np.allclose(total_read, np.asarray(vals.sum(axis=1)))
    assert float(jnp.abs(ring).max()) == 0.0, "ring must be empty after a lap"


@settings(**SETTINGS)
@given(
    n=st.integers(4, 48),
    d=st.sampled_from([2, 5, 10]),
    blocks=st.integers(2, 6),
    tail_w=st.integers(0, 12),
    w0=st.integers(0, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_blocked_ring_access_equals_per_cycle(n, d, blocks, tail_w, w0, seed):
    """read_and_clear_block + merge_window_tail == per-cycle read_and_clear:
    for any phase-aligned window start, the blocked path reads the same
    slots, clears the same slots, and the merged tail lands where per-cycle
    deposits would."""
    rng = np.random.default_rng(seed)
    r = d * blocks
    tail_w = min(tail_w, r)
    t0 = jnp.int32(w0 * d)
    ring = jnp.asarray(np.round(rng.normal(0, 64, (n, r))) / 256.0, jnp.float32)
    blk, cleared = ring_buffer.read_and_clear_block(ring, t0, d)
    ring_ref = ring
    for s in range(d):
        i_in, ring_ref = ring_buffer.read_and_clear(ring_ref, t0 + s)
        assert np.array_equal(np.asarray(blk[..., s]), np.asarray(i_in)), s
    assert np.array_equal(np.asarray(cleared), np.asarray(ring_ref))
    if tail_w:
        tail = jnp.asarray(
            np.round(rng.normal(0, 64, (n, tail_w))) / 256.0, jnp.float32)
        got = ring_buffer.merge_window_tail(cleared, tail, t0 + d)
        want = np.asarray(cleared).copy()
        for j in range(tail_w):
            want[:, (int(t0) + d + j) % r] += np.asarray(tail[:, j])
        assert np.allclose(np.asarray(got), want, atol=1e-6)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 1000),
    t=st.integers(0, 10_000),
    n=st.integers(8, 256),
    split=st.integers(1, 7),
)
def test_counter_uniform_shard_invariance(seed, t, n, split):
    """The drive is a pure function of (seed, t, gid): any partition of the
    gid range reproduces exactly the same values (key for distributed
    bit-exactness)."""
    gids = jnp.arange(n, dtype=jnp.int32)
    full = np.asarray(counter_uniform(seed, jnp.int32(t), gids))
    cut = max(1, n * split // 8)
    a = np.asarray(counter_uniform(seed, jnp.int32(t), gids[:cut]))
    b = np.asarray(counter_uniform(seed, jnp.int32(t), gids[cut:]))
    assert np.array_equal(np.concatenate([a, b]), full)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 100000),
    pathway=st.sampled_from(["intra", "inter"]),
    perm_seed=st.integers(0, 2**31 - 1),
)
def test_counter_draws_row_order_and_shard_invariance(
        seed, pathway, perm_seed):
    """The connectivity draws are pure functions of (seed, pathway, row):
    any row subset in any order reproduces the host-built global tensors'
    rows exactly, and a shard's row range under 4 groups equals the union
    of its matching row ranges under 8 groups -- the property that makes
    the host-free sharded build bitwise-independent of the shard count."""
    from repro.core.connectivity import draw_pathway_rows
    from repro.core.partition import shard_pathway_rows

    spec = mam_benchmark_spec(
        n_areas=8, n_per_area=16, k_intra=4, k_inter=4)
    n_pad = spec.padded_area_size(1)
    full = np.arange(8 * n_pad, dtype=np.int64)
    s_f, w_f, d_f = draw_pathway_rows(spec, seed, full, pathway=pathway)
    rng = np.random.default_rng(perm_seed)
    rows = rng.permutation(full)[: 3 * n_pad]
    s, w, d = draw_pathway_rows(spec, seed, rows, pathway=pathway)
    assert np.array_equal(s, s_f[rows])
    assert np.array_equal(w, w_f[rows])
    assert np.array_equal(d, d_f[rows])
    # Shard g of 4 groups == its two matching shards of 8 groups.
    g = int(rng.integers(4))
    coarse = shard_pathway_rows("group", g, 4, 8, n_pad)
    fine = np.concatenate([
        shard_pathway_rows("group", 2 * g, 8, 8, n_pad),
        shard_pathway_rows("group", 2 * g + 1, 8, 8, n_pad)])
    assert np.array_equal(coarse, fine)
    s4, w4, d4 = draw_pathway_rows(spec, seed, coarse, pathway=pathway)
    s8 = np.concatenate([
        draw_pathway_rows(spec, seed, r, pathway=pathway)[0]
        for r in (coarse[: len(coarse) // 2], coarse[len(coarse) // 2:])])
    assert np.array_equal(s4, s8)
    assert np.array_equal(s4, s_f[coarse])
    assert np.array_equal(w4, w_f[coarse])
    assert np.array_equal(d4, d_f[coarse])


@settings(**SETTINGS)
@given(
    shape=st.sampled_from([(8,), (16, 4), (3, 5, 7)]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_roundtrip_error_bound(shape, scale, seed):
    """Quantisation error is bounded by scale/254 per element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, shape), jnp.float32)
    q, s = int8_encode(x)
    err = np.abs(np.asarray(int8_decode(q, s)) - np.asarray(x))
    bound = float(np.abs(np.asarray(x)).max()) / 127.0
    assert err.max() <= bound * 0.5 + 1e-9


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_is_lossless_over_time(seed):
    """With error feedback, the *accumulated* transmitted signal converges to
    the accumulated true signal (compression is unbiased over time)."""
    rng = np.random.default_rng(seed)
    ef = jnp.zeros((32,), jnp.float32)
    total_true = np.zeros(32, np.float64)
    total_sent = np.zeros(32, np.float64)
    for step in range(30):
        x = jnp.asarray(rng.normal(0, 1, 32), jnp.float32)
        dec, ef, _ = ef_compress(x, ef, "int8")
        total_true += np.asarray(x, np.float64)
        total_sent += np.asarray(dec, np.float64)
    resid = np.abs(total_true - total_sent - np.asarray(ef, np.float64))
    assert resid.max() < 1e-3, "EF identity: sent + residual == true"


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([16, 32]),
    window=st.sampled_from([0, 3, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_streaming_attention_property(b, s, window, seed):
    import repro.models.layers as L
    rng = np.random.default_rng(seed)
    h, hkv, dh = 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out_s = L._streaming_attention(q, k, v, pos, pos, jnp.int32(s), window)
    out_d = L.attention_scores(
        q, k, v, L.causal_window_mask(pos, pos, None, window))
    assert float(jnp.abs(out_s - out_d).max()) < 5e-5
