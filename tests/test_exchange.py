"""Exchange-layer tests: the pluggable spike transport must be invisible.

The tentpole invariant: ``LocalExchange`` (single host), ``DenseMeshExchange``
(mesh-wide collectives) and ``RoutedExchange`` (connectivity-routed packet
rounds over the area-adjacency group graph) produce bit-identical spike
trains, ring buffers and overflow counts -- across schedules, delivery
backends, superstep/legacy windows and mesh shapes, including a deliberately
sparse area graph where routing actually skips rounds and ships strictly
fewer bytes.

Multi-device cases run in subprocesses with 8 forced host devices (per the
launch contract, the main pytest process must keep seeing one device).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_routed_exchange_equivalence_sparse_graph():
    """Tentpole: on a sparse area graph (directed ring over 8 areas), the
    routed exchange reproduces the single-host reference bitwise -- spike
    blocks AND rings -- for dense and event backends, under both the fused
    superstep and the legacy per-cycle window, with zero overflow; and its
    static wire accounting ships strictly fewer global bytes than the dense
    mesh exchange."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
        from repro.core.connectivity import build_network, area_adjacency
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation
        from repro.core import exchange as exchange_lib

        spec = mam_benchmark_spec(
            n_areas=8, n_per_area=32, k_intra=4, k_inter=4, rate_hz=30.0,
            area_adjacency=ring_area_adjacency(8, width=2))
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        adj = area_adjacency(net, spec)
        assert adj.sum() < adj.size - adj.shape[0], "graph must be sparse"
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks, ring_ref = [], None
        for _ in range(6):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        ring_ref = np.asarray(s0.ring)
        assert sum(b.sum() for b in blocks) > 0
        for backend in ("scatter", "event"):
            for superstep in (None, False):
                eng = make_simulation(spec, EngineConfig(
                    neuron_model="ignore_and_fire",
                    schedule="structure_aware", delivery_backend=backend,
                    exchange="routed", s_max_floor=32, superstep=superstep), net=net, mesh=mesh)
                st = eng.init()
                for w in range(6):
                    st, blk = eng.window(st)
                    assert np.array_equal(
                        np.asarray(blk).astype(bool), blocks[w]
                    ), (backend, superstep, w)
                assert np.array_equal(np.asarray(st.ring), ring_ref), (
                    backend, superstep, "ring")
                assert int(st.overflow) == 0, (backend, superstep)
                wire = eng.wire_bytes
                assert wire["rounds"] < wire["dense_rounds"], wire
        # Apples-to-apples wire volume (id packets both ways): routed < dense.
        rep = exchange_lib.wire_report(net, adj, backend="event",
                                       n_groups=4, gsz=2)
        assert (rep["routed"]["global_bytes"]
                < rep["dense"]["global_bytes"]), rep
        print("OK")
    """))


def test_routed_exchange_multi_pod_and_overflow():
    """The 3-axis (pod, data, model) mesh exercises the multi-axis group
    rotation (one ppermute over the (pod, data) axis-name tuple with pairs
    on the flattened row-major group index); LIF dynamics must stay
    bitwise. A forced-overflow run must surface the per-edge spill in
    SimState.overflow instead of dropping spikes silently."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        adj = ring_area_adjacency(8, width=1)
        spec = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4,
                                  k_inter=4, area_adjacency=adj)
        net = build_network(spec, seed=654, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ref = make_simulation(spec, EngineConfig(
            schedule="conventional", neuron_model="lif"), net=net)
        eng = make_simulation(spec, EngineConfig(
            schedule="structure_aware", neuron_model="lif",
            exchange="routed", s_max_floor=64), net=net, mesh=mesh)
        st, s0 = eng.init(), ref.init()
        for w in range(6):
            s0, blk_ref = ref.window(s0)
            st, blk = eng.window(st)
            assert np.array_equal(np.asarray(blk).astype(bool),
                                  np.asarray(blk_ref)), w
        assert np.array_equal(np.asarray(st.ring), np.asarray(s0.ring))
        assert int(st.overflow) == 0

        spec2 = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4,
                                   k_inter=4, rate_hz=2000.0,
                                   area_adjacency=adj)
        net2 = build_network(spec2, seed=12, size_multiple=8, outgoing=True)
        eng2 = make_simulation(spec2, EngineConfig(
            neuron_model="ignore_and_fire", schedule="structure_aware",
            exchange="routed", delivery_backend="event",
            s_max_headroom=0.0, s_max_floor=1), net=net2, mesh=mesh)
        st = eng2.init()
        for _ in range(5):
            st, _ = eng2.window(st)
        assert int(st.spike_count.sum()) > 0
        assert int(st.overflow) > 0, "routed edge spill must be visible"
        print("OK")
    """))


def test_sharded_inter_tables_equivalence():
    """Tentpole: the sharded inbound inter tables (the default distributed
    receive path) are bit-identical to the legacy replicated tables AND the
    single-host reference -- spike trains, rings and SimState.overflow --
    for both DenseMeshExchange and RoutedExchange on an 8-fake-device mesh,
    including the conventional schedule's window-sliced variant; and a
    forced per-edge s_max overflow run reports the *same* nonzero spill
    under either table layout (packets are cut send-side, so the layout
    cannot change what drops)."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        adj = ring_area_adjacency(8, width=2)
        spec = mam_benchmark_spec(
            n_areas=8, n_per_area=32, k_intra=4, k_inter=4, rate_hz=30.0,
            area_adjacency=adj)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks = []
        for _ in range(6):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        ring_ref = np.asarray(s0.ring)
        assert sum(b.sum() for b in blocks) > 0
        cells = [("structure_aware", "dense"), ("structure_aware", "routed"),
                 ("conventional", "dense")]
        for sched, exch in cells:
            for shard_tables in (True, False):
                eng = make_simulation(spec, EngineConfig(
                    neuron_model="ignore_and_fire", schedule=sched,
                    delivery_backend="event", exchange=exch,
                    s_max_floor=32, shard_inter_tables=shard_tables), net=net, mesh=mesh)
                st = eng.init()
                for w in range(6):
                    st, blk = eng.window(st)
                    assert np.array_equal(
                        np.asarray(blk).astype(bool), blocks[w]
                    ), (sched, exch, shard_tables, w)
                assert np.array_equal(np.asarray(st.ring), ring_ref), (
                    sched, exch, shard_tables, "ring")
                assert int(st.overflow) == 0, (sched, exch, shard_tables)

        # Forced per-edge spill: identical (nonzero) overflow and identical
        # surviving spike trains under both table layouts.
        spec2 = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4,
                                   k_inter=4, rate_hz=2000.0,
                                   area_adjacency=adj)
        net2 = build_network(spec2, seed=12, size_multiple=8, outgoing=True)
        got = {}
        for shard_tables in (True, False):
            eng = make_simulation(spec2, EngineConfig(
                neuron_model="ignore_and_fire", schedule="structure_aware",
                exchange="routed", delivery_backend="event",
                s_max_headroom=0.0, s_max_floor=1,
                shard_inter_tables=shard_tables), net=net2, mesh=mesh)
            st = eng.init()
            for _ in range(5):
                st, _ = eng.window(st)
            got[shard_tables] = (int(st.overflow),
                                 np.asarray(st.spike_count),
                                 np.asarray(st.ring))
        over_sh, spikes_sh, ring_sh = got[True]
        over_rep, spikes_rep, ring_rep = got[False]
        assert over_sh > 0, "forced spill must be visible"
        assert over_sh == over_rep, (over_sh, over_rep)
        assert np.array_equal(spikes_sh, spikes_rep)
        assert np.array_equal(ring_sh, ring_rep)
        print("OK")
    """))


def test_shard_inter_tables_partitions_the_replicated_table():
    """Host-only: every replicated inter synapse lands in exactly one shard
    (union over shards == the replicated table, per source row), each
    shard's targets belong to it, and the network_sds width bound covers
    the instantiated per-shard width for both slicing modes."""
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import (
        _inbound_k_bound, build_network, shard_inter_tables)

    spec = mam_benchmark_spec(n_areas=8, n_per_area=32, k_intra=4, k_inter=6)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    A, n_pad = net.alive.shape
    n_rows = A * n_pad
    tgt = np.asarray(net.tgt_inter).reshape(n_rows, -1)
    w = np.asarray(net.wout_inter).reshape(n_rows, -1)
    d = np.asarray(net.dout_inter).reshape(n_rows, -1)
    for mode, S in (("group", 4), ("window", 4)):
        sh = shard_inter_tables(net, S, mode=mode)
        assert sh.tgt_inter is None and sh.inter_shard_mode == mode
        t_in = np.asarray(sh.tgt_inter_in)
        w_in = np.asarray(sh.wout_inter_in)
        d_in = np.asarray(sh.dout_inter_in)
        assert t_in.shape[:2] == (S, n_rows)
        assert _inbound_k_bound(spec.k_inter, S) >= t_in.shape[2]
        for s in range(S):
            ts = t_in[s][t_in[s] >= 0]
            owner = ((ts // n_pad) // (A // S) if mode == "group"
                     else (ts % n_pad) // (n_pad // S))
            assert (owner == s).all(), (mode, s)
        for r in range(0, n_rows, 29):
            rep = sorted(
                (int(t), float(wv), int(dv))
                for t, wv, dv in zip(tgt[r], w[r], d[r]) if t >= 0)
            shd = sorted(
                (int(t_in[s, r, j]), float(w_in[s, r, j]),
                 int(d_in[s, r, j]))
                for s in range(S) for j in range(t_in.shape[2])
                if t_in[s, r, j] >= 0)
            assert rep == shd, (mode, r)
    with pytest.raises(ValueError, match="divisible"):
        shard_inter_tables(net, 3, mode="group")
    # Built from the *incoming* tensors: a network without the replicated
    # outgoing tables yields the identical inbound slices -- production
    # builds never need to materialise the replicated layout at all.
    lean = shard_inter_tables(
        build_network(spec, seed=12, size_multiple=8), 4)
    full = shard_inter_tables(net, 4)
    assert np.array_equal(np.asarray(lean.tgt_inter_in),
                          np.asarray(full.tgt_inter_in))
    assert np.array_equal(np.asarray(lean.wout_inter_in),
                          np.asarray(full.wout_inter_in))
    assert np.array_equal(np.asarray(lean.dout_inter_in),
                          np.asarray(full.dout_inter_in))


def test_sharded_tables_mesh_mismatch_rejected():
    """A network whose prebuilt inbound tables do not match the mesh's
    shard grid (wrong count or wrong slicing mode) must be rejected at
    assembly, not silently misdelivered."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network, shard_inter_tables
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = EngineConfig(neuron_model="ignore_and_fire",
                       schedule="structure_aware", delivery_backend="event")
    with pytest.raises(ValueError, match="do not match the"):
        make_simulation(spec, cfg, net=shard_inter_tables(net, 2), mesh=mesh)
    with pytest.raises(ValueError, match="do not match the"):
        make_simulation(spec, cfg, net=shard_inter_tables(net, 1, mode="window"), mesh=mesh)


def test_build_routing_hierarchical_round_order():
    """Satellite: with ``intra_tier`` set (groups per pod on the (pod, data)
    group grid), rotation rounds are ordered group-local -> all-intra-pod ->
    pod-crossing, so most rounds stay on the fast tier; without it the flat
    offset order is preserved."""
    from repro.core import exchange as exchange_lib

    full = ~np.eye(8, dtype=bool)
    # 8 groups in 2 pods of 4: offsets 1-3 can stay intra-pod only for some
    # source groups, so with a full graph every nonzero offset crosses a pod
    # boundary somewhere -- use a block-diagonal graph to create genuinely
    # intra-pod offsets.
    intra = np.zeros((8, 8), dtype=bool)
    intra[:4, :4] = ~np.eye(4, dtype=bool)   # pod 0 areas talk to pod 0
    intra[4:, 4:] = ~np.eye(4, dtype=bool)
    intra[0, 4] = True                       # one slow-tier edge
    rt = exchange_lib.build_routing(
        intra, 8, exp_area_spikes=1.0, headroom=8.0, floor=2, intra_tier=4)

    def tier(rnd):
        if rnd.offset == 0:
            return 0
        return 1 if all(g // 4 == h // 4 for g, h in rnd.pairs) else 2

    tiers = [tier(r) for r in rt.rounds]
    assert tiers == sorted(tiers), [(r.offset, t) for r, t in
                                    zip(rt.rounds, tiers)]
    assert 1 in tiers and 2 in tiers, tiers
    # Within a tier the offsets stay ascending (stable order).
    for want in (1, 2):
        offs = [r.offset for r, t in zip(rt.rounds, tiers) if t == want]
        assert offs == sorted(offs)
    # Flat order without the tier hint (the single-pod mesh).
    rt_flat = exchange_lib.build_routing(
        full, 8, exp_area_spikes=1.0, headroom=8.0, floor=2)
    assert [r.offset for r in rt_flat.rounds] == sorted(
        r.offset for r in rt_flat.rounds)
    # The ordering must not change what ships: same offsets, same bounds.
    rt_h = exchange_lib.build_routing(
        full, 8, exp_area_spikes=1.0, headroom=8.0, floor=2, intra_tier=4)
    assert ({(r.offset, r.pairs, r.s_max) for r in rt_h.rounds}
            == {(r.offset, r.pairs, r.s_max) for r in rt_flat.rounds})


def test_routed_single_group_mesh_runs_inprocess():
    """A 1x1 mesh degenerates routing to the group-local round (offset 0, no
    ppermute) -- the full packet/compaction/scatter path on one device,
    bitwise against the single-host reference."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4,
                              rate_hz=30.0)
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ref = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="conventional"), net=net)
    eng = make_simulation(spec, EngineConfig(
        neuron_model="ignore_and_fire", schedule="structure_aware",
        exchange="routed", s_max_floor=32), net=net, mesh=mesh)
    assert eng.wire_bytes["exchange"] == "routed"
    s0, st = ref.init(), eng.init()
    for w in range(6):
        s0, blk_ref = ref.window(s0)
        st, blk = eng.window(st)
        assert np.array_equal(np.asarray(blk).astype(bool),
                              np.asarray(blk_ref)), w
    assert np.array_equal(np.asarray(st.ring), np.asarray(s0.ring))
    assert int(st.overflow) == 0


def test_routed_validation():
    """Config- and build-time guards: routed needs the structure-aware
    schedule, and -- only when the sharded inbound tables are disabled --
    the replicated outgoing tables."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    with pytest.raises(ValueError):
        EngineConfig(schedule="conventional", exchange="routed")
    with pytest.raises(ValueError):
        EngineConfig(exchange="mesh")
    spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
    net = build_network(spec, seed=12, size_multiple=8)  # no outgoing tables
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # The legacy replicated receive path cannot exist without the outgoing
    # build; the default sharded path builds its inbound slices straight
    # from the incoming tensors, so outgoing=True is no longer required.
    with pytest.raises(ValueError, match="outgoing"):
        make_simulation(spec, EngineConfig(
            exchange="routed", shard_inter_tables=False), net=net, mesh=mesh)
    eng = make_simulation(spec, EngineConfig(exchange="routed"), net=net, mesh=mesh)
    assert eng.wire_bytes["exchange"] == "routed"
    with pytest.raises(ValueError, match="mesh"):
        make_simulation(spec, EngineConfig(exchange="dense"), net=net)


def test_build_routing_skips_rounds_and_bounds_edges():
    """Host-only routing-table checks: a sparse ring graph needs few
    rotation offsets, the all-to-all graph needs all of them, and per-edge
    packet bounds scale with the number of projecting source areas."""
    from repro.core import exchange as exchange_lib
    from repro.core.areas import ring_area_adjacency

    a, g = 16, 8
    sparse = np.asarray(ring_area_adjacency(a, width=1), dtype=bool)
    rt = exchange_lib.build_routing(
        sparse, g, exp_area_spikes=1.0, headroom=8.0, floor=2)
    # A width-1 ring over 2-area groups touches only offsets 0 and 1.
    assert {r.offset for r in rt.rounds} == {0, 1}
    assert rt.n_wire_rounds == 1
    full = ~np.eye(a, dtype=bool)
    rt_full = exchange_lib.build_routing(
        full, g, exp_area_spikes=1.0, headroom=8.0, floor=2)
    assert {r.offset for r in rt_full.rounds} == set(range(g))
    assert rt_full.n_wire_rounds == g - 1
    # Fuller edges (2 projecting areas) must get bigger packets than the
    # ring's single-area edges.
    s_sparse = {r.offset: r.s_max for r in rt.rounds}
    s_full = {r.offset: r.s_max for r in rt_full.rounds}
    assert s_full[1] > s_sparse[1]


def test_wire_report_routed_beats_dense_on_sparse_graph():
    """The static accounting that feeds BENCH_delivery.json: strictly fewer
    global bytes and fewer rounds on a sparse graph, honest (possibly
    larger) numbers on the all-to-all default."""
    from repro.core import exchange as exchange_lib
    from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
    from repro.core.connectivity import area_adjacency, build_network

    spec = mam_benchmark_spec(n_areas=8, n_per_area=64, k_intra=4, k_inter=4,
                              area_adjacency=ring_area_adjacency(8, width=2))
    net = build_network(spec, seed=12, outgoing=True)
    rep = exchange_lib.wire_report(
        net, area_adjacency(net, spec), backend="event", n_groups=4, gsz=2)
    assert rep["routed"]["global_bytes"] < rep["dense"]["global_bytes"]
    assert rep["routed"]["rounds"] < rep["routed"]["dense_rounds"]
    assert rep["routed"]["local_bytes"] == rep["dense"]["local_bytes"]


def test_cost_model_prices_wire_counters():
    """The exchange wire counters feed simulate_rtf's communication term:
    strictly fewer routed bytes must price out as a strictly cheaper
    communicate RTF (same workload, same seed)."""
    from repro.core import cost_model as cm
    from repro.core import exchange as exchange_lib
    from repro.core.areas import mam_benchmark_spec, ring_area_adjacency
    from repro.core.connectivity import area_adjacency, build_network

    spec = mam_benchmark_spec(n_areas=8, n_per_area=64, k_intra=8, k_inter=8,
                              area_adjacency=ring_area_adjacency(8, width=2))
    net = build_network(spec, seed=12, outgoing=True)
    rep = exchange_lib.wire_report(
        net, area_adjacency(net, spec), backend="event", n_groups=8, gsz=2)
    wl = cm.WorkloadModel(n_m=64, k_n=16)
    rtf = {
        name: cm.simulate_rtf(
            wl, cm.SUPERMUC, 16, "structure_aware", seed=3,
            bytes_per_window=rep[name]["total_bytes"]).communicate
        for name in ("dense", "routed")
    }
    assert rep["routed"]["total_bytes"] < rep["dense"]["total_bytes"]
    assert rtf["routed"] < rtf["dense"]


def test_network_sds_outgoing_mirrors_build():
    """Satellite: the dry-run stand-in now carries the outgoing-table leaves
    (with a deterministic width bound), so the event backend and routed
    exchange lower at production scale; spec pspecs must cover them."""
    import jax

    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import build_network, network_sds

    spec = mam_benchmark_spec(n_areas=4, n_per_area=48, k_intra=8, k_inter=8)
    sds = network_sds(spec, size_multiple=8, outgoing=True)
    real = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    for name in ("tgt_intra", "wout_intra", "dout_intra",
                 "tgt_inter", "wout_inter", "dout_inter"):
        leaf, ref = getattr(sds, name), getattr(real, name)
        assert leaf is not None, name
        assert leaf.dtype == ref.dtype, name
        assert leaf.shape[:2] == ref.shape[:2], name
        # The SDS width is a deterministic *bound* on the data-dependent one.
        assert leaf.shape[2] >= ref.shape[2], name
    assert network_sds(spec, outgoing=False).tgt_intra is None
    # The sharded variant (the dry-run's default since the sharded-table
    # PR): inbound [S, A*n_pad, K_in] stand-ins whose width bounds the
    # instantiated per-shard width, replicated inter tables dropped.
    from repro.core.connectivity import shard_inter_tables

    sds_sh = network_sds(spec, size_multiple=8, outgoing=True,
                         inter_shards=2)
    real_sh = shard_inter_tables(real, 2, mode="group")
    assert sds_sh.tgt_inter is None and sds_sh.inter_shard_mode == "group"
    for name in ("tgt_inter_in", "wout_inter_in", "dout_inter_in"):
        leaf, ref = getattr(sds_sh, name), getattr(real_sh, name)
        assert leaf.dtype == ref.dtype, name
        assert leaf.shape[:2] == ref.shape[:2], name
        assert leaf.shape[2] >= ref.shape[2], name
    # The stand-in must lower the event window through shard_map like the
    # dry-run does (1x1 mesh here; dryrun.py forces the production meshes).
    from jax.sharding import NamedSharding
    from repro.core.dist_engine import network_pspecs, state_pspecs
    from repro.core.factory import make_simulation
    from repro.core.engine import EngineConfig, SimState
    from repro.core import neuron as neuron_lib

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = EngineConfig(neuron_model="lif", schedule="structure_aware",
                       delivery_backend="event", exchange="routed")
    sds = network_sds(spec, size_multiple=8, outgoing=True, inter_shards=1)
    eng = make_simulation(spec, cfg, net=sds, mesh=mesh)
    A, n_pad = sds.alive.shape
    s = jax.ShapeDtypeStruct
    st_specs = state_pspecs(mesh, cfg.schedule, cfg.neuron_model)

    def shard(sd, spec_):
        return s(sd.shape, sd.dtype, sharding=NamedSharding(mesh, spec_))

    state_sds = SimState(
        neuron=neuron_lib.LIFState(
            v=shard(s((A, n_pad), "float32"), st_specs.neuron.v),
            i_syn=shard(s((A, n_pad), "float32"), st_specs.neuron.i_syn),
            refrac=shard(s((A, n_pad), "int32"), st_specs.neuron.refrac),
        ),
        ring=shard(s((A, n_pad, sds.ring_len), "float32"), st_specs.ring),
        t=s((), "int32"),
        spike_count=shard(s((A, n_pad), "int32"), st_specs.spike_count),
        overflow=s((), "int32"),
        shipped_bytes=s((), "float32"),
    )
    nt_specs = network_pspecs(mesh, cfg.schedule, like=sds)
    net_in = jax.tree.map(
        lambda leaf, spec_: shard(leaf, spec_), sds, nt_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct,
                                         type(st_specs.t))),
    )
    gids_sds = shard(s((A, n_pad), "int32"), st_specs.spike_count)
    lowered = jax.jit(eng.window_raw).lower(state_sds, net_in, gids_sds)
    assert "ppermute" in lowered.as_text() or True  # lowering must succeed
    lowered.compile()
