"""Optimizer, compression and hierarchical-sync unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compress import ef_compress, int8_decode, int8_encode
from repro.optim.hierarchical import Hierarchical, HierarchicalConfig


def test_adamw_minimises_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, metrics = adamw_update(g, opt, params, cfg)
    assert float(loss_fn(params)) < 1e-3
    assert int(opt["count"]) == 200


def test_adamw_grad_clip():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 1e6)}
    p1, _, metrics = adamw_update(g, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip
    assert float(jnp.abs(p1["w"]).max()) < 1.0  # update stayed bounded


def test_adamw_moment_dtype():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8,), jnp.bfloat16)}
    _, opt2, _ = adamw_update(g, opt, params, cfg)
    assert opt2["v"]["w"].dtype == jnp.bfloat16


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_int8_encode_decode():
    x = jnp.asarray([-1.0, 0.0, 0.5, 1.0])
    q, s = int8_encode(x)
    y = int8_decode(q, s)
    assert float(jnp.abs(y - x).max()) < 1e-2
    assert q.dtype == jnp.int8


def test_ef_compress_none_passthrough():
    x = jnp.asarray([1.0, 2.0])
    ef = jnp.zeros(2)
    dec, new_ef, wire = ef_compress(x, ef, "none")
    assert np.array_equal(np.asarray(dec), np.asarray(x))
    assert wire is None


def test_hierarchical_replicate_and_pspecs():
    from jax.sharding import PartitionSpec as P

    hier = Hierarchical(HierarchicalConfig(sync_every=5), n_pods=3)
    tree = {"w": jnp.ones((4, 2))}
    rep = hier.replicate(tree)
    assert rep["w"].shape == (3, 4, 2)
    specs = hier.pspecs({"w": P("data", "model")})
    assert specs["w"] == P("pod", "data", "model")


def test_hierarchical_sync_uncompressed_fixed_point():
    """Identical replicas are a fixed point; diverged replicas average."""
    hier = Hierarchical(HierarchicalConfig(), n_pods=2)
    params = {"w": jnp.asarray([1.0, 3.0])}
    state = hier.init_sync_state(params)
    pods = {"w": jnp.asarray([[0.0, 2.0], [2.0, 4.0]])}
    synced, state = hier.sync_step(pods, state)
    assert np.allclose(np.asarray(synced["w"]), [[1.0, 3.0], [1.0, 3.0]])
    again, _ = hier.sync_step(synced, state)
    assert np.allclose(np.asarray(again["w"]), np.asarray(synced["w"]))


def test_hierarchical_sync_int8_converges():
    """Compressed sync approaches the true mean; EF keeps residuals bounded."""
    hier = Hierarchical(HierarchicalConfig(compression="int8"), n_pods=2)
    params = {"w": jnp.zeros(8)}
    state = hier.init_sync_state(params)
    rng = np.random.default_rng(0)
    pods = {"w": jnp.asarray(rng.normal(0, 1, (2, 8)), jnp.float32)}
    true_mean = np.asarray(pods["w"]).mean(axis=0)
    synced, state = hier.sync_step(pods, state)
    got = np.asarray(synced["w"][0])
    assert np.abs(got - true_mean).max() < 0.02
    # residuals bounded by the int8 step size
    assert np.abs(np.asarray(state["ef"]["w"])).max() < 0.02


def test_elastic_pod_resize():
    from repro.checkpoint.manager import elastic_pod_resize

    pods = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    resized = elastic_pod_resize(pods, 4)
    assert resized["w"].shape == (4, 2)
    assert np.allclose(np.asarray(resized["w"]), [[2.0, 3.0]] * 4)


def test_hierarchical_sync_drops_straggler_pod():
    """A dead/straggling pod is excluded from the average and re-joins with
    the synced parameters (elastic straggler mitigation)."""
    hier = Hierarchical(HierarchicalConfig(), n_pods=3)
    params = {"w": jnp.asarray([0.0, 0.0])}
    state = hier.init_sync_state(params)
    pods = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0], [100.0, -100.0]])}
    live = jnp.asarray([True, True, False])  # pod 2 is a straggler
    synced, _ = hier.sync_step(pods, state, live=live)
    assert np.allclose(np.asarray(synced["w"]),
                       [[2.0, 2.0]] * 3), "straggler must not poison the mean"
