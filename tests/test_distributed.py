"""Distributed tests: run in a subprocess with 8 forced host devices.

Per the launch contract, only the dry-run (and these subprocesses) force a
device count -- the main pytest process must keep seeing one device, so each
test spawns ``python -c`` with XLA_FLAGS set in its environment.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_dist_engine_equivalence_both_schedules():
    """Distributed engines (2x4 mesh) == single-host reference, bitwise."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
        net = build_network(spec, seed=12, size_multiple=8)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for model in ("ignore_and_fire", "lif"):
            ref = make_simulation(spec, EngineConfig(neuron_model=model,
                                                      schedule="conventional"), net=net)
            for sched in ("structure_aware", "conventional"):
                eng = make_simulation(spec, EngineConfig(neuron_model=model,
                                                    schedule=sched), net=net, mesh=mesh)
                st, s0 = eng.init(), ref.init()
                for w in range(8):
                    s0, blk_ref = ref.window(s0)
                    st, blk = eng.window(st)
                    assert np.array_equal(np.asarray(blk).astype(bool),
                                          np.asarray(blk_ref)), (model, sched, w)
        print("OK")
    """))


def test_dist_engine_delivery_backend_equivalence():
    """Tentpole: every delivery backend, run through the shard_map window
    bodies (2x4 mesh), reproduces the single-host reference bitwise -- under
    both the fused D-cycle superstep (default: blocked ring access +
    single-pass blocked receive of the lumped exchange) and the legacy
    per-cycle window. The event backend exchanges sparse id packets instead
    of dense vectors and must report zero overflow."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks = []
        for _ in range(6):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        assert sum(b.sum() for b in blocks) > 0
        cases = [(b, sched, None) for b in ("scatter", "pallas", "event")
                 for sched in ("structure_aware", "conventional")]
        # The legacy (superstep=False) windows must stay equivalent too.
        cases += [("event", "structure_aware", False),
                  ("scatter", "structure_aware", False)]
        for backend, sched, superstep in cases:
            eng = make_simulation(spec, EngineConfig(
                                       neuron_model="ignore_and_fire",
                                       schedule=sched,
                                       delivery_backend=backend,
                                       s_max_floor=32,
                                       superstep=superstep), net=net, mesh=mesh)
            st = eng.init()
            for w in range(6):
                st, blk = eng.window(st)
                assert np.array_equal(np.asarray(blk).astype(bool),
                                      blocks[w]), (backend, sched, w)
            assert int(st.overflow) == 0, (backend, sched)
        print("OK")
    """))


def test_dist_engine_multi_pod_mesh():
    """The 3-axis (pod, data, model) mesh also reproduces the reference."""
    print(_run("""
        import numpy as np, jax
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import build_network
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4, k_inter=4)
        net = build_network(spec, seed=654, size_multiple=8)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ref = make_simulation(spec, EngineConfig(schedule="conventional",
                                                  neuron_model="lif"), net=net)
        eng = make_simulation(spec, EngineConfig(schedule="structure_aware",
                                            neuron_model="lif"), net=net, mesh=mesh)
        st, s0 = eng.init(), ref.init()
        for w in range(6):
            s0, blk_ref = ref.window(s0)
            st, blk = eng.window(st)
            assert np.array_equal(np.asarray(blk).astype(bool),
                                  np.asarray(blk_ref)), w
        print("OK")
    """))


def test_hierarchical_trainer_local_steps_and_sync():
    """Per-pod local steps diverge; the D-step sync re-converges replicas.
    With int8+EF compression the sync stays within quantisation error."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.registry import get_arch
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.optim.hierarchical import Hierarchical, HierarchicalConfig
        from repro.train.steps import make_train_artifacts
        from repro.configs.common import ShapeSpec

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        bundle = get_arch("qwen2-0.5b", reduced=True)
        art = make_train_artifacts(
            bundle, mesh=mesh, batch_axes=("data",), fsdp_axis=None,
            hier_cfg=HierarchicalConfig(sync_every=4, compression="int8"),
        )
        hier = art.hier
        params = bundle.model.init_params(jax.random.PRNGKey(0))
        pparams = hier.replicate(params)
        popt = hier.replicate(adamw_init(params, AdamWConfig()))
        sync_state = hier.init_sync_state(params)

        rng = np.random.default_rng(0)
        def batch(step):
            toks = rng.integers(0, 64, (2, 8, 16))  # [pods, B/pod, S]
            return {"tokens": jnp.asarray(toks, jnp.int32),
                    "labels": jnp.asarray(toks, jnp.int32)}

        for step in range(4):
            pparams, popt, metrics = art.step_fn(pparams, popt, batch(step))
        # replicas must now differ (different pod data)
        leaf = jax.tree.leaves(pparams)[1]
        assert float(jnp.abs(leaf[0] - leaf[1]).max()) > 0
        pparams, sync_state = art.sync_fn(pparams, sync_state)
        for x in jax.tree.leaves(pparams):
            assert np.allclose(np.asarray(x[0]), np.asarray(x[1])), "not synced"
        print("losses:", [float(v) for v in np.atleast_1d(metrics["loss"])])
        print("OK")
    """))


def test_host_batch_sharding():
    print(_run("""
        import numpy as np, jax
        from repro.data.pipeline import SyntheticLM, host_batch

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ds = SyntheticLM(vocab=64, seq_len=16, global_batch=8)
        b = ds.batch(0)
        sharded = host_batch(b, mesh, batch_axes=("data",), pod_axis="pod")
        assert sharded["tokens"].shape == (2, 4, 16)
        flat = np.asarray(sharded["tokens"]).reshape(8, 16)
        assert np.array_equal(flat, b["tokens"]), "sharding must not reorder"
        print("OK")
    """))


def test_moe_expert_parallel_lowering():
    """EP dispatch lowers with experts sharded over 'model' (all-to-alls)."""
    print(_run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.models.moe import MoEConfig, moe_apply, moe_init, moe_pspecs

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(n_experts=8, top_k=1, d_ff=32, expert_sharding="ep")
        p = moe_init(jax.random.PRNGKey(0), 16, cfg)
        specs = moe_pspecs(cfg, fsdp="data", tp="model")
        p = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), p, specs,
            is_leaf=lambda x: isinstance(x, (jax.Array, P)))
        x = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16)),
            NamedSharding(mesh, P("data", None, None)))
        with compat.set_mesh(mesh):
            y, aux = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
        assert y.shape == x.shape
        print("OK")
    """))


def test_pipeline_parallel_matches_sequential():
    """GPipe wrapper == sequential stage application (4-stage pipe)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.train.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("pipe",))
        S, M, mb, d = 4, 6, 2, 8
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, d, d)) * 0.3
        params = {"w": w}

        def stage(p, x):
            return jnp.tanh(x @ p["w"])

        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        with compat.set_mesh(mesh):
            got = pipeline_apply(stage, params, x, mesh)
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ w[s])
        assert np.allclose(np.asarray(got), np.asarray(ref), atol=1e-5), \
            float(jnp.abs(got - ref).max())
        print("OK")
    """, n_devices=4))
