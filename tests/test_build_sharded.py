"""Host-free sharded construction: the counter-based build tentpole.

The contract: ``build_network``'s per-pathway draws are pure functions of
``(seed, pathway, row)``, so any shard can regenerate exactly its own
inbound inter slice and lane-cut intra tables -- bitwise-identical to
slicing the host-built global network -- without any process ever
materialising the global ``src_inter/w_inter/delay_inter`` tensors. The
layout half (plan widths, per-shard builders vs the host cuts) runs in the
main process; the distributed half (``build_network_sharded`` engines vs a
single-host host-built reference) runs in subprocesses with 8 forced host
devices, per the launch contract.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _spec(**kw):
    from repro.core.areas import mam_benchmark_spec

    kw.setdefault("n_areas", 4)
    kw.setdefault("n_per_area", 64)
    kw.setdefault("k_intra", 8)
    kw.setdefault("k_inter", 12)
    return mam_benchmark_spec(**kw)


def test_counter_draws_match_host_build_rows():
    """Any subset of rows, in any order, regenerates exactly the host-built
    global tensors' rows -- the init-sharding property the whole tentpole
    rests on (each synapse is a pure function of (seed, pathway, row, k))."""
    from repro.core.connectivity import build_network, draw_pathway_rows

    spec = _spec()
    net = build_network(spec, seed=12, size_multiple=8)
    A, n_pad, _ = net.src_intra.shape
    full = np.arange(A * n_pad, dtype=np.int64)
    rng = np.random.default_rng(0)
    for rows in (full, full[::3], rng.permutation(full)[:50]):
        for pathway, (s_g, w_g, d_g) in (
            ("intra", (net.src_intra, net.w_intra, net.delay_intra)),
            ("inter", (net.src_inter, net.w_inter, net.delay_inter)),
        ):
            s, w, d = draw_pathway_rows(
                spec, 12, rows, pathway=pathway, size_multiple=8)
            a, r = rows // n_pad, rows % n_pad
            assert np.array_equal(s, np.asarray(s_g)[a, r])
            assert np.array_equal(w, np.asarray(w_g)[a, r])
            assert np.array_equal(d, np.asarray(d_g)[a, r])
            assert d.dtype == np.asarray(d_g).dtype


def test_plan_matches_host_built_widths_and_metadata():
    """Pass 1's streamed global counts reproduce the host build's padded
    table widths, delay windows and realized area adjacency exactly -- so a
    sharded build compiles to the same shapes a host build would."""
    from repro.core.connectivity import (
        area_adjacency, build_network, shard_inter_tables,
        sharded_build_plan, slice_intra_tables)

    spec = _spec()
    S, sub = 4, 2
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    cut = slice_intra_tables(
        shard_inter_tables(net, S, mode="group", subgroup=sub), sub)
    plan = sharded_build_plan(spec, 12, S, mode="group", subgroup=sub,
                              size_multiple=8)
    assert plan.k_in == cut.tgt_inter_in.shape[-1]
    assert plan.k_lane_intra == cut.tgt_intra.shape[-1]
    assert plan.k_out_intra == net.tgt_intra.shape[-1]
    assert (plan.steps_lo_intra, plan.r_span_intra) == (
        net.steps_lo_intra, net.r_span_intra)
    assert (plan.steps_lo_inter, plan.r_span_inter) == (
        net.steps_lo_inter, net.r_span_inter)
    assert np.array_equal(np.asarray(plan.area_adj, dtype=bool),
                          area_adjacency(net, spec))


@pytest.mark.parametrize("layout", [(4, 1), (4, 2), (2, 4)])
def test_shard_tables_bitwise_vs_host_cut(layout):
    """Pass 2: every (shard, lane)'s regenerated inbound inter slice and
    lane intra tables are bitwise-identical to the host-built network's
    cuts, including the narrow delay dtype."""
    from repro.core.connectivity import (
        build_lane_intra_tables, build_network, build_shard_tables,
        shard_inter_tables, sharded_build_plan, slice_intra_tables)

    S, sub = layout
    spec = _spec()
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    cut = shard_inter_tables(net, S, mode="group", subgroup=sub)
    plan = sharded_build_plan(spec, 12, S, mode="group", subgroup=sub,
                              size_multiple=8)
    a_loc = spec.n_areas // S
    for s in range(S):
        for lane in range(sub):
            t, w, d = build_shard_tables(spec, 12, s, plan=plan, lane=lane)
            host = cut.tgt_inter_in[s, lane] if sub > 1 else \
                cut.tgt_inter_in[s]
            hw = cut.wout_inter_in[s, lane] if sub > 1 else \
                cut.wout_inter_in[s]
            hd = cut.dout_inter_in[s, lane] if sub > 1 else \
                cut.dout_inter_in[s]
            assert np.array_equal(t, np.asarray(host)), (s, lane)
            assert np.array_equal(w, np.asarray(hw)), (s, lane)
            assert np.array_equal(d, np.asarray(hd)), (s, lane)
            assert d.dtype == np.asarray(hd).dtype
        if sub > 1:
            cut_i = slice_intra_tables(net, sub)
            areas = list(range(s * a_loc, (s + 1) * a_loc))
            for lane in range(sub):
                ti, wi, di = build_lane_intra_tables(
                    spec, 12, areas, lane, plan=plan)
                assert np.array_equal(
                    ti, np.asarray(cut_i.tgt_intra[lane])[areas]), (s, lane)
                assert np.array_equal(
                    wi, np.asarray(cut_i.wout_intra[lane])[areas])
                assert np.array_equal(
                    di, np.asarray(cut_i.dout_intra[lane])[areas])
                assert di.dtype == np.asarray(cut_i.dout_intra).dtype


def test_window_mode_and_group_intra_tables():
    """The conventional 'window' cut and the subgroup==1 outgoing intra
    builder get the same bitwise guarantee."""
    from repro.core.connectivity import (
        build_group_intra_tables, build_network, build_shard_tables,
        shard_inter_tables, sharded_build_plan)

    spec = _spec()
    S = 4
    net = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    cut = shard_inter_tables(net, S, mode="window")
    plan = sharded_build_plan(spec, 12, S, mode="window", size_multiple=8)
    for s in range(S):
        t, w, d = build_shard_tables(spec, 12, s, plan=plan)
        assert np.array_equal(t, np.asarray(cut.tgt_inter_in[s])), s
        assert np.array_equal(w, np.asarray(cut.wout_inter_in[s])), s
        assert np.array_equal(d, np.asarray(cut.dout_inter_in[s])), s
    plan_g = sharded_build_plan(spec, 12, 2, mode="group", size_multiple=8)
    areas = [1, 3]
    ti, wi, di = build_group_intra_tables(spec, 12, areas, plan=plan_g)
    assert np.array_equal(ti, np.asarray(net.tgt_intra)[areas])
    assert np.array_equal(wi, np.asarray(net.wout_intra)[areas])
    assert np.array_equal(di, np.asarray(net.dout_intra)[areas])


def test_outgoing_intra_skips_inter_inversion():
    """build_network(outgoing='intra') gives the intra tables the bounds
    verify needs without paying the dense outgoing inter inversion -- and
    the tensors it does build match outgoing=True bitwise."""
    from repro.core.connectivity import build_network

    spec = _spec()
    full = build_network(spec, seed=12, size_multiple=8, outgoing=True)
    lean = build_network(spec, seed=12, size_multiple=8, outgoing="intra")
    assert lean.tgt_inter is None and lean.wout_inter is None
    assert full.tgt_inter is not None
    assert np.array_equal(np.asarray(lean.tgt_intra),
                          np.asarray(full.tgt_intra))
    assert np.array_equal(np.asarray(lean.src_inter),
                          np.asarray(full.src_inter))
    with pytest.raises(ValueError):
        build_network(spec, seed=12, outgoing="bogus")


def test_k_inter_zero_edge():
    """K_e == 0: the plan degenerates cleanly and the shard builder returns
    width-0 tables matching the host build's empty inter pathway."""
    from repro.core.connectivity import (
        build_network, build_shard_tables, sharded_build_plan)

    spec = _spec(n_areas=2, k_inter=0)
    net = build_network(spec, seed=12, size_multiple=8)
    plan = sharded_build_plan(spec, 12, 2, mode="group", size_multiple=8)
    assert plan.k_in == 0
    assert plan.r_span_inter == net.r_span_inter == 0
    t, w, d = build_shard_tables(spec, 12, 0, plan=plan)
    assert t.shape[-1] == 0 and w.shape[-1] == 0 and d.shape[-1] == 0


def test_plan_and_config_validation():
    """Divisibility / mode errors at plan time; EngineConfig.sharded_build
    is refused off the event backend, off structure_aware, without sharded
    tables, and by the single-host engine (which holds the whole network
    anyway)."""
    from repro.core.connectivity import build_network, sharded_build_plan
    from repro.core.engine import EngineConfig
    from repro.core.factory import make_simulation

    spec = _spec()
    with pytest.raises(ValueError):
        sharded_build_plan(spec, 12, 3, mode="group")  # 3 does not divide 4
    with pytest.raises(ValueError):
        sharded_build_plan(spec, 12, 2, mode="bogus")
    with pytest.raises(ValueError):
        sharded_build_plan(spec, 12, 2, mode="window", subgroup=2)
    with pytest.raises(ValueError):
        EngineConfig(delivery_backend="scatter", sharded_build=True)
    with pytest.raises(ValueError):
        EngineConfig(delivery_backend="event", schedule="conventional",
                     sharded_build=True)
    with pytest.raises(ValueError):
        EngineConfig(delivery_backend="event", shard_inter_tables=False,
                     sharded_build=True)
    cfg = EngineConfig(delivery_backend="event", sharded_build=True,
                       neuron_model="ignore_and_fire")
    net = build_network(spec, seed=12, outgoing=True)
    with pytest.raises(ValueError, match="single-host"):
        make_simulation(spec, cfg, net=net)


@pytest.mark.parametrize("exchange", ["dense", "routed"])
def test_sharded_built_engine_bitwise_vs_host(exchange):
    """Acceptance matrix on 8 forced host devices: engines whose tables
    come from build_network_sharded (no global inter tensors ever
    materialised) reproduce the host-built single-host reference bitwise --
    spike blocks AND rings -- under {static,adaptive} x {superstep,legacy},
    with zero overflow; the sharded-built Network's tables equal the
    host-built shard cuts leaf for leaf."""
    print(_run(f"""
        import numpy as np, jax
        import dataclasses
        from repro.core.areas import mam_benchmark_spec
        from repro.core.connectivity import (
            build_network, shard_inter_tables, slice_intra_tables)
        from repro.core.engine import EngineConfig
        from repro.core.factory import make_simulation
        from repro.core.dist_engine import build_network_sharded

        spec = mam_benchmark_spec(n_areas=4, n_per_area=32, k_intra=4,
                                  k_inter=4, rate_hz=30.0)
        net = build_network(spec, seed=12, size_multiple=8)
        ref = make_simulation(spec, EngineConfig(
            neuron_model="ignore_and_fire", schedule="conventional"), net=net)
        s0 = ref.init()
        blocks = []
        for _ in range(4):
            s0, b = ref.window(s0)
            blocks.append(np.asarray(b))
        ring_ref = np.asarray(s0.ring)
        assert sum(b.sum() for b in blocks) > 0

        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def cfg(adaptive=False, superstep=None):
            return EngineConfig(
                neuron_model="ignore_and_fire",
                schedule="structure_aware", delivery_backend="event",
                exchange={exchange!r}, s_max_floor=32,
                sharded_build=True,
                adaptive_exchange=adaptive, superstep=superstep)

        # The sharded-built Network's tables == the host-built shard cuts.
        snet = build_network_sharded(spec, mesh, cfg(), seed=12)
        host = build_network(spec, seed=12, size_multiple=8, outgoing=True)
        hcut = slice_intra_tables(
            shard_inter_tables(host, 4, mode="group", subgroup=2), 2)
        for name in ("tgt_inter_in", "wout_inter_in", "dout_inter_in",
                     "tgt_intra", "wout_intra", "dout_intra",
                     "alive", "rate_hz"):
            a = np.asarray(getattr(snet, name))
            b = np.asarray(getattr(hcut, name))
            assert a.dtype == b.dtype, name
            assert np.array_equal(a, b), name
        assert snet.src_inter.shape[0] == 0  # never materialised globally
        for f in ("steps_lo_intra", "r_span_intra", "steps_lo_inter",
                  "r_span_inter", "ring_len", "delay_ratio"):
            assert getattr(snet, f) == getattr(host, f), f

        # net=None: the engine builds its own tables host-free.
        for adaptive in (False, True):
            for superstep in (None, False):
                eng = make_simulation(spec, cfg(adaptive, superstep), net=None, mesh=mesh, build_seed=12)
                st = eng.init()
                for w in range(4):
                    st, blk = eng.window(st)
                    assert np.array_equal(
                        np.asarray(blk).astype(bool), blocks[w]
                    ), (adaptive, superstep, w)
                assert np.array_equal(np.asarray(st.ring), ring_ref), (
                    adaptive, superstep, "ring")
                assert int(st.overflow) == 0, (adaptive, superstep)
        print("sharded-build matrix OK:", {exchange!r})
    """))
