"""Gradient/delta compression for the slow (cross-pod) tier.

Mirrors the paper's local/global asymmetry: the fast tier (intra-pod) stays
exact; only the rare cross-pod exchange is compressed. Error feedback keeps
the compression unbiased over time (the residual is re-injected next sync).

* int8: per-tensor absmax scaling, 4x wire reduction vs f32 (2x vs bf16).
* top-k: magnitude sparsification to a fraction of entries.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "int8_encode",
    "int8_decode",
    "topk_mask",
    "ef_compress",
]


def int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    """Boolean mask keeping the top ``frac`` entries by magnitude."""
    flat = jnp.abs(x.reshape(-1).astype(jnp.float32))
    k = max(1, int(frac * flat.size))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x.astype(jnp.float32)) >= thresh).reshape(x.shape)


def ef_compress(
    x: jax.Array, ef: jax.Array, method: str, topk_frac: float = 0.01
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Error-feedback compression of one tensor.

    Returns (decoded payload as seen by receivers, new error residual,
    wire tensor for byte accounting or None for 'none').
    """
    if method == "none":
        return x, jnp.zeros_like(ef), None
    y = x.astype(jnp.float32) + ef.astype(jnp.float32)
    if method == "int8":
        q, scale = int8_encode(y)
        dec = int8_decode(q, scale)
        return dec.astype(x.dtype), (y - dec).astype(ef.dtype), q
    if method == "topk":
        mask = topk_mask(y, topk_frac)
        dec = jnp.where(mask, y, 0.0)
        return dec.astype(x.dtype), (y - dec).astype(ef.dtype), dec
    raise ValueError(f"unknown compression method {method!r}")
