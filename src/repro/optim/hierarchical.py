"""Hierarchical (two-tier) synchronization -- the paper's technique applied to
distributed training.

The paper's insight: when the interaction graph has a two-scale delay
structure, align the partition with the structure and synchronize the slow
tier D-times less often (global communication every D-th cycle). For training
on a (pod, data, model) mesh the transfer is:

* fast tier  = intra-pod data parallelism: exact gradient all-reduce every
  step (over 'data'), exactly like the paper's per-cycle local exchange;
* slow tier  = cross-pod synchronization every D steps: each pod runs local
  optimizer steps on its own parameter replica; every D-th step the replicas
  are averaged across pods (optionally int8-compressed with error feedback --
  the slow tier tolerates approximation, the fast tier stays exact).

Implementation is pjit-native: every state leaf gains a leading [n_pods] axis
sharded over 'pod', and the local step is ``vmap`` over it -- so the compiled
local step contains *zero* 'pod'-axis collectives (verifiable in the dry-run
HLO), while the sync step contains exactly one. The 1/sqrt(D) jitter-
absorption argument of paper §2.2 applies to the slow tier verbatim.

Compressed sync protocol (anchor-based, int8 on the wire):
every pod keeps the last synced parameters (``anchor``, identical across
pods). At sync, each pod int8-encodes (delta + error residual) from the
anchor; the *int8* tensors are replicated across pods (that is the only
cross-pod transfer -- forced by a sharding constraint so the dry-run HLO
carries honest byte counts); each pod decodes, averages, and advances the
anchor. Error feedback re-injects the truncation at the next sync.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import compress

__all__ = ["HierarchicalConfig", "Hierarchical"]


@dataclasses.dataclass(frozen=True)
class HierarchicalConfig:
    sync_every: int = 10        # D: slow-tier period (paper eq. (1))
    pod_axis: str = "pod"
    compression: str = "none"   # 'none' | 'int8' (slow tier only)

    def __post_init__(self) -> None:
        if self.compression not in ("none", "int8"):
            raise ValueError(f"unknown compression {self.compression!r}")


class Hierarchical:
    """Per-pod replica management + the two sync tiers."""

    def __init__(self, cfg: HierarchicalConfig, n_pods: int,
                 mesh: Mesh | None = None, param_specs: Any = None):
        self.cfg = cfg
        self.n_pods = n_pods
        self.mesh = mesh
        # Per-leaf PartitionSpecs WITHOUT the pod axis: the compressed sync
        # must only un-shard 'pod' (the slow tier); FSDP/TP shardings of the
        # other axes stay intact on the wire tensors.
        self.param_specs = param_specs

    # -- state ----------------------------------------------------------------

    def replicate(self, tree: Any) -> Any:
        """Add the leading [n_pods] axis (same initial value in every pod)."""
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_pods,) + x.shape), tree
        )

    def pspecs(self, tree_specs: Any) -> Any:
        """Prefix every leaf spec with the pod axis."""
        return jax.tree.map(
            lambda s: P(self.cfg.pod_axis, *s), tree_specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    def init_sync_state(self, params: Any) -> dict:
        """anchor = last synced params (no pod axis); ef = per-pod residuals."""
        state = {"anchor": params}
        if self.cfg.compression != "none":
            state["ef"] = jax.tree.map(
                lambda x: jnp.zeros((self.n_pods,) + x.shape, jnp.float32), params
            )
        return state

    # -- steps ----------------------------------------------------------------

    def local_step(self, step_fn: Callable) -> Callable:
        """vmap a per-pod step over the leading pod axis.

        ``step_fn(params, opt_state, batch) -> (params', opt_state', metrics)``
        becomes the same over [n_pods, ...] trees; batches carry a leading
        [n_pods] axis (the data pipeline shards by pod). No 'pod'-axis
        collective exists in the result -- the slow tier stays silent.
        """
        return jax.vmap(step_fn)

    def _replicate_over_pods(self, x: jax.Array, rest: P | None) -> jax.Array:
        """Force cross-pod replication (the wire transfer) via constraint.

        Only the leading pod axis un-shards; the remaining dims keep their
        FSDP/TP layout (``rest``) so the transfer is the int8 payload, not a
        full-mesh all-gather."""
        if self.mesh is None:
            return x
        tail = tuple(rest) if rest is not None else ()
        tail = tail + (None,) * (x.ndim - 1 - len(tail))
        spec = P(None, *tail)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def sync_step(self, params_pods: Any, sync_state: dict,
                  live: jax.Array | None = None) -> tuple[Any, dict]:
        """Slow tier: average replicas across pods (every D-th step).

        ``live`` ([n_pods] bool) drops straggling/failed pods from the
        average (the paper's own mechanism IS straggler absorption within a
        window; this extends it across windows: a pod that misses the
        rendezvous is excluded and re-joins at the next sync with the
        averaged parameters -- semantically one elastic resync)."""
        cfg = self.cfg
        if live is None:
            live = jnp.ones((self.n_pods,), bool)
        wts = live.astype(jnp.float32)
        wts = wts / jnp.maximum(wts.sum(), 1.0)

        if cfg.compression == "none":
            def avg(x):
                shape = (self.n_pods,) + (1,) * (x.ndim - 1)
                m = (x.astype(jnp.float32) * wts.reshape(shape)).sum(axis=0)
                return jnp.broadcast_to(m[None], x.shape).astype(x.dtype), m

            out = jax.tree.map(avg, params_pods)
            new_params = jax.tree.map(lambda t: t[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
            new_anchor = jax.tree.map(
                lambda t, a: t[1].astype(a.dtype), out, sync_state["anchor"],
                is_leaf=lambda x: isinstance(x, tuple))
            return new_params, {"anchor": new_anchor}

        def avg_int8(x, anchor, ef, rest_spec):
            delta = x.astype(jnp.float32) - anchor.astype(jnp.float32)[None]
            y = delta + ef
            q, scale = jax.vmap(compress.int8_encode)(y)   # [P,...] int8, [P]
            # The only cross-pod transfer: int8 payload + per-pod scales.
            q = self._replicate_over_pods(q, rest_spec)
            scale = self._replicate_over_pods(scale, None)
            dec = q.astype(jnp.float32) * scale.reshape(
                (self.n_pods,) + (1,) * (q.ndim - 1))
            wshape = (self.n_pods,) + (1,) * (dec.ndim - 1)
            new_anchor = anchor.astype(jnp.float32) + (
                dec * wts.reshape(wshape)).sum(axis=0)
            new_ef = y - dec
            new_x = jnp.broadcast_to(new_anchor[None], x.shape).astype(x.dtype)
            return new_x, new_anchor.astype(anchor.dtype), new_ef

        specs = self.param_specs
        if specs is None:
            specs = jax.tree.map(lambda _: None, params_pods)
        out = jax.tree.map(
            avg_int8, params_pods, sync_state["anchor"], sync_state["ef"],
            specs, is_leaf=lambda v: v is None or isinstance(v, P),
        )
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"anchor": pick(1), "ef": pick(2)}
