"""AdamW from scratch (pytree ops), with configurable moment dtype.

Moments inherit each parameter's sharding (the update is elementwise), so
FSDP-sharded params get FSDP-sharded optimizer state for free -- this is what
keeps the 400B-class configs inside the per-chip HBM budget (bf16 moments for
the giants; see configs/*.py ``moment_dtype``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100

    def schedule(self, step: jax.Array) -> jax.Array:
        warm = jnp.minimum(step.astype(jnp.float32) / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    md = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict[str, jax.Array]]:
    """One AdamW step. Returns (params', state', metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = cfg.schedule(count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    md = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step).astype(p.dtype),
            m32.astype(md),
            v32.astype(md),
        )

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
