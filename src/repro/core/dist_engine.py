"""Distributed engine: the shared window core on a (pod, data, model) mesh.

Placement:

* **structure-aware**: the area dimension ``A`` is sharded over the slow axes
  ``(pod, data)``; each area's ``n_pad`` neurons are sharded over the fast
  ``model`` axis (the intra-area device subgroup -- the paper's ``MPI_Group``
  generalisation). Per cycle only the subgroup communicates (local pathway);
  every D-th cycle the lumped ``[D, ...]`` spike block crosses the area-group
  graph (global pathway).

* **conventional**: the round-robin analogue -- every device hosts a slice of
  *every* area (``n_pad`` sharded over all axes). Perfect balance, zero
  structure: the full spike vector must be exchanged globally every cycle.

The window body itself lives in :mod:`repro.core.schedule` (shared with the
single-host engine -- superstep, legacy window and conventional scan
included); this module only validates the placement, selects the exchange
(``EngineConfig.exchange``) and wraps the body in ``shard_map``:

* ``'dense'`` (:class:`repro.core.exchange.DenseMeshExchange`): the dense
  backends exchange bit-packed spike vectors (``comm.gather_*``); the
  ``event`` backend compacts fired neurons into fixed-size *id packets*
  before each exchange (NEST's sparse wire format) and the receive side
  scatters the ids through this device's *sharded inbound* inter tables
  (``connectivity.shard_inter_tables`` -- only the ~1/S of edges the
  device owns; ``EngineConfig.shard_inter_tables=False`` keeps the legacy
  replicated tables as the equivalence reference). Either way the
  global pathway is a mesh-wide ``all_gather``: every device receives every
  fired id, even from areas that project nothing into its shard.

* ``'routed'`` (:class:`repro.core.exchange.RoutedExchange`): the global
  pathway mirrors network structure. The area->area adjacency computed at
  build time (:func:`repro.core.connectivity.area_adjacency`) is folded to
  the device-group graph; the window-end exchange ships id packets only
  along group->group edges that exist, via ``ppermute`` rotation rounds
  with per-edge ``s_max`` bounds. Sparse area graphs skip most rounds and
  ship strictly fewer bytes (see ``Engine.wire_bytes`` and
  ``benchmarks/bench_delivery.py``).

All exchanges produce spike trains bit-identical to the single-host
reference engine (tests/test_distributed.py, tests/test_exchange.py run them
in 8-device subprocesses). Packet bounds are static; spills are counted in
``SimState.overflow`` (any nonzero value means spikes were dropped and the
bounds must be raised).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.areas import MultiAreaSpec
from repro.core import connectivity as connectivity_lib
from repro.core.connectivity import Network
from repro.core import exchange as exchange_lib
from repro.core import neuron as neuron_lib
from repro.core import schedule as schedule_lib
from repro.core.engine import (
    CONVENTIONAL,
    STRUCTURE_AWARE,
    Engine,
    EngineConfig,
    SimState,
    make_fused_lif_update,
    resolve_params,
)

__all__ = [
    "make_dist_engine",
    "build_network_sharded",
    "network_pspecs",
    "state_pspecs",
    "shard_network",
]


def _area_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names[:-1])


def _subgroup_axis(mesh: Mesh) -> str:
    return mesh.axis_names[-1]


def network_pspecs(mesh: Mesh, schedule: str, like: Network | None = None) -> Network:
    """A Network-shaped pytree of PartitionSpecs for the given schedule.

    ``like`` supplies the static metadata fields (pytree structure must match
    exactly when used as shard_map in_specs). When ``like`` carries outgoing
    (event-path) tables: intra tables are replicated over the subgroup (each
    device scans its areas' complete fired lists); the *inbound* inter
    tables (``connectivity.shard_inter_tables``, the default assembly) are
    sharded over their leading shard axis -- the device-group grid under
    structure-aware placement, the full device grid under conventional --
    so each device holds only the ~1/S of inter edges it owns. Legacy
    replicated inter tables (``shard_inter_tables=False``, the equivalence
    reference) keep the NEST every-rank-holds-everything layout.
    """
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        syn = P(_area_axes(mesh), _subgroup_axis(mesh), None)
        out_intra = P(_area_axes(mesh), None, None)
        if like is not None and like.tgt_intra is not None \
                and like.tgt_intra.ndim == 4:
            # [gsz, A, n_pad, K_lane]: subgroup-sliced outgoing intra
            # tables (connectivity.slice_intra_tables) -- the leading lane
            # axis shards over the subgroup, so the local pathway's tables
            # stop being replicated across the gsz lanes of each group.
            out_intra = P(_subgroup_axis(mesh), _area_axes(mesh), None,
                          None)
        # [G, n_rows, K_in]: one group slice per area-group shard,
        # replicated over the subgroup (every lane scatters its own
        # neuron window of the group's targets).
        inter_in = P(_area_axes(mesh), None, None)
        if like is not None and like.tgt_inter_in is not None \
                and like.tgt_inter_in.ndim == 4:
            # [G, gsz, n_rows, K_in]: subgroup-sliced inbound tables -- the
            # second axis shards over the subgroup so each lane holds only
            # the rows targeting its own neuron window.
            inter_in = P(_area_axes(mesh), _subgroup_axis(mesh), None, None)
    else:  # conventional round-robin analogue: slice every area everywhere
        area = P(None, tuple(mesh.axis_names))
        syn = P(None, tuple(mesh.axis_names), None)
        out_intra = P(None, None, None)
        # [n_dev, n_rows, K_in]: one neuron-window slice per device.
        inter_in = P(tuple(mesh.axis_names), None, None)
    arrays = dict(
        alive=area, rate_hz=area,
        src_intra=syn, w_intra=syn, delay_intra=syn,
        src_inter=syn, w_inter=syn, delay_inter=syn,
    )
    if like is None or like.tgt_intra is not None:
        arrays.update(tgt_intra=out_intra, wout_intra=out_intra,
                      dout_intra=out_intra)
    if like is not None and like.tgt_inter is not None:
        rep = P(None, None, None)
        arrays.update(tgt_inter=rep, wout_inter=rep, dout_inter=rep)
    if like is None or like.tgt_inter_in is not None:
        arrays.update(tgt_inter_in=inter_in, wout_inter_in=inter_in,
                      dout_inter_in=inter_in)
    if like is not None:
        return dataclasses.replace(like, **arrays)
    return Network(
        n_pad=0, n_areas=0, ring_len=0, delay_ratio=1, dt_ms=0.1, **arrays
    )


def state_pspecs(
    mesh: Mesh,
    schedule: str,
    neuron_model: str,
    trial_leaves: bool = False,
) -> SimState:
    """A SimState-shaped pytree of PartitionSpecs.

    ``trial_leaves=True`` adds specs for the optional per-trial ``seed``/
    ``stim`` drive leaves (same ``[A, n_pad]`` placement as the neuron
    state); the default matches the classic leafless state exactly, so
    every existing state tree, checkpoint and shard_map spec is unchanged.
    """
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        ring = P(_area_axes(mesh), _subgroup_axis(mesh), None)
    else:
        area = P(None, tuple(mesh.axis_names))
        ring = P(None, tuple(mesh.axis_names), None)
    if neuron_model == "lif":
        nstate = neuron_lib.LIFState(v=area, i_syn=area, refrac=area)
    else:
        nstate = neuron_lib.IafState(countdown=area)
    return SimState(neuron=nstate, ring=ring, t=P(), spike_count=area,
                    overflow=P(), shipped_bytes=P(),
                    seed=area if trial_leaves else None,
                    stim=area if trial_leaves else None)


def shard_network(net: Network, mesh: Mesh, schedule: str) -> Network:
    """device_put the connectivity with the schedule's shardings."""
    specs = network_pspecs(mesh, schedule, like=net)

    def put(x, spec):
        if isinstance(x, jax.Array):
            return jax.device_put(x, NamedSharding(mesh, spec))
        return x

    return jax.tree.map(put, net, specs)


def _validate(net: Network, mesh: Mesh, schedule: str) -> None:
    A, n_pad = net.alive.shape
    if schedule == STRUCTURE_AWARE:
        n_groups = math.prod(mesh.shape[a] for a in _area_axes(mesh))
        gsz = mesh.shape[_subgroup_axis(mesh)]
        if A % n_groups != 0:
            raise ValueError(
                f"n_areas={A} not divisible by area shards={n_groups} "
                f"(mesh {dict(mesh.shape)})"
            )
        if n_pad % gsz != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by subgroup {gsz}"
            )
    else:
        total = math.prod(mesh.shape.values())
        if n_pad % total != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by {total} devices"
            )


def _make_exchange(
    net: Network, spec: MultiAreaSpec, mesh: Mesh, cfg: EngineConfig
) -> exchange_lib.Exchange:
    name = cfg.exchange or "dense"
    if name == "local":
        raise ValueError(
            "exchange='local' is the single-host identity; the distributed "
            "engine needs 'dense' or 'routed'"
        )
    if name == "routed":
        adjacency = connectivity_lib.area_adjacency(net, spec)
        return exchange_lib.RoutedExchange(net, cfg, mesh, adjacency)
    return exchange_lib.DenseMeshExchange(net, cfg, mesh)


def build_network_sharded(
    spec: MultiAreaSpec,
    mesh: Mesh,
    config: EngineConfig,
    *,
    seed: int = 12,
    size_multiple: int = 1,
) -> Network:
    """Host-free construction: each device's tables straight from the rules.

    The counter-based draws (:func:`repro.core.connectivity.draw_pathway_rows`)
    make every synapse a pure function of ``(seed, pathway, row, k)``, so a
    shard can regenerate exactly its own inbound inter slice and lane-cut
    intra tables -- bitwise-identical to slicing the host-built global
    network -- without any process materialising the global
    ``src_inter/w_inter/delay_inter`` tensors. This assembles that Network:

    * a streaming planning pass (:func:`~repro.core.connectivity.
      sharded_build_plan`, peak RSS ~ one row chunk) fixes the global padded
      widths, delay windows and realised area adjacency;
    * every synapse-table leaf is a ``jax.make_array_from_callback`` whose
      callback generates one shard's slice on demand (memoised per shard
      index, shared across the src/w/delay sibling leaves), so host memory
      holds at most the addressable shards' own tables;
    * the O(N) ``alive``/``rate_hz`` masks are built host-side (they are
      the model's *state* scale, not its synapse scale) and placed sharded;
    * the dense incoming inter tensors become the zero-row stand-ins the
      event engine would have dropped at build anyway, and the realised
      adjacency rides along as static ``area_adj`` metadata for the routed
      exchange.

    Structure-aware placement only (``config.sharded_build`` semantics):
    groups own consecutive areas, lanes own ``n_pad / gsz`` windows.
    """
    import numpy as np

    cfg = config
    if cfg.schedule != STRUCTURE_AWARE:
        raise ValueError(
            "build_network_sharded targets the structure-aware placement")
    if cfg.backend != "event":
        raise ValueError("build_network_sharded builds the event-path tables")
    area_axes = _area_axes(mesh)
    sg_axis = _subgroup_axis(mesh)
    n_groups = math.prod(mesh.shape[a] for a in area_axes)
    gsz = mesh.shape[sg_axis]
    A = spec.n_areas
    n_pad = spec.padded_area_size(size_multiple)
    if A % n_groups != 0:
        raise ValueError(
            f"n_areas={A} not divisible by area shards={n_groups} "
            f"(mesh {dict(mesh.shape)})")
    if n_pad % gsz != 0:
        raise ValueError(
            f"padded area size {n_pad} not divisible by subgroup {gsz}")
    sub = gsz if (cfg.subgroup_inter_tables and gsz > 1) else 1
    K_i, K_e = spec.k_intra, spec.k_inter

    # De-duplicated planning: the memo/keyed-file cache computes the
    # streaming sweep once per (spec, seed, layout) -- in multi-process
    # runs process 0 publishes and the rest read ($REPRO_PLAN_CACHE).
    plan = connectivity_lib.cached_sharded_build_plan(
        spec, seed, n_groups, mode="group", subgroup=sub,
        size_multiple=size_multiple)

    sizes = spec.area_sizes()
    alive = np.zeros((A, n_pad), dtype=bool)
    rate = np.zeros((A, n_pad), dtype=np.float32)
    for a, ar in enumerate(spec.areas):
        alive[a, : sizes[a]] = True
        rate[a, : sizes[a]] = ar.rate_hz

    area_sh = NamedSharding(mesh, P(area_axes, sg_axis))
    syn_sh = NamedSharding(mesh, P(area_axes, sg_axis, None))

    def _rng(sl, n: int) -> tuple[int, int]:
        # Callback indices arrive as slices; replicated dims come as
        # slice(None), so normalise both ends against the dim size.
        return (sl.start or 0, n if sl.stop is None else sl.stop)

    def from_cb(shape, sharding, cb):
        return jax.make_array_from_callback(shape, sharding, cb)

    # ---- incoming intra tables: each device draws its own rows.
    intra_cache: dict = {}

    def intra_slices(index):
        key = _rng(index[0], A) + _rng(index[1], n_pad)
        if key not in intra_cache:
            a0, a1, n0, n1 = key
            rows = (np.arange(a0, a1, dtype=np.int64)[:, None] * n_pad
                    + np.arange(n0, n1, dtype=np.int64)[None, :]).reshape(-1)
            s_, w_, d_ = connectivity_lib.draw_pathway_rows(
                spec, seed, rows, pathway="intra",
                size_multiple=size_multiple)
            shp = (a1 - a0, n1 - n0, K_i)
            intra_cache[key] = (s_.reshape(shp), w_.reshape(shp),
                                d_.reshape(shp))
        return intra_cache[key]

    shp_syn = (A, n_pad, K_i)
    src_intra = from_cb(shp_syn, syn_sh, lambda i: intra_slices(i)[0])
    w_intra = from_cb(shp_syn, syn_sh, lambda i: intra_slices(i)[1])
    delay_intra = from_cb(shp_syn, syn_sh, lambda i: intra_slices(i)[2])

    # ---- outgoing intra tables: lane-cut [gsz, A, n_pad, K_lane] when the
    # subgroup slicing is on, replicated [A, n_pad, K_out] otherwise.
    out_cache: dict = {}
    if sub > 1:
        out_sh = NamedSharding(mesh, P(sg_axis, area_axes, None, None))
        shp_out = (gsz, A, n_pad, plan.k_lane_intra)

        def out_slices(index):
            key = _rng(index[0], gsz) + _rng(index[1], A)
            if key not in out_cache:
                l0, l1, a0, a1 = key
                areas = np.arange(a0, a1, dtype=np.int64)
                parts = [connectivity_lib.build_lane_intra_tables(
                    spec, seed, areas, lane, plan=plan)
                    for lane in range(l0, l1)]
                out_cache[key] = tuple(
                    np.stack([p[j] for p in parts]) for j in range(3))
            return out_cache[key]
    else:
        out_sh = NamedSharding(mesh, P(area_axes, None, None))
        shp_out = (A, n_pad, plan.k_out_intra)

        def out_slices(index):
            key = _rng(index[0], A)
            if key not in out_cache:
                a0, a1 = key
                out_cache[key] = connectivity_lib.build_group_intra_tables(
                    spec, seed, np.arange(a0, a1, dtype=np.int64), plan=plan)
            return out_cache[key]

    tgt_intra = from_cb(shp_out, out_sh, lambda i: out_slices(i)[0])
    wout_intra = from_cb(shp_out, out_sh, lambda i: out_slices(i)[1])
    dout_intra = from_cb(shp_out, out_sh, lambda i: out_slices(i)[2])

    # ---- inbound inter slices: [S(, sub), A * n_pad, K_in].
    inter: dict = {}
    if K_e > 0:
        in_cache: dict = {}
        n_rows = A * n_pad
        if sub > 1:
            in_sh = NamedSharding(mesh, P(area_axes, sg_axis, None, None))
            shp_in = (n_groups, sub, n_rows, plan.k_in)

            def in_slices(index):
                key = _rng(index[0], n_groups) + _rng(index[1], sub)
                if key not in in_cache:
                    s0, s1, l0, l1 = key
                    rows = [[connectivity_lib.build_shard_tables(
                        spec, seed, s, plan=plan, lane=l)
                        for l in range(l0, l1)] for s in range(s0, s1)]
                    in_cache[key] = tuple(
                        np.stack([[b[j] for b in r] for r in rows])
                        for j in range(3))
                return in_cache[key]
        else:
            in_sh = NamedSharding(mesh, P(area_axes, None, None))
            shp_in = (n_groups, n_rows, plan.k_in)

            def in_slices(index):
                key = _rng(index[0], n_groups)
                if key not in in_cache:
                    s0, s1 = key
                    parts = [connectivity_lib.build_shard_tables(
                        spec, seed, s, plan=plan) for s in range(s0, s1)]
                    in_cache[key] = tuple(
                        np.stack([p[j] for p in parts]) for j in range(3))
                return in_cache[key]

        inter = dict(
            tgt_inter_in=from_cb(shp_in, in_sh, lambda i: in_slices(i)[0]),
            wout_inter_in=from_cb(shp_in, in_sh, lambda i: in_slices(i)[1]),
            dout_inter_in=from_cb(shp_in, in_sh, lambda i: in_slices(i)[2]),
            inter_shard_mode="group",
        )

    # Dense incoming inter tensors: the zero-row stand-ins the event engine
    # drops at build anyway (K_e axis preserved -- `k_inter` reads it).
    d_e = connectivity_lib._delay_dtype(spec.steps_inter_max)
    return Network(
        alive=jax.device_put(alive, area_sh),
        rate_hz=jax.device_put(rate, area_sh),
        src_intra=src_intra, w_intra=w_intra, delay_intra=delay_intra,
        src_inter=jnp.zeros((0, 0, K_e), jnp.int32),
        w_inter=jnp.zeros((0, 0, K_e), jnp.float32),
        delay_inter=jnp.zeros((0, 0, K_e), d_e),
        tgt_intra=tgt_intra, wout_intra=wout_intra, dout_intra=dout_intra,
        n_pad=n_pad,
        n_areas=A,
        ring_len=spec.ring_len,
        delay_ratio=spec.delay_ratio,
        dt_ms=spec.dt_ms,
        steps_lo_intra=plan.steps_lo_intra,
        r_span_intra=plan.r_span_intra,
        steps_lo_inter=plan.steps_lo_inter,
        r_span_inter=plan.r_span_inter,
        area_adj=plan.area_adj,
        **inter,
    )


def _make_dist_engine(
    net: Network | None,
    spec: MultiAreaSpec,
    mesh: Mesh,
    config: EngineConfig = EngineConfig(),
    *,
    build_seed: int = 12,
    gids: jax.Array | None = None,
    trial_leaves: bool = False,
) -> Engine:
    """Build the distributed engine. ``net`` may be host-resident; callers on
    real hardware should pass ``shard_network(net, mesh, schedule)``.

    ``net=None`` requires ``config.sharded_build`` and constructs the
    connectivity host-free on this mesh (:func:`build_network_sharded`,
    seeded by ``build_seed``) -- no global tensors ever exist.

    ``gids`` overrides the global-id table (see the single-host engine).
    ``trial_leaves=True`` sizes the shard_map state specs for the optional
    per-trial ``seed``/``stim`` drive leaves; ``init()`` then always
    materialises them (defaulting to the engine-wide seed / unit stimulus)."""
    cfg = config
    cfg.check(distributed=True)
    backend = cfg.backend
    if net is None:
        if not cfg.sharded_build:
            raise ValueError(
                "net=None needs config.sharded_build=True (otherwise pass "
                "a build_network(...) network)")
        net = build_network_sharded(spec, mesh, cfg, seed=build_seed)
    _validate(net, mesh, cfg.schedule)
    if backend == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    # The event/routed receive path scatters arriving id packets through
    # inter receive tables. By default (cfg.shard_inter_tables) those are
    # the *sharded inbound* slices: the replicated [A*n_pad, K_out] tables
    # are re-cut per target shard (connectivity.shard_inter_tables) and the
    # replicated leaves dropped, so each device holds ~1/S of the edges.
    # A network that already carries inbound tables (network_sds
    # inter_shards, the dry-run path) is validated against the mesh.
    if (backend == "event" or cfg.exchange == "routed") and net.k_inter > 0:
        if cfg.schedule == STRUCTURE_AWARE:
            n_shards = math.prod(mesh.shape[a] for a in _area_axes(mesh))
            gsz = mesh.shape[_subgroup_axis(mesh)]
            mode = "group"
        else:
            n_shards, gsz, mode = mesh.size, 1, "window"
        if net.tgt_inter_in is not None:
            got_sub = (net.tgt_inter_in.shape[1]
                       if net.tgt_inter_in.ndim == 4 else 1)
            want_sub = gsz if net.tgt_inter_in.ndim == 4 else 1
            if (net.tgt_inter_in.shape[0] != n_shards
                    or got_sub != want_sub
                    or net.inter_shard_mode != mode):
                raise ValueError(
                    f"sharded inter tables ({net.tgt_inter_in.shape[0]} "
                    f"{net.inter_shard_mode!r} shards x {got_sub} lanes) "
                    f"do not match the "
                    f"mesh ({n_shards} {mode!r} shards x {want_sub} lanes)")
        elif cfg.shard_inter_tables:
            # Built from the incoming tensors -- no replicated outgoing
            # inter tables needed (build_network(outgoing=True) is only
            # required for the event backend's intra tables above).
            # With subgroup_inter_tables the structure-aware cut also
            # slices each group's table over the gsz neuron windows
            # ([S, gsz, rows, K]) so a lane holds only its own targets.
            sub = (gsz if cfg.subgroup_inter_tables and mode == "group"
                   else 1)
            net = connectivity_lib.shard_inter_tables(
                net, n_shards, mode=mode, subgroup=sub)
    # The outgoing intra tables get the same subgroup treatment: under the
    # structure-aware event path every lane scatters the whole group's
    # fired ids through them, masking foreign targets -- so they are
    # lane-replicated unless each lane's slice is cut down to its own
    # neuron window (connectivity.slice_intra_tables). At production scale
    # that replication, not the inter tables, dominates per-device HBM.
    if net.tgt_intra is not None and net.tgt_intra.ndim == 4:
        gsz = mesh.shape[_subgroup_axis(mesh)]
        if cfg.schedule != STRUCTURE_AWARE:
            raise ValueError(
                "subgroup-sliced intra tables need the structure-aware "
                "schedule (the conventional cut is already per-device)")
        if net.tgt_intra.shape[0] != gsz:
            raise ValueError(
                f"subgroup-sliced intra tables ({net.tgt_intra.shape[0]} "
                f"lanes) do not match the mesh subgroup ({gsz})")
    elif (backend == "event" and net.tgt_intra is not None
          and cfg.schedule == STRUCTURE_AWARE
          and cfg.shard_inter_tables and cfg.subgroup_inter_tables):
        net = connectivity_lib.slice_intra_tables(
            net, mesh.shape[_subgroup_axis(mesh)])
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    R = net.ring_len
    area_axes = _area_axes(mesh)
    subgroup = _subgroup_axis(mesh)
    all_axes = tuple(mesh.axis_names)
    lif_params, _ = resolve_params(net, spec, cfg)
    fused_lif = make_fused_lif_update(lif_params) if cfg.fused else None

    exchange = _make_exchange(net, spec, mesh, cfg)
    if net.tgt_inter_in is not None and (
            backend == "event" or cfg.exchange == "routed"):
        # Every inter receive on these paths scatters id packets through
        # the inbound tables (`_inter_tables`); the dense incoming
        # src_inter/w_inter/delay_inter tensors are never read again after
        # the slices are cut (area_adjacency above was their last reader),
        # so free them here instead of keeping both layouts live.
        # Zero-row stand-ins keep the pytree structure and the K_e axis
        # (`k_inter` gates the window-end exchange on shape[-1] > 0).
        k_e = net.k_inter
        net = dataclasses.replace(
            net,
            src_inter=jnp.zeros((0, 0, k_e), net.src_inter.dtype),
            w_inter=jnp.zeros((0, 0, k_e), net.w_inter.dtype),
            delay_inter=jnp.zeros((0, 0, k_e), net.delay_inter.dtype),
        )
    update_fn = schedule_lib.make_update_fn(
        cfg, spec, net.dt_ms, lif_params, fused_lif)
    window_body = schedule_lib.make_window_fn(cfg, exchange, update_fn)

    # ---------------- assemble jitted entry points ---------------------------

    st_specs = state_pspecs(
        mesh, cfg.schedule, cfg.neuron_model, trial_leaves=trial_leaves)
    nt_specs = network_pspecs(mesh, cfg.schedule, like=net)
    gid_spec = (
        P(area_axes, subgroup)
        if cfg.schedule == STRUCTURE_AWARE
        else P(None, all_axes)
    )
    if cfg.schedule == STRUCTURE_AWARE:
        block_spec = P(None, area_axes, subgroup)
    else:
        block_spec = P(None, None, all_axes)

    window_sm = shard_map(
        window_body,
        mesh=mesh,
        in_specs=(st_specs, nt_specs, gid_spec),
        out_specs=(st_specs, block_spec),
        check_vma=False,
    )

    gids_global = (
        jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)
        if gids is None else gids
    )

    overlap_jit = drain_jit = init_inflight = None
    if cfg.overlap_exchange:
        overlap_body, drain_body = schedule_lib.make_overlap_window_fn(
            cfg, exchange, update_fn)
        # The in-flight wire's specs come from the exchange: the dense wire
        # is a whole-mesh gather (replicated), the routed wire differs per
        # device group (leading group axis sharded over the area axes).
        # Finish is collective-free, so `drain` is safe as its own
        # shard_map'd program -- no SPMD deadlock risk from running it at a
        # host-decided boundary.
        if_specs = exchange.inflight_pspecs()
        overlap_sm = shard_map(
            overlap_body,
            mesh=mesh,
            in_specs=(st_specs, if_specs, nt_specs, gid_spec),
            out_specs=(st_specs, if_specs, block_spec),
            check_vma=False,
        )
        drain_sm = shard_map(
            drain_body,
            mesh=mesh,
            in_specs=(st_specs, if_specs, nt_specs, gid_spec),
            out_specs=st_specs,
            check_vma=False,
        )
        inflight_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), if_specs,
            is_leaf=lambda x: isinstance(x, P),
        )

        def init_inflight():
            return jax.device_put(
                exchange.init_inflight(net), inflight_shardings)

        @jax.jit
        def overlap_jit(state, inflight):
            return overlap_sm(state, inflight, net, gids_global)

        @jax.jit
        def drain_jit(state, inflight):
            return drain_sm(state, inflight, net, gids_global)

        # Compatibility `window`: one overlapped window drained on the spot
        # (finish of an empty inflight is a no-op) -- bit-identical to the
        # sequential window for every unpipelined caller.
        @jax.jit
        def window(state: SimState):
            st, inf, block = overlap_sm(
                state, exchange.init_inflight(net), net, gids_global)
            return drain_sm(st, inf, net, gids_global), block

    else:
        @jax.jit
        def window(state: SimState):
            return window_sm(state, net, gids_global)

    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), st_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def shard_state(state: SimState) -> SimState:
        """Scatter a host/global SimState over the mesh (checkpoint restore:
        the state layout is area-keyed and global, so the same arrays place
        onto any group count -- elastic reshard-restart is this device_put
        plus the re-cut inter tables above)."""
        return jax.device_put(state, state_shardings)

    def init(seed=None, stim=None) -> SimState:
        if seed is not None or stim is not None:
            if not trial_leaves:
                raise ValueError(
                    "per-trial seed/stim need make_simulation(..., "
                    "trial_leaves=True) -- the shard_map state specs are "
                    "sized at engine build"
                )
            if cfg.neuron_model != "lif":
                raise ValueError(
                    "per-trial seed/stim drive the LIF Poisson input; "
                    "ignore_and_fire has no seed or input dependence"
                )
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids_global
            )
        if trial_leaves:
            # The spec'd leaves always exist; absent overrides fall back to
            # the engine-wide seed / unit stimulus (bit-identical drive).
            seed_leaf = jnp.broadcast_to(
                jnp.asarray(cfg.seed if seed is None else seed, jnp.uint32),
                (A, n_pad))
            stim_leaf = jnp.broadcast_to(
                jnp.asarray(1.0 if stim is None else stim, jnp.float32),
                (A, n_pad))
        else:
            seed_leaf = stim_leaf = None
        state = SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, R), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
            overflow=jnp.int32(0),
            shipped_bytes=jnp.float32(0),
            seed=seed_leaf,
            stim=stim_leaf,
        )
        return shard_state(state)

    if cfg.overlap_exchange:
        @functools.partial(jax.jit, static_argnums=1)
        def run(state: SimState, n_windows: int):
            def step(carry, _):
                st, inf = carry
                st, inf, block = overlap_sm(st, inf, net, gids_global)
                return (st, inf), block.astype(jnp.int32).sum()

            (state, inf), spikes = jax.lax.scan(
                step, (state, exchange.init_inflight(net)), None,
                length=n_windows)
            return drain_sm(state, inf, net, gids_global), spikes
    else:
        @functools.partial(jax.jit, static_argnums=1)
        def run(state: SimState, n_windows: int):
            def step(st, _):
                st, block = window_sm(st, net, gids_global)
                return st, block.astype(jnp.int32).sum()

            return jax.lax.scan(step, state, None, length=n_windows)

    return Engine(init=init, window=window, run=run, config=cfg,
                  delay_ratio=D, window_raw=window_sm,
                  wire_bytes=exchange.wire_bytes(net),
                  shard_state=shard_state,
                  window_overlap=overlap_jit, drain=drain_jit,
                  init_inflight=init_inflight)


def make_dist_engine(
    net: Network | None,
    spec: MultiAreaSpec,
    mesh: Mesh,
    config: EngineConfig = EngineConfig(),
    *,
    build_seed: int = 12,
    gids: jax.Array | None = None,
    trial_leaves: bool = False,
) -> Engine:
    """Deprecated alias for :func:`repro.core.make_simulation`.

    Same engine, same trajectories -- only the entry point moved: the
    unified factory dispatches to this distributed assembly when a mesh is
    given.
    """
    import warnings

    warnings.warn(
        "make_dist_engine is deprecated; use repro.core.make_simulation"
        "(spec, config, net=net, mesh=mesh) -- it builds the identical "
        "distributed engine when a mesh is given",
        DeprecationWarning,
        stacklevel=2,
    )
    return _make_dist_engine(
        net, spec, mesh, config,
        build_seed=build_seed, gids=gids, trial_leaves=trial_leaves)
