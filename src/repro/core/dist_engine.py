"""Distributed engine: the structure-aware scheme on a (pod, data, model) mesh.

Placement (DESIGN.md §4):

* **structure-aware**: the area dimension ``A`` is sharded over the slow axes
  ``(pod, data)``; each area's ``n_pad`` neurons are sharded over the fast
  ``model`` axis (the intra-area device subgroup -- the paper's ``MPI_Group``
  generalisation). Per cycle only the subgroup communicates (local pathway);
  every D-th cycle the lumped ``[D, ...]`` spike block crosses the whole mesh
  (global pathway).

* **conventional**: the round-robin analogue -- every device hosts a slice of
  *every* area (``n_pad`` sharded over all axes). Perfect balance, zero
  structure: the full spike vector must be exchanged globally every cycle.

Both produce spike trains bit-identical to the single-host reference engine
(tests/test_distributed.py runs them in an 8-device subprocess).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import comm, neuron as neuron_lib, ring_buffer
from repro.core.engine import (
    CONVENTIONAL,
    STRUCTURE_AWARE,
    Engine,
    EngineConfig,
    SimState,
)

__all__ = [
    "make_dist_engine",
    "network_pspecs",
    "state_pspecs",
    "shard_network",
]


def _area_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names[:-1])


def _subgroup_axis(mesh: Mesh) -> str:
    return mesh.axis_names[-1]


def network_pspecs(mesh: Mesh, schedule: str, like: Network | None = None) -> Network:
    """A Network-shaped pytree of PartitionSpecs for the given schedule.

    ``like`` supplies the static metadata fields (pytree structure must match
    exactly when used as shard_map in_specs).
    """
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        syn = P(_area_axes(mesh), _subgroup_axis(mesh), None)
    else:  # conventional round-robin analogue: slice every area everywhere
        area = P(None, tuple(mesh.axis_names))
        syn = P(None, tuple(mesh.axis_names), None)
    arrays = dict(
        alive=area, rate_hz=area,
        src_intra=syn, w_intra=syn, delay_intra=syn,
        src_inter=syn, w_inter=syn, delay_inter=syn,
    )
    if like is not None:
        return dataclasses.replace(like, **arrays)
    return Network(
        n_pad=0, n_areas=0, ring_len=0, delay_ratio=1, dt_ms=0.1, **arrays
    )


def state_pspecs(mesh: Mesh, schedule: str, neuron_model: str) -> SimState:
    """A SimState-shaped pytree of PartitionSpecs."""
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        ring = P(_area_axes(mesh), _subgroup_axis(mesh), None)
    else:
        area = P(None, tuple(mesh.axis_names))
        ring = P(None, tuple(mesh.axis_names), None)
    if neuron_model == "lif":
        nstate = neuron_lib.LIFState(v=area, i_syn=area, refrac=area)
    else:
        nstate = neuron_lib.IafState(countdown=area)
    return SimState(neuron=nstate, ring=ring, t=P(), spike_count=area)


def shard_network(net: Network, mesh: Mesh, schedule: str) -> Network:
    """device_put the connectivity with the schedule's shardings."""
    specs = network_pspecs(mesh, schedule, like=net)

    def put(x, spec):
        if isinstance(x, jax.Array):
            return jax.device_put(x, NamedSharding(mesh, spec))
        return x

    return jax.tree.map(put, net, specs)


def _validate(net: Network, mesh: Mesh, schedule: str) -> None:
    A, n_pad = net.alive.shape
    if schedule == STRUCTURE_AWARE:
        n_groups = math.prod(mesh.shape[a] for a in _area_axes(mesh))
        gsz = mesh.shape[_subgroup_axis(mesh)]
        if A % n_groups != 0:
            raise ValueError(
                f"n_areas={A} not divisible by area shards={n_groups} "
                f"(mesh {dict(mesh.shape)})"
            )
        if n_pad % gsz != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by subgroup {gsz}"
            )
    else:
        total = math.prod(mesh.shape.values())
        if n_pad % total != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by {total} devices"
            )


def make_dist_engine(
    net: Network,
    spec: MultiAreaSpec,
    mesh: Mesh,
    config: EngineConfig = EngineConfig(),
) -> Engine:
    """Build the distributed engine. ``net`` may be host-resident; callers on
    real hardware should pass ``shard_network(net, mesh, schedule)``."""
    cfg = config
    _validate(net, mesh, cfg.schedule)
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    R = net.ring_len
    area_axes = _area_axes(mesh)
    subgroup = _subgroup_axis(mesh)
    all_axes = tuple(mesh.axis_names)
    lif_params = cfg.lif
    if abs(lif_params.dt_ms - net.dt_ms) > 1e-12:
        lif_params = dataclasses.replace(lif_params, dt_ms=net.dt_ms)

    drive_scale = spec.ext_rate_hz / 2.5

    def _update(neuron_state, i_in, t, alive, rate_hz, gids):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, rate_hz * drive_scale, net.dt_ms, spec.w_ext
            )
            return neuron_lib.lif_update(neuron_state, i_in + drive, alive, lif_params)
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, alive, rate_hz, net.dt_ms
        )

    def _deposit(ring, vals, delays, t):
        a, n, r = ring.shape
        k = vals.shape[-1]
        out = ring_buffer.deposit_scatter(
            ring.reshape(a * n, r), vals.reshape(a * n, k),
            delays.reshape(a * n, k), t,
        )
        return out.reshape(a, n, r)

    def _deliver_intra(ring, spikes_area_f32, lnet, t):
        """spikes_area_f32: [A_loc, n_pad] complete per-area vectors."""
        vals = lnet.w_intra * jax.vmap(lambda s, i: s[i])(
            spikes_area_f32, lnet.src_intra
        )
        return _deposit(ring, vals, lnet.delay_intra, t)

    def _deliver_inter(ring, spikes_flat_f32, lnet, t):
        """spikes_flat_f32: [A * n_pad] global spike vector for one cycle."""
        if lnet.src_inter.shape[-1] == 0:
            return ring
        vals = lnet.w_inter * spikes_flat_f32[lnet.src_inter]
        return _deposit(ring, vals, lnet.delay_inter, t)

    # ---------------- shard_map window bodies --------------------------------

    def window_struct(state: SimState, lnet: Network, gids: jax.Array):
        """Structure-aware: D local cycles + one lumped global exchange."""
        t0 = state.t

        def cycle(st, _):
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = _update(
                st.neuron, i_in, st.t, lnet.alive, lnet.rate_hz, gids
            )
            s8 = spikes.astype(jnp.int8)
            # Local pathway: complete this device's areas over the subgroup.
            area_spikes = comm.gather_area(s8, subgroup_axis=subgroup)
            ring = _deliver_intra(ring, area_spikes.astype(jnp.float32), lnet, st.t)
            st = SimState(
                neuron=nstate, ring=ring, t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
            )
            return st, s8

        state, block = jax.lax.scan(cycle, state, None, length=D)

        # Global pathway: one collective for the whole window (paper Fig. 3).
        gblock = comm.gather_global(
            block, area_axes=area_axes, subgroup_axis=subgroup
        )  # [D, A, n_pad] int8
        gflat = gblock.astype(jnp.float32).reshape(D, A * n_pad)

        def deliver_s(s, ring):
            return _deliver_inter(ring, gflat[s], lnet, t0 + s)

        ring = jax.lax.fori_loop(0, D, deliver_s, state.ring)
        return dataclasses.replace(state, ring=ring), block

    def window_conv(state: SimState, lnet: Network, gids: jax.Array):
        """Conventional: global exchange every cycle (round-robin analogue)."""

        def cycle(st, _):
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = _update(
                st.neuron, i_in, st.t, lnet.alive, lnet.rate_hz, gids
            )
            s8 = spikes.astype(jnp.int8)
            # One global all_gather per cycle: every device needs the full
            # vector because its neurons' sources are scattered everywhere.
            full = comm.gather_full(s8, all_axes)
            full_f = full.astype(jnp.float32)  # [A, n_pad]
            ring = _deliver_intra(ring, full_f, lnet, st.t)
            ring = _deliver_inter(ring, full_f.reshape(-1), lnet, st.t)
            st = SimState(
                neuron=nstate, ring=ring, t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
            )
            return st, s8

        return jax.lax.scan(cycle, state, None, length=D)

    # ---------------- assemble jitted entry points ---------------------------

    st_specs = state_pspecs(mesh, cfg.schedule, cfg.neuron_model)
    nt_specs = network_pspecs(mesh, cfg.schedule, like=net)
    gid_spec = (
        P(area_axes, subgroup)
        if cfg.schedule == STRUCTURE_AWARE
        else P(None, all_axes)
    )
    if cfg.schedule == STRUCTURE_AWARE:
        block_spec = P(None, area_axes, subgroup)
    else:
        block_spec = P(None, None, all_axes)

    body = window_struct if cfg.schedule == STRUCTURE_AWARE else window_conv
    window_sm = shard_map(
        body,
        mesh=mesh,
        in_specs=(st_specs, nt_specs, gid_spec),
        out_specs=(st_specs, block_spec),
        check_vma=False,
    )

    gids_global = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    @jax.jit
    def window(state: SimState):
        return window_sm(state, net, gids_global)

    def init() -> SimState:
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids_global
            )
        state = SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, R), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
        )
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), st_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        return jax.device_put(state, shardings)

    @functools.partial(jax.jit, static_argnums=1)
    def run(state: SimState, n_windows: int):
        def step(st, _):
            st, block = window_sm(st, net, gids_global)
            return st, block.astype(jnp.int32).sum()

        return jax.lax.scan(step, state, None, length=n_windows)

    return Engine(init=init, window=window, run=run, config=cfg,
                  delay_ratio=D, window_raw=window_sm)
