"""Distributed engine: the structure-aware scheme on a (pod, data, model) mesh.

Placement:

* **structure-aware**: the area dimension ``A`` is sharded over the slow axes
  ``(pod, data)``; each area's ``n_pad`` neurons are sharded over the fast
  ``model`` axis (the intra-area device subgroup -- the paper's ``MPI_Group``
  generalisation). Per cycle only the subgroup communicates (local pathway);
  every D-th cycle the lumped ``[D, ...]`` spike block crosses the whole mesh
  (global pathway).

* **conventional**: the round-robin analogue -- every device hosts a slice of
  *every* area (``n_pad`` sharded over all axes). Perfect balance, zero
  structure: the full spike vector must be exchanged globally every cycle.

Both produce spike trains bit-identical to the single-host reference engine
(tests/test_distributed.py runs them in an 8-device subprocess).

Delivery inside the shard_map window bodies goes through the shared dispatch
in :mod:`repro.core.delivery` (``EngineConfig.delivery_backend``). The dense
backends (onehot/scatter/pallas) exchange bit-packed spike vectors
(``comm.gather_*``); the ``event`` backend instead compacts fired neurons
into fixed-size *id packets* before each exchange -- NEST's sparse wire
format, the one the paper contrasts with dense vectors -- and the receive
side scatters the ids through replicated outgoing tables
(``ops.event_deliver_ids``). Packet bounds are static (``s_max``); spills
are counted in ``SimState.overflow`` (any nonzero value means spikes were
dropped and the bounds must be raised).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.kernels import ops as kops
from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import comm, delivery as delivery_lib, neuron as neuron_lib
from repro.core import ring_buffer
from repro.core.engine import (
    CONVENTIONAL,
    STRUCTURE_AWARE,
    Engine,
    EngineConfig,
    SimState,
    make_fused_lif_update,
    resolve_params,
)

__all__ = [
    "make_dist_engine",
    "network_pspecs",
    "state_pspecs",
    "shard_network",
]


def _area_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names[:-1])


def _subgroup_axis(mesh: Mesh) -> str:
    return mesh.axis_names[-1]


def network_pspecs(mesh: Mesh, schedule: str, like: Network | None = None) -> Network:
    """A Network-shaped pytree of PartitionSpecs for the given schedule.

    ``like`` supplies the static metadata fields (pytree structure must match
    exactly when used as shard_map in_specs). When ``like`` carries outgoing
    (event-path) tables they are kept device-resident in full: intra tables
    replicated over the subgroup (each device scans its areas' complete fired
    lists), inter tables replicated everywhere (each device scans the global
    packet) -- the NEST pattern where every rank receives all spikes and
    delivers to its local targets.
    """
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        syn = P(_area_axes(mesh), _subgroup_axis(mesh), None)
        out_intra = P(_area_axes(mesh), None, None)
    else:  # conventional round-robin analogue: slice every area everywhere
        area = P(None, tuple(mesh.axis_names))
        syn = P(None, tuple(mesh.axis_names), None)
        out_intra = P(None, None, None)
    arrays = dict(
        alive=area, rate_hz=area,
        src_intra=syn, w_intra=syn, delay_intra=syn,
        src_inter=syn, w_inter=syn, delay_inter=syn,
    )
    if like is None or like.tgt_intra is not None:
        arrays.update(tgt_intra=out_intra, wout_intra=out_intra,
                      dout_intra=out_intra)
    if like is None or like.tgt_inter is not None:
        rep = P(None, None, None)
        arrays.update(tgt_inter=rep, wout_inter=rep, dout_inter=rep)
    if like is not None:
        return dataclasses.replace(like, **arrays)
    return Network(
        n_pad=0, n_areas=0, ring_len=0, delay_ratio=1, dt_ms=0.1, **arrays
    )


def state_pspecs(mesh: Mesh, schedule: str, neuron_model: str) -> SimState:
    """A SimState-shaped pytree of PartitionSpecs."""
    if schedule == STRUCTURE_AWARE:
        area = P(_area_axes(mesh), _subgroup_axis(mesh))
        ring = P(_area_axes(mesh), _subgroup_axis(mesh), None)
    else:
        area = P(None, tuple(mesh.axis_names))
        ring = P(None, tuple(mesh.axis_names), None)
    if neuron_model == "lif":
        nstate = neuron_lib.LIFState(v=area, i_syn=area, refrac=area)
    else:
        nstate = neuron_lib.IafState(countdown=area)
    return SimState(neuron=nstate, ring=ring, t=P(), spike_count=area,
                    overflow=P())


def shard_network(net: Network, mesh: Mesh, schedule: str) -> Network:
    """device_put the connectivity with the schedule's shardings."""
    specs = network_pspecs(mesh, schedule, like=net)

    def put(x, spec):
        if isinstance(x, jax.Array):
            return jax.device_put(x, NamedSharding(mesh, spec))
        return x

    return jax.tree.map(put, net, specs)


def _validate(net: Network, mesh: Mesh, schedule: str) -> None:
    A, n_pad = net.alive.shape
    if schedule == STRUCTURE_AWARE:
        n_groups = math.prod(mesh.shape[a] for a in _area_axes(mesh))
        gsz = mesh.shape[_subgroup_axis(mesh)]
        if A % n_groups != 0:
            raise ValueError(
                f"n_areas={A} not divisible by area shards={n_groups} "
                f"(mesh {dict(mesh.shape)})"
            )
        if n_pad % gsz != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by subgroup {gsz}"
            )
    else:
        total = math.prod(mesh.shape.values())
        if n_pad % total != 0:
            raise ValueError(
                f"padded area size {n_pad} not divisible by {total} devices"
            )


def make_dist_engine(
    net: Network,
    spec: MultiAreaSpec,
    mesh: Mesh,
    config: EngineConfig = EngineConfig(),
) -> Engine:
    """Build the distributed engine. ``net`` may be host-resident; callers on
    real hardware should pass ``shard_network(net, mesh, schedule)``."""
    cfg = config
    backend = cfg.backend
    _validate(net, mesh, cfg.schedule)
    if backend == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    if cfg.superstep_kernel:
        raise ValueError(
            "superstep_kernel is single-host only; the distributed engine "
            "fuses the window at the jnp level (use_superstep)"
        )
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    R = net.ring_len
    area_axes = _area_axes(mesh)
    subgroup = _subgroup_axis(mesh)
    all_axes = tuple(mesh.axis_names)
    n_dev = mesh.size
    lif_params, _ = resolve_params(net, spec, cfg)
    fused_lif = make_fused_lif_update(lif_params) if cfg.fused else None

    # Per-shard form of resolve_params' drive_rate: the window bodies scale
    # their device-local rate_hz slice by this factor.
    drive_scale = spec.ext_rate_hz / 2.5

    # Static event-packet bounds (see delivery.event_bounds): per-device
    # shares of the single-host bounds, floored so tiny shards keep headroom.
    if backend == "event":
        s_max_area, s_max_all = delivery_lib.event_bounds(
            net, headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
        gsz = mesh.shape[subgroup]
        s_max_loc = max(cfg.s_max_floor, -(-s_max_area // gsz))
        s_max_dev = max(cfg.s_max_floor, -(-s_max_all // n_dev))
    else:
        s_max_loc = s_max_dev = 0

    def _update(neuron_state, i_in, t, alive, rate_hz, gids):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, rate_hz * drive_scale, net.dt_ms, spec.w_ext
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, alive)
            return neuron_lib.lif_update(neuron_state, i_in + drive, alive, lif_params)
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, alive, rate_hz, net.dt_ms
        )

    def _axis_offset(axes, block: int):
        """This device's row offset for a dim sharded over ``axes`` (row-major)."""
        idx = jnp.int32(0)
        for ax in axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        return idx * block

    # ---------------- shard_map window bodies --------------------------------

    def window_struct(state: SimState, lnet: Network, gids: jax.Array):
        """Structure-aware: D local cycles + one lumped global exchange.

        With ``cfg.use_superstep`` (the default) the window is one fused
        D-cycle superstep: a blocked ``[.., D]`` ring read/clear, D unrolled
        cycles consuming window-static slots of the live buffer ``fut``, and
        a *single-pass* blocked scatter of the lumped ``[D, ...]`` exchange
        (the wire already carried the whole window; now the receive side
        stops replaying it cycle by cycle).
        """
        t0 = state.t
        a_loc, n_loc = lnet.alive.shape

        def cycle_body(st_ring, t, neuron, spike_count, over, fut_mode):
            """One deliver->update->collocate cycle; ``fut_mode`` means
            ``st_ring`` is the live window buffer and ``t`` the static
            within-window index (deposits are wrap-free by construction)."""
            ring = st_ring
            if fut_mode:
                i_in, t_abs = ring[..., t], t0 + t
            else:
                i_in, ring = ring_buffer.read_and_clear(ring, t)
                t_abs = t
            nstate, spikes = _update(
                neuron, i_in, t_abs, lnet.alive, lnet.rate_hz, gids
            )
            s8 = spikes.astype(jnp.int8)
            if backend == "event" and lnet.src_intra.shape[-1] > 0:
                # Local pathway, sparse wire: compact fired neurons into
                # per-area id packets *before* the subgroup exchange.
                noff = jax.lax.axis_index(subgroup) * n_loc
                ids = noff + jnp.arange(n_loc, dtype=jnp.int32)
                packets, counts = jax.vmap(
                    lambda f: delivery_lib.compact_fired(
                        f, ids, s_max=s_max_loc, invalid=n_pad)
                )(spikes)
                over_local = jnp.maximum(counts - s_max_loc, 0).sum()
                over = over + jax.lax.psum(over_local, all_axes)
                wire = jax.lax.all_gather(
                    packets, subgroup, axis=1, tiled=True)  # [A_loc, gsz*s]

                # Scatter straight into this device's neuron window of each
                # area: within-area target -> local row, -1 if not ours.
                def to_local(i):
                    il = i - noff
                    keep = (il >= 0) & (il < n_loc)
                    return jnp.where(keep, il, -1)

                ring = jax.vmap(
                    lambda r, idl, tg, w, d: kops.event_deliver_ids(
                        r, idl, tg, w, d, t, tgt_map=to_local)
                )(ring, wire, lnet.tgt_intra, lnet.wout_intra,
                  lnet.dout_intra)
            elif backend != "event":
                # Local pathway, dense wire: complete this device's areas
                # over the subgroup, then deliver via the shared dispatch.
                area_spikes = comm.gather_area(s8, subgroup_axis=subgroup)
                ring = delivery_lib.deliver_intra(
                    ring, area_spikes.astype(jnp.float32), lnet, t,
                    backend=backend)
            return ring, nstate, spike_count + spikes.astype(jnp.int32), over, s8

        if cfg.use_superstep:
            fut, ring = ring_buffer.open_window(
                state.ring, t0, D, lnet.live_window)
            neuron, spike_count, over = (
                state.neuron, state.spike_count, state.overflow)
            if cfg.superstep_unroll:
                cols = []
                for s in range(D):  # unrolled: static slot indices throughout
                    fut, neuron, spike_count, over, s8 = cycle_body(
                        fut, s, neuron, spike_count, over, fut_mode=True)
                    cols.append(s8)
                block = jnp.stack(cols)
            else:
                # Scan over the live window buffer (see engine.py): the
                # cheap [.., W] column access without the ~Dx op blow-up of
                # a fully unrolled jnp graph.
                def sbody(carry, s):
                    fut, neuron, spike_count, over = carry
                    fut, neuron, spike_count, over, s8 = cycle_body(
                        fut, s, neuron, spike_count, over, fut_mode=True)
                    return (fut, neuron, spike_count, over), s8

                (fut, neuron, spike_count, over), block = jax.lax.scan(
                    sbody, (fut, neuron, spike_count, over),
                    jnp.arange(D, dtype=jnp.int32))
            ring = ring_buffer.merge_window_tail(ring, fut[..., D:], t0 + D)
            state = SimState(
                neuron=neuron, ring=ring, t=t0 + D,
                spike_count=spike_count, overflow=over,
            )
        else:
            def cycle(st, _):
                ring, nstate, spike_count, over, s8 = cycle_body(
                    st.ring, st.t, st.neuron, st.spike_count, st.overflow,
                    fut_mode=False)
                return SimState(neuron=nstate, ring=ring, t=st.t + 1,
                                spike_count=spike_count, overflow=over), s8

            state, block = jax.lax.scan(cycle, state, None, length=D)

        if lnet.src_inter.shape[-1] == 0:
            return state, block

        # Global pathway: one collective for the whole window (paper Fig. 3).
        if backend == "event":
            # Sparse wire: one (id, step) packet for the whole window.
            packets, counts = delivery_lib.compact_fired_block(
                block != 0, gids, s_max=s_max_dev, invalid=A * n_pad
            )                                            # [D, s], [D]
            over = state.overflow + jax.lax.psum(
                jnp.maximum(counts - s_max_dev, 0).sum(), all_axes)
            wire = jax.lax.all_gather(
                packets, all_axes, axis=1, tiled=True)   # [D, n_dev*s]
            k_out = lnet.tgt_inter.shape[-1]
            tgt_f = lnet.tgt_inter.reshape(A * n_pad, k_out)
            w_f = lnet.wout_inter.reshape(A * n_pad, k_out)
            d_f = lnet.dout_inter.reshape(A * n_pad, k_out)

            # Scatter the global packets straight into this device's ring
            # shard: global target id -> local row, -1 if another device
            # owns it. No full-network buffer is ever materialised.
            aoff = _axis_offset(area_axes, a_loc)
            noff = _axis_offset((subgroup,), n_loc)

            def to_local(g):
                al = g // n_pad - aoff
                il = g % n_pad - noff
                keep = (al >= 0) & (al < a_loc) & (il >= 0) & (il < n_loc)
                return jnp.where(keep, al * n_loc + il, -1)

            if cfg.use_superstep:
                # Single-pass blocked receive: all D packets in one scatter.
                ring_flat = kops.event_deliver_block(
                    state.ring.reshape(a_loc * n_loc, R), wire,
                    tgt_f, w_f, d_f, t0, tgt_map=to_local)
            else:
                def deliver_s(s, ring_flat):
                    return kops.event_deliver_ids(
                        ring_flat, wire[s], tgt_f, w_f, d_f, t0 + s,
                        tgt_map=to_local)

                ring_flat = jax.lax.fori_loop(
                    0, D, deliver_s, state.ring.reshape(a_loc * n_loc, R))
            return dataclasses.replace(
                state, ring=ring_flat.reshape(a_loc, n_loc, R),
                overflow=over), block

        gblock = comm.gather_global(
            block, area_axes=area_axes, subgroup_axis=subgroup
        )  # [D, A, n_pad] int8
        gflat = gblock.astype(jnp.float32).reshape(D, A * n_pad)

        if cfg.use_superstep:
            # Single-pass blocked receive for the dense backends too.
            ring = delivery_lib.deliver_inter_block(
                state.ring, gflat, lnet, t0, backend=backend)
            return dataclasses.replace(state, ring=ring), block

        def deliver_s(s, ring):
            return delivery_lib.deliver_inter(
                ring, gflat[s], lnet, t0 + s, backend=backend)

        ring = jax.lax.fori_loop(0, D, deliver_s, state.ring)
        return dataclasses.replace(state, ring=ring), block

    def window_conv(state: SimState, lnet: Network, gids: jax.Array):
        """Conventional: global exchange every cycle (round-robin analogue)."""
        a_loc, n_loc = lnet.alive.shape  # a_loc == A; n_loc = n_pad / n_dev

        def cycle(st, _):
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = _update(
                st.neuron, i_in, st.t, lnet.alive, lnet.rate_hz, gids
            )
            s8 = spikes.astype(jnp.int8)
            over = st.overflow
            if backend == "event":
                # One sparse global exchange feeds both pathways.
                packet, count = delivery_lib.compact_fired(
                    spikes, gids, s_max=s_max_dev, invalid=A * n_pad)
                over = over + jax.lax.psum(
                    jnp.maximum(count - s_max_dev, 0), all_axes)
                wire = jax.lax.all_gather(
                    packet, all_axes, axis=0, tiled=True)  # [n_dev*s]
                noff = _axis_offset(all_axes, n_loc)

                # Both scatters go straight into this device's neuron window
                # (rows [noff, noff + n_loc) of every area) -- no full
                # [A, n_pad, R] buffer.
                def win_local(i):
                    il = i - noff
                    keep = (il >= 0) & (il < n_loc)
                    return jnp.where(keep, il, -1)

                if lnet.src_intra.shape[-1] > 0:
                    # Short-range: per-area within-area ids from the list.
                    areas = jnp.arange(A, dtype=jnp.int32)
                    ids_a = jnp.where(
                        wire[None, :] // n_pad == areas[:, None],
                        wire[None, :] % n_pad, n_pad)       # [A, S]
                    ring = jax.vmap(
                        lambda r, idl, tg, w, d: kops.event_deliver_ids(
                            r, idl, tg, w, d, st.t, tgt_map=win_local)
                    )(ring, ids_a, lnet.tgt_intra, lnet.wout_intra,
                      lnet.dout_intra)
                # Long-range: global target id -> (area row, local window).
                if lnet.src_inter.shape[-1] > 0:
                    k_out = lnet.tgt_inter.shape[-1]

                    def glob_local(g):
                        il = g % n_pad - noff
                        keep = (il >= 0) & (il < n_loc)
                        return jnp.where(keep, (g // n_pad) * n_loc + il, -1)

                    ring = kops.event_deliver_ids(
                        ring.reshape(A * n_loc, R), wire,
                        lnet.tgt_inter.reshape(A * n_pad, k_out),
                        lnet.wout_inter.reshape(A * n_pad, k_out),
                        lnet.dout_inter.reshape(A * n_pad, k_out),
                        st.t, tgt_map=glob_local).reshape(A, n_loc, R)
            else:
                # One global all_gather per cycle: every device needs the full
                # vector because its neurons' sources are scattered everywhere.
                full = comm.gather_full(s8, all_axes)
                full_f = full.astype(jnp.float32)  # [A, n_pad]
                ring = delivery_lib.deliver_intra(
                    ring, full_f, lnet, st.t, backend=backend)
                ring = delivery_lib.deliver_inter(
                    ring, full_f.reshape(-1), lnet, st.t, backend=backend)
            st = SimState(
                neuron=nstate, ring=ring, t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
                overflow=over,
            )
            return st, s8

        return jax.lax.scan(cycle, state, None, length=D)

    # ---------------- assemble jitted entry points ---------------------------

    st_specs = state_pspecs(mesh, cfg.schedule, cfg.neuron_model)
    nt_specs = network_pspecs(mesh, cfg.schedule, like=net)
    gid_spec = (
        P(area_axes, subgroup)
        if cfg.schedule == STRUCTURE_AWARE
        else P(None, all_axes)
    )
    if cfg.schedule == STRUCTURE_AWARE:
        block_spec = P(None, area_axes, subgroup)
    else:
        block_spec = P(None, None, all_axes)

    body = window_struct if cfg.schedule == STRUCTURE_AWARE else window_conv
    window_sm = shard_map(
        body,
        mesh=mesh,
        in_specs=(st_specs, nt_specs, gid_spec),
        out_specs=(st_specs, block_spec),
        check_vma=False,
    )

    gids_global = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    @jax.jit
    def window(state: SimState):
        return window_sm(state, net, gids_global)

    def init() -> SimState:
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids_global
            )
        state = SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, R), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
            overflow=jnp.int32(0),
        )
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), st_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        return jax.device_put(state, shardings)

    @functools.partial(jax.jit, static_argnums=1)
    def run(state: SimState, n_windows: int):
        def step(st, _):
            st, block = window_sm(st, net, gids_global)
            return st, block.astype(jnp.int32).sum()

        return jax.lax.scan(step, state, None, length=n_windows)

    return Engine(init=init, window=window, run=run, config=cfg,
                  delay_ratio=D, window_raw=window_sm)
