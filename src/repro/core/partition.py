"""Workload placement: round-robin vs structure-aware (+ elastic resharding).

The paper contrasts two placements (Fig. 2):

* **round-robin** (conventional): neuron ``gid`` lives on process
  ``gid % M`` -- perfect load balance, zero structure. Any pair of processes
  may host neurons separated by the overall minimum delay ``d_min``, so global
  communication is required every ``d_min``.

* **structure-aware**: area ``a`` maps onto one process (or, as proposed in
  the paper's Discussion and implemented here, onto a *subgroup* of devices --
  the ``model`` mesh axis). Heterogeneous areas are padded with frozen "ghost
  neurons" to the largest area size so the placement machinery stays uniform
  (§4.1.1). Inter-process delays are then >= ``d_min_inter``, enabling the
  D-cycle communication interval.

This module is pure metadata -- numpy only; engines and cost models consume it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.areas import MultiAreaSpec

__all__ = [
    "RoundRobinPlacement",
    "StructureAwarePlacement",
    "round_robin_placement",
    "structure_aware_placement",
    "elastic_reshard_plan",
    "placement_from_sizes",
    "reshard_area_order",
    "reshard_moves",
    "shard_pathway_rows",
]


def shard_pathway_rows(
    mode: str, shard: int, n_shards: int, n_areas: int, n_pad: int,
    *, subgroup: int = 1, lane: int = 0,
) -> np.ndarray:
    """Global row ids of the targets shard ``shard`` (lane ``lane``) owns.

    The shard -> pathway-row-range derivation shared by the inbound
    inter-table cut (``connectivity.shard_inter_tables``) and the host-free
    sharded build (``connectivity.build_shard_tables``): a shard's table is
    exactly the inversion of these rows' incoming draws. Rows are returned
    ascending (area-major), matching how the host path slices the global
    tensors -- which is what makes the per-shard inversion bitwise-equal.

    ``'group'`` -- the structure-aware placement: shards own ``A / S``
    consecutive areas (row-major over the mesh's area axes, matching
    ``dist_engine`` placement and ``exchange._group_index``). With
    ``subgroup > 1``, lane ``lane`` of the shard additionally owns only its
    ``n_pad / subgroup`` neuron window of each owned area (matching the
    mesh's last-axis window split, ``exchange._axis_offset``).
    ``'window'`` -- the conventional round-robin placement: shards own a
    ``n_pad / S`` neuron window of *every* area (matching
    ``exchange._axis_offset`` over all mesh axes).
    """
    if mode == "group":
        a_loc = n_areas // n_shards
        n_loc = n_pad // subgroup
        areas = np.arange(shard * a_loc, (shard + 1) * a_loc, dtype=np.int64)
        win = np.arange(lane * n_loc, (lane + 1) * n_loc, dtype=np.int64)
        return (areas[:, None] * n_pad + win[None, :]).reshape(-1)
    if mode == "window":
        n_loc = n_pad // n_shards
        win = np.arange(shard * n_loc, (shard + 1) * n_loc, dtype=np.int64)
        return (np.arange(n_areas, dtype=np.int64)[:, None] * n_pad
                + win[None, :]).reshape(-1)
    raise ValueError(f"unknown inter_shard_mode {mode!r}")


@dataclasses.dataclass(frozen=True)
class RoundRobinPlacement:
    """Conventional placement: neuron gid -> process gid % M."""

    n_total: int
    n_procs: int

    def neurons_on(self, proc: int) -> int:
        return (self.n_total - proc + self.n_procs - 1) // self.n_procs

    def proc_of(self, gids: np.ndarray) -> np.ndarray:
        return gids % self.n_procs

    @property
    def max_neurons_per_proc(self) -> int:
        return (self.n_total + self.n_procs - 1) // self.n_procs


@dataclasses.dataclass(frozen=True)
class StructureAwarePlacement:
    """Area-aligned placement over a (groups x group_size) device grid.

    ``area_of_group[g]`` lists the areas hosted by device group ``g`` (each
    group is the paper's MPI process / MPI_Group); ``n_pad`` is the padded
    per-area size; ghost counts quantify the padding overhead.
    """

    n_groups: int
    group_size: int  # devices per group ('model' axis extent)
    areas_per_group: int
    n_pad: int
    area_sizes: tuple[int, ...]

    @property
    def n_areas(self) -> int:
        return len(self.area_sizes)

    def areas_of_group(self, g: int) -> tuple[int, ...]:
        lo = g * self.areas_per_group
        return tuple(range(lo, lo + self.areas_per_group))

    def group_of_area(self, a: int) -> int:
        return a // self.areas_per_group

    @property
    def ghost_count(self) -> int:
        return sum(self.n_pad - s for s in self.area_sizes)

    @property
    def ghost_fraction(self) -> float:
        return self.ghost_count / (self.n_pad * self.n_areas)

    def neurons_on_group(self, g: int) -> int:
        return sum(self.area_sizes[a] for a in self.areas_of_group(g))

    def load_imbalance(self) -> float:
        """max/mean live-neuron load across groups (1.0 = perfectly balanced).

        This is the quantity that drives the elevated synchronization time for
        heterogeneous models in Fig. 8a / Fig. 9.
        """
        loads = np.asarray(
            [self.neurons_on_group(g) for g in range(self.n_groups)], dtype=float
        )
        return float(loads.max() / loads.mean())


def round_robin_placement(spec: MultiAreaSpec, n_procs: int) -> RoundRobinPlacement:
    return RoundRobinPlacement(n_total=spec.n_total, n_procs=n_procs)


def structure_aware_placement(
    spec: MultiAreaSpec,
    n_groups: int,
    group_size: int = 1,
    *,
    size_multiple: int = 1,
) -> StructureAwarePlacement:
    """Map areas onto ``n_groups`` device groups of ``group_size`` devices.

    Requires ``n_areas % n_groups == 0`` (areas per group constant); the padded
    area size must divide evenly by ``group_size`` so the intra-area ('model')
    sharding is uniform.
    """
    A = spec.n_areas
    if A % n_groups != 0:
        raise ValueError(
            f"n_areas={A} must be divisible by n_groups={n_groups}; "
            "pad the model with empty areas or choose a different mesh"
        )
    n_pad = spec.padded_area_size(max(size_multiple, group_size))
    if n_pad % group_size != 0:
        raise ValueError("padded area size must divide by group_size")
    return StructureAwarePlacement(
        n_groups=n_groups,
        group_size=group_size,
        areas_per_group=A // n_groups,
        n_pad=n_pad,
        area_sizes=tuple(int(a.n_neurons) for a in spec.areas),
    )


def elastic_reshard_plan(
    old: StructureAwarePlacement, new_n_groups: int
) -> dict[int, tuple[int, int]]:
    """Plan an elastic re-mesh: for every area, (old_group, new_group).

    Used by checkpoint restore when the data-parallel extent changes (node
    failure / elastic scale-up): state arrays are keyed by area, so moving an
    area is a pure data movement with no renumbering.
    """
    if old.n_areas % new_n_groups != 0:
        raise ValueError(
            f"cannot rebalance {old.n_areas} areas onto {new_n_groups} groups"
        )
    per = old.n_areas // new_n_groups
    plan: dict[int, tuple[int, int]] = {}
    for a in range(old.n_areas):
        plan[a] = (old.group_of_area(a), a // per)
    return plan


def placement_from_sizes(
    area_sizes: tuple[int, ...] | list[int],
    n_groups: int,
    *,
    n_pad: int,
    group_size: int = 1,
) -> StructureAwarePlacement:
    """A placement from already-built network metadata (no MultiAreaSpec).

    Checkpoint resume works from a manifest + an instantiated ``Network``
    (area sizes = live-neuron counts, ``n_pad`` already fixed), not from the
    original spec; this constructor lets the resume path build the *old*
    placement recorded in the manifest and plan the elastic re-mesh.
    """
    n_areas = len(area_sizes)
    if n_areas % n_groups != 0:
        raise ValueError(
            f"n_areas={n_areas} not divisible by n_groups={n_groups}")
    return StructureAwarePlacement(
        n_groups=n_groups,
        group_size=group_size,
        areas_per_group=n_areas // n_groups,
        n_pad=n_pad,
        area_sizes=tuple(int(s) for s in area_sizes),
    )


def reshard_area_order(plan: dict[int, tuple[int, int]]) -> np.ndarray:
    """Global area order implied by a reshard plan (new-group-major).

    The gather/re-scatter step of elastic resume: per-area state rows are
    re-laid-out so that each *new* group's areas are contiguous (ties broken
    by area id, matching ``StructureAwarePlacement.areas_of_group``). For the
    contiguous plans :func:`elastic_reshard_plan` emits this is the identity
    permutation -- asserted by the resume tests, since any non-identity
    order here would have to be applied to the inter-table shard cut too.
    """
    areas = np.arange(len(plan))
    new_groups = np.asarray([plan[int(a)][1] for a in areas])
    return areas[np.argsort(new_groups, kind="stable")]


def reshard_moves(plan: dict[int, tuple[int, int]]) -> int:
    """How many areas change device group under the plan.

    Group ids are renumbered when the group count changes, so "moved" means
    the area's *peer set* changed: the set of areas co-hosted with it differs
    between the old and new placement. This is the data-movement count an
    elastic restart actually pays (areas whose whole group maps 1:1 onto a
    new group need no cross-device traffic).
    """
    old_peers: dict[int, list[int]] = {}
    new_peers: dict[int, list[int]] = {}
    for a, (og, ng) in plan.items():
        old_peers.setdefault(og, []).append(a)
        new_peers.setdefault(ng, []).append(a)
    moved = 0
    for a, (og, ng) in plan.items():
        if old_peers[og] != new_peers[ng]:
            moved += 1
    return moved
