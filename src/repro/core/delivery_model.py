"""§2.3 -- cache model of spike delivery (irregular memory access fractions).

Delivering a spike to its *first* target synapse on a thread is an irregular
(uncached) access; subsequent targets on the same thread are sequential. The
paper derives the fraction of irregular accesses for both placements
(eqs. 13-17); the structure-aware placement keeps all intra-area targets on
one process, so its advantage grows with M and T_M (Fig. 6b).

On TPU the role of 'thread' is played by the VMEM tile an area shard maps to,
and 'irregular access' corresponds to gather rows touching distinct tiles; the
formulas carry over unchanged (they only count first-touch probabilities).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "p_target_conventional",
    "f_irr_conventional",
    "p_target_intra",
    "p_target_inter",
    "f_irr_structure_aware",
    "fig6b_reduction",
]


def p_target_conventional(n: int, n_t: float, k_n: float) -> float:
    """Eq. (13): P(a neuron has >= 1 target on a specific thread)."""
    return 1.0 - (1.0 - 1.0 / n) ** (n_t * k_n)


def f_irr_conventional(n: int, k_n: float, m: int, t_m: int) -> float:
    """Eq. (14): irregular-access fraction, round-robin placement."""
    t = m * t_m
    n_t = n / t
    return p_target_conventional(n, n_t, k_n) * t / k_n


def p_target_intra(n_m: float, n_t: float, k_intra: float) -> float:
    """Eq. (15): >= 1 intra-area target on a thread of the hosting process."""
    return 1.0 - (1.0 - 1.0 / n_m) ** (n_t * k_intra)


def p_target_inter(n: int, n_m: float, n_t: float, k_inter: float) -> float:
    """Eq. (16): >= 1 inter-area target on a thread of a remote process."""
    return 1.0 - (1.0 - 1.0 / (n - n_m)) ** (n_t * k_inter)


def f_irr_structure_aware(
    n: int,
    k_n: float,
    m: int,
    t_m: int,
    k_intra: float | None = None,
    k_inter: float | None = None,
) -> float:
    """Eq. (17): irregular-access fraction, structure-aware placement.

    Defaults to the paper's equal split K_intra = K_inter = K_N / 2 and equal
    area sizes N_M = N / M.
    """
    if k_intra is None:
        k_intra = k_n / 2
    if k_inter is None:
        k_inter = k_n / 2
    n_m = n / m
    n_t = n / (m * t_m)
    p_i = p_target_intra(n_m, n_t, k_intra)
    p_e = p_target_inter(n, n_m, n_t, k_inter) if m > 1 else 0.0
    return (p_i * t_m + p_e * t_m * (m - 1)) / k_n


def fig6b_reduction(
    m: int,
    t_m: int,
    n_m: int = 130_000,
    k_n: int = 6000,
) -> tuple[float, float, float]:
    """Weak-scaling point of Fig. 6b: (f_conv, f_struc, relative reduction).

    Weak scaling: N = M * N_M. The paper quotes reductions of 12 % (M=32,
    T_M=48), 29 % (M=32, T_M=128), 37 % (M=128, T_M=48), 43 % (M=128,
    T_M=128); tests assert these within rounding.
    """
    n = m * n_m
    f_c = f_irr_conventional(n, k_n, m, t_m)
    f_s = f_irr_structure_aware(n, k_n, m, t_m)
    return f_c, f_s, 1.0 - f_s / f_c
