"""Single-host reference engine: conventional vs structure-aware schedules.

This is the semantic reference for the distributed engine and the Pallas
kernels. It advances the network in *windows* of ``D`` cycles (``D`` = delay
ratio, paper eq. (1)); each cycle is the paper's deliver -> update -> collocate
sequence (Fig. 3):

* ``conventional``: inter-area spikes are delivered every cycle (this is what
  the per-cycle global ``MPI_Alltoall`` achieves in the reference code);
* ``structure_aware``: inter-area spikes are *accumulated* for the whole
  window and delivered in one lumped exchange at the window end. Causality is
  guaranteed because every inter-area delay is >= D steps.

Both schedules produce **bit-identical** spike trains: delivery weights live on
an exact 1/256 grid, so f32 ring accumulation is associative-exact, and the
external drive is a counter-based function of absolute model time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import neuron as neuron_lib
from repro.core import ring_buffer

__all__ = ["EngineConfig", "SimState", "Engine", "make_engine"]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    neuron_model: str = "lif"  # 'lif' | 'ignore_and_fire'
    schedule: str = STRUCTURE_AWARE  # 'conventional' | 'structure_aware'
    seed: int = 42
    lif: neuron_lib.LIFParams = dataclasses.field(
        default_factory=neuron_lib.LIFParams
    )
    # When True use the one-hot-einsum deposit (reference semantics, small K);
    # when False use scatter-add (production / large K). Results are identical.
    deposit_onehot: bool = True
    # 'dense': gather-matvec over every synapse each cycle (paper-faithful
    # baseline; what the Pallas kernel implements). 'event': compact the
    # fired neurons and scatter their outgoing targets -- exploits the
    # 0.025%-per-cycle firing sparsity for a >1000x multiply reduction
    # (EXPERIMENTS.md §Perf). Requires build_network(outgoing=True).
    delivery: str = "dense"
    # Event-buffer headroom: s_max = headroom x expected spikes/cycle + floor
    # (cf. NEST's dynamic spike-register resizing; static here). The event
    # path's cost is s_max-bound, so the bound tracks the expected rate.
    s_max_headroom: float = 8.0
    s_max_floor: int = 16

    def __post_init__(self) -> None:
        if self.neuron_model not in ("lif", "ignore_and_fire"):
            raise ValueError(f"unknown neuron model {self.neuron_model!r}")
        if self.schedule not in (CONVENTIONAL, STRUCTURE_AWARE):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.delivery not in ("dense", "event"):
            raise ValueError(f"unknown delivery {self.delivery!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes


class Engine(NamedTuple):
    init: Callable[[], SimState]
    # Advance one window of D cycles; returns (state', spikes[D, A, n_pad] bool).
    window: Callable[[SimState], tuple[SimState, jax.Array]]
    # Advance n_windows via scan; returns (state', total spikes per window [W]).
    run: Callable[[SimState, int], tuple[SimState, jax.Array]]
    config: EngineConfig
    delay_ratio: int
    # Distributed engines also expose the raw shard_map'd window
    # (state, net, gids) -> (state, block), used by the dry-run to lower with
    # ShapeDtypeStruct connectivity (production scale, no allocation).
    window_raw: Callable | None = None


def _gather_intra(spikes_f32: jax.Array, src_intra: jax.Array) -> jax.Array:
    """[A, N] spikes, [A, N, K] per-area source indices -> [A, N, K] values."""
    return jax.vmap(lambda s, idx: s[idx])(spikes_f32, src_intra)


def _gather_inter(spikes_f32: jax.Array, src_inter: jax.Array) -> jax.Array:
    """[A, N] spikes, [A, N, K] *global* source ids -> [A, N, K] values."""
    return spikes_f32.reshape(-1)[src_inter]


def _deposit(ring, vals, delays, t, *, onehot: bool):
    a, n, r = ring.shape
    k = vals.shape[-1]
    fn = ring_buffer.deposit if onehot else ring_buffer.deposit_scatter
    out = fn(ring.reshape(a * n, r), vals.reshape(a * n, k),
             delays.reshape(a * n, k), t)
    return out.reshape(a, n, r)


def make_engine(
    net: Network,
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
) -> Engine:
    """Build a jitted reference engine for ``net``.

    The returned callables close over the (host-resident) connectivity; the
    distributed engine in ``dist_engine.py`` shards the same computation.
    """
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    cfg = config
    if cfg.delivery == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    lif_params = cfg.lif
    if abs(lif_params.dt_ms - net.dt_ms) > 1e-12:
        lif_params = dataclasses.replace(lif_params, dt_ms=net.dt_ms)

    # Per-neuron external drive rate for LIF: scaled by the area's target rate
    # relative to the 2.5 Hz reference, which induces the across-area activity
    # heterogeneity studied in Fig. 8b / §2.4.3.
    drive_rate = net.rate_hz / 2.5 * spec.ext_rate_hz
    gids = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    def _update(neuron_state, i_in, t):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, drive_rate, net.dt_ms, spec.w_ext
            )
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params
            )
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, net.dt_ms
        )

    mean_rate = float(jnp.asarray(net.rate_hz).mean()) if hasattr(
        net.rate_hz, "mean") else 2.5
    exp_area = n_pad * mean_rate * net.dt_ms * 1e-3
    s_max_area = max(cfg.s_max_floor, int(cfg.s_max_headroom * exp_area + 8))
    s_max_all = max(4 * cfg.s_max_floor,
                    int(cfg.s_max_headroom * exp_area * A + 32))

    def _deliver_intra(ring, spikes_f32, t):
        if cfg.delivery == "event":
            from repro.kernels import ops as kops

            return jax.vmap(
                lambda r, sp, tg, w, d: kops.event_deliver(
                    r, sp > 0, tg, w, d, t, s_max=s_max_area)
            )(ring, spikes_f32, net.tgt_intra, net.wout_intra, net.dout_intra)
        vals = net.w_intra * _gather_intra(spikes_f32, net.src_intra)
        return _deposit(ring, vals, net.delay_intra, t, onehot=cfg.deposit_onehot)

    def _deliver_inter(ring, spikes_f32, t):
        if net.k_inter == 0:
            return ring
        if cfg.delivery == "event":
            from repro.kernels import ops as kops

            r = ring.shape[-1]
            k_out = net.tgt_inter.shape[-1]
            flat = kops.event_deliver(
                ring.reshape(A * n_pad, r),
                spikes_f32.reshape(-1) > 0,
                net.tgt_inter.reshape(A * n_pad, k_out),
                net.wout_inter.reshape(A * n_pad, k_out),
                net.dout_inter.reshape(A * n_pad, k_out),
                t, s_max=s_max_all,
            )
            return flat.reshape(A, n_pad, r)
        vals = net.w_inter * _gather_inter(spikes_f32, net.src_inter)
        return _deposit(ring, vals, net.delay_inter, t, onehot=cfg.deposit_onehot)

    def _cycle(state: SimState, deliver_inter_now: bool):
        """deliver -> update -> collocate for one dt step."""
        i_in, ring = ring_buffer.read_and_clear(state.ring, state.t)
        neuron_state, spikes = _update(state.neuron, i_in, state.t)
        sf = spikes.astype(jnp.float32)
        ring = _deliver_intra(ring, sf, state.t)
        if deliver_inter_now:
            ring = _deliver_inter(ring, sf, state.t)
        new_state = SimState(
            neuron=neuron_state,
            ring=ring,
            t=state.t + 1,
            spike_count=state.spike_count + spikes.astype(jnp.int32),
        )
        return new_state, spikes

    def window(state: SimState) -> tuple[SimState, jax.Array]:
        t0 = state.t
        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence inter delivery) every cycle.
            def body(st, _):
                return _cycle(st, deliver_inter_now=True)

            state, spikes = jax.lax.scan(body, state, None, length=D)
            return state, spikes

        # Structure-aware: local-only cycles, lumped inter delivery at the end.
        def body(st, _):
            return _cycle(st, deliver_inter_now=False)

        state, spikes = jax.lax.scan(body, state, None, length=D)

        # The lumped 'global communication': deliver the whole [D, A, N] block.
        # Every inter-area delay is >= D, so slot (t0+s+d) is strictly in the
        # future of the last cycle read -- causality is preserved (paper §2.1).
        def deliver_s(s, ring):
            return _deliver_inter(ring, spikes[s].astype(jnp.float32), t0 + s)

        ring = jax.lax.fori_loop(0, D, deliver_s, state.ring)
        return dataclasses.replace(state, ring=ring), spikes

    window_jit = jax.jit(window)

    def init() -> SimState:
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids
            )
        return SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, net.ring_len), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
        )

    @functools.partial(jax.jit, static_argnums=1)
    def run(state: SimState, n_windows: int) -> tuple[SimState, jax.Array]:
        def body(st, _):
            st, spikes = window(st)
            return st, spikes.sum(dtype=jnp.int32)

        return jax.lax.scan(body, state, None, length=n_windows)

    return Engine(
        init=init, window=window_jit, run=run, config=cfg, delay_ratio=D
    )
