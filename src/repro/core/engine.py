"""Single-host reference engine: a thin assembly over the shared window core.

The engine advances the network in *windows* of ``D`` cycles (``D`` = delay
ratio, paper eq. (1)); each cycle is the paper's deliver -> update ->
collocate sequence (Fig. 3):

* ``conventional``: inter-area spikes are delivered every cycle;
* ``structure_aware``: inter-area spikes are *accumulated* for the whole
  window and delivered in one lumped exchange at the window end. Causality
  is guaranteed because every inter-area delay is >= D steps.

Both schedules produce **bit-identical** spike trains: delivery weights live
on an exact 1/256 grid, so f32 ring accumulation is associative-exact, and
the external drive is a counter-based function of absolute model time.

The window/cycle bodies live in :mod:`repro.core.schedule`, shared with the
distributed engine (``dist_engine.py``) and parameterized by an
:class:`repro.core.exchange.Exchange`; this module only resolves the config,
builds the single-host :class:`~repro.core.exchange.LocalExchange`, and jits
the assembled window. The per-cycle *deliver* hot path is backend-selectable
(``EngineConfig.delivery_backend``) -- see :mod:`repro.core.delivery`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import delivery as delivery_lib
from repro.core import exchange as exchange_lib
from repro.core import faults as faults_lib
from repro.core import neuron as neuron_lib
from repro.core import schedule as schedule_lib
from repro.core.schedule import CONVENTIONAL, STRUCTURE_AWARE, SimState

__all__ = [
    "ConfigError",
    "ConfigViolation",
    "EngineConfig",
    "SimState",
    "Engine",
    "make_engine",
    "CONVENTIONAL",
    "STRUCTURE_AWARE",
]


@dataclasses.dataclass(frozen=True)
class ConfigViolation:
    """One broken EngineConfig rule: which field, what's wrong, how to fix."""

    field: str
    problem: str
    remedy: str

    def __str__(self) -> str:
        return f"{self.field}: {self.problem} [remedy: {self.remedy}]"


class ConfigError(ValueError):
    """All of a config's rule violations in one structured error.

    ``EngineConfig`` used to refuse invalid combinations one raise at a
    time, scattered between ``__post_init__``, ``make_engine`` and
    ``make_dist_engine`` -- fixing a config meant replaying the constructor
    until it stopped throwing. ``EngineConfig.validate()`` now evaluates
    *every* rule and this error carries the full list (``.violations``),
    each with a remedy.
    """

    def __init__(self, violations):
        self.violations: tuple[ConfigViolation, ...] = tuple(violations)
        n = len(self.violations)
        lines = "\n".join(f"  - {v}" for v in self.violations)
        super().__init__(
            f"invalid EngineConfig ({n} rule"
            f"{'s' if n != 1 else ''} violated):\n{lines}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    neuron_model: str = "lif"  # 'lif' | 'ignore_and_fire'
    schedule: str = STRUCTURE_AWARE  # 'conventional' | 'structure_aware'
    seed: int = 42
    lif: neuron_lib.LIFParams = dataclasses.field(
        default_factory=neuron_lib.LIFParams
    )
    # The per-cycle deliver hot path: 'onehot' | 'scatter' | 'pallas' |
    # 'event' (see repro.core.delivery). '' defaults to 'onehot'; the
    # `backend` property is the single dispatch point.
    delivery_backend: str = ""
    # How spikes travel between distributed shards (repro.core.exchange):
    # 'dense' (mesh-wide collectives) | 'routed' (connectivity-routed packet
    # rounds over the area-adjacency group graph; structure-aware only).
    # '' resolves to 'local' for the single-host engine and 'dense' for the
    # distributed one.
    exchange: str = ""
    # Distributed event/routed receive tables: True (default) re-cuts the
    # replicated outgoing inter tables into per-shard *inbound* slices
    # (connectivity.shard_inter_tables) so each device stores and scatters
    # only the inter edges it owns (~1/S of the bytes and receive work);
    # False keeps the legacy replicated tables -- the bit-identity
    # reference for the equivalence suite. Single-host engines ignore it.
    shard_inter_tables: bool = True
    # On top of shard_inter_tables, slice each group's inbound inter table
    # over the subgroup (window-within-group) axis as well
    # (connectivity.shard_inter_tables(subgroup=gsz)): the [S, rows, K_in]
    # stack becomes [S, gsz, rows, K_in] and every device lane holds only
    # the rows targeting its own neuron window -- ~gsz x smaller inter
    # slices at identical trajectories (the receive scatter already masks
    # foreign targets to -1). The event path's outgoing intra tables get
    # the same cut (connectivity.slice_intra_tables: [A, n_pad, K_out] ->
    # [gsz, A, n_pad, K_lane]), removing their per-lane replication -- at
    # production scale the dominant per-device table cost. Structure-aware
    # distributed engines only; ignored under shard_inter_tables=False and
    # by the conventional schedule (whose "window" cut is already
    # per-device).
    subgroup_inter_tables: bool = True
    # Use the fused Pallas LIF kernel (kernels.ops.lif_update) for the update
    # phase. None = enable exactly when delivery_backend is 'pallas' (the
    # all-kernel cycle); the flag exists so the fused update can be tested
    # against the jnp chain under every backend.
    fused_update: bool | None = None
    # Event-buffer headroom: s_max = headroom x expected spikes/cycle + slack
    # (cf. NEST's dynamic spike-register resizing; static here). The event
    # path's cost is s_max-bound, so the bound tracks the expected rate;
    # overruns are counted in SimState.overflow.
    s_max_headroom: float = 8.0
    s_max_floor: int = 16
    # Multiplies only the whole-network event bound's constant burst slack
    # (delivery.event_bounds' `4 x floor` term), leaving the per-area bound
    # alone. launch.serve sets this to its fold factor B so a B-copy folded
    # batch keeps the same per-copy burst headroom as B sequential runs --
    # scaling s_max_floor instead would widen every per-area packet B x.
    s_max_burst: int = 1
    # Adaptive two-phase exchange (repro.core.exchange): phase 1 moves a
    # tiny int32 count collective, phase 2 ships packets sized by the
    # smallest power-of-two bucket (>= s_max_floor, pre-compiled ladder) that
    # covers the counted need -- quiet windows ship floor-sized packets, and
    # because the ladder tops out at the hard population cap, a packet can
    # NEVER drop a spike: SimState.overflow is provably 0 and the static
    # s_max_headroom bound becomes irrelevant. Applies wherever id packets
    # exist (event-backend packets on every exchange, the routed global
    # pathway under any backend; the dense bit-packed pathways have nothing
    # to size and are unaffected). Trajectories are bit-identical to the
    # static path whenever the static path itself reports overflow == 0.
    adaptive_exchange: bool = False
    # Fuse the structure-aware window into one D-cycle superstep: blocked
    # ring read/clear (one [.., D] slice per window instead of D dynamic
    # slot updates), D unrolled cycles with window-static slot indices, and a
    # single-pass lumped inter delivery (delivery.deliver_inter_block) in
    # place of the window-end loop of D sequential deliver_inter calls.
    # None = enabled exactly for the structure-aware schedule (the
    # conventional schedule exchanges every cycle, so there is no window to
    # fuse); False forces the legacy per-cycle scan, kept as the semantic
    # reference for the equivalence/overflow suites.
    superstep: bool | None = None
    # Python-unroll the superstep's D cycles (fully static slot indices).
    # Default False: the cycle loop stays a lax.scan over the *live window
    # buffer* (cheap [.., W] column access instead of full-ring updates) --
    # unrolling the jnp graph multiplies the XLA op count ~Dx, which on the
    # CPU backend costs more in per-op dispatch than the static indices
    # save. The fused Pallas kernel (superstep_kernel) always unrolls
    # in-kernel, where the cycles fuse into one VMEM-resident program.
    superstep_unroll: bool = False
    # Run the window body as the fused Pallas superstep kernel
    # (kernels.cycle): membrane state and the live ring slots stay in VMEM
    # across the D unrolled cycles (update + intra delivery fused); the
    # lumped inter exchange still goes through the selected backend.
    # Single-host structure-aware engine only. NOTE on overflow semantics
    # with delivery_backend='event': the kernel's intra delivery is dense
    # (delay-resolved), so the intra packet bound s_max_area does not apply
    # -- intra spikes can neither drop nor count toward SimState.overflow;
    # only the inter packet bound remains. Identical trajectories to the
    # unfused event engine are therefore guaranteed only while the unfused
    # engine reports overflow == 0 (its own exactness condition anyway).
    superstep_kernel: bool = False
    # Double-buffer the structure-aware window-end exchange
    # (repro.core.exchange start_window_end/finish_window_end): window w's
    # payload collectives are issued at the end of w's compute and their
    # receive scatter deferred to the top of w+1 -- on hardware with async
    # collectives (see launch.simulate.xla_overlap_flags) the transfer
    # overlaps w+1's compute, so the per-window wall tracks
    # max(compute, comm) instead of their sum (the order-statistics claim
    # of sync_model.expected_wall_overlapped). Bit-identical to the
    # sequential schedule: same packets, same scatter values, and the
    # in-flight window drains at every checkpoint/preemption boundary, so
    # saved states ARE sequential states (resume_config_hash treats the
    # flag as layout, not trajectory). Structure-aware schedule only.
    overlap_exchange: bool = False
    # Host-free sharded construction (connectivity.sharded_build_plan /
    # build_shard_tables): the distributed engine generates each device's
    # inbound inter slices and lane-cut intra tables directly from the
    # seeded counter-based connectivity rules
    # (dist_engine.build_network_sharded) instead of slicing a host-built
    # global network -- no process ever materialises the global
    # src_inter/w_inter/delay_inter tensors, so host peak RSS scales with
    # ONE shard's tables, not the model. Bitwise-identical trajectories to
    # the host-build path by the counter-draw row identity
    # (connectivity.draw_pathway_rows); pure layout, not trajectory
    # (resume_config_hash excludes it). Requires the event backend +
    # sharded inbound tables + the structure-aware schedule (the layouts
    # the sharded builders emit); distributed engines only.
    sharded_build: bool = False
    # Host-side fault-injection plan (repro.core.faults.FaultConfig): per-
    # device compute jitter slept at window boundaries, transient
    # checkpoint-write failures, simulated preemption. Consumed by the
    # windowed run loop (schedule.run_windows) only -- nothing here is
    # traced into the jitted window body, so the trajectory is untouched;
    # None injects nothing.
    faults: faults_lib.FaultConfig | None = None

    def __post_init__(self) -> None:
        self.check()

    def validate(
        self, *, distributed: bool | None = None
    ) -> "list[ConfigViolation]":
        """Evaluate *every* config rule and return the full violation list.

        ``distributed=None`` checks the construction-time rules only (the
        set ``__post_init__`` enforces). ``distributed=False`` adds the
        single-host engine's context rules; ``distributed=True`` the
        distributed engine's. The factories pass the dispatch target so a
        bad config surfaces its complete rule list in one structured
        :class:`ConfigError` instead of one raise per constructor replay.
        """
        v: list[ConfigViolation] = []
        if self.neuron_model not in ("lif", "ignore_and_fire"):
            v.append(ConfigViolation(
                "neuron_model",
                f"unknown neuron model {self.neuron_model!r}",
                "use 'lif' or 'ignore_and_fire'"))
        if self.schedule not in (CONVENTIONAL, STRUCTURE_AWARE):
            v.append(ConfigViolation(
                "schedule",
                f"unknown schedule {self.schedule!r}",
                f"use {CONVENTIONAL!r} or {STRUCTURE_AWARE!r}"))
        if self.delivery_backend not in ("",) + delivery_lib.BACKENDS:
            v.append(ConfigViolation(
                "delivery_backend",
                f"unknown delivery_backend {self.delivery_backend!r} "
                f"(expected one of {delivery_lib.BACKENDS})",
                "pick a listed backend, or '' for the default"))
        if self.exchange not in ("",) + exchange_lib.EXCHANGES:
            v.append(ConfigViolation(
                "exchange",
                f"unknown exchange {self.exchange!r} "
                f"(expected one of {exchange_lib.EXCHANGES})",
                "pick a listed exchange, or '' for the default"))
        if self.s_max_burst < 1:
            v.append(ConfigViolation(
                "s_max_burst",
                f"s_max_burst={self.s_max_burst} would shrink the "
                "whole-network event bound's burst slack below its floor",
                "use an integer >= 1 (B for a B-trial folded batch)"))
        if self.exchange == "routed" and self.schedule != STRUCTURE_AWARE:
            v.append(ConfigViolation(
                "exchange",
                "exchange='routed' routes the structure-aware window's "
                "lumped global pathway; the conventional schedule has none",
                "use schedule='structure_aware', or exchange='dense'"))
        if self.superstep is True and self.schedule != STRUCTURE_AWARE:
            v.append(ConfigViolation(
                "superstep",
                "superstep=True requires the structure-aware schedule; "
                "the conventional schedule exchanges every cycle and has "
                "no window to fuse",
                "use schedule='structure_aware', or superstep=None"))
        if self.superstep_kernel:
            if self.schedule != STRUCTURE_AWARE:
                v.append(ConfigViolation(
                    "superstep_kernel",
                    "superstep_kernel fuses the structure-aware window; "
                    "the conventional schedule has no window to fuse",
                    "use schedule='structure_aware'"))
            if self.superstep is False:
                v.append(ConfigViolation(
                    "superstep_kernel",
                    "superstep_kernel=True conflicts with superstep=False",
                    "drop one of the two flags"))
        if self.overlap_exchange and self.schedule != STRUCTURE_AWARE:
            v.append(ConfigViolation(
                "overlap_exchange",
                "overlap_exchange double-buffers the structure-aware "
                "window-end exchange; the conventional schedule has no "
                "lumped exchange to overlap",
                "use schedule='structure_aware', or drop overlap_exchange"))
        if self.sharded_build:
            if self.backend != "event":
                v.append(ConfigViolation(
                    "sharded_build",
                    "sharded_build generates the event path's inbound/"
                    "outgoing tables; dense backends read the global "
                    "incoming tensors it never materialises",
                    "use delivery_backend='event'"))
            if not self.shard_inter_tables:
                v.append(ConfigViolation(
                    "sharded_build",
                    "sharded_build emits per-shard inbound inter slices; "
                    "shard_inter_tables=False asks for the replicated "
                    "layout it exists to avoid",
                    "keep shard_inter_tables=True"))
            if self.schedule != STRUCTURE_AWARE:
                v.append(ConfigViolation(
                    "sharded_build",
                    "sharded_build targets the structure-aware placement "
                    "(area groups x subgroup lanes); the conventional "
                    "schedule slices a host-built network",
                    "use schedule='structure_aware'"))
        if distributed is False:
            if self.exchange not in ("", "local"):
                v.append(ConfigViolation(
                    "exchange",
                    f"exchange={self.exchange!r} needs a device mesh; the "
                    "single-host engine is exchange-free "
                    "(use make_dist_engine)",
                    "pass mesh=... to make_simulation, or use exchange=''"))
            if self.sharded_build:
                v.append(ConfigViolation(
                    "sharded_build",
                    "sharded_build is a distributed construction mode; the "
                    "single-host engine holds the whole network anyway "
                    "(use make_dist_engine)",
                    "pass mesh=... to make_simulation"))
        if distributed is True:
            if self.superstep_kernel:
                v.append(ConfigViolation(
                    "superstep_kernel",
                    "superstep_kernel is single-host only; the distributed "
                    "engine fuses the window at the jnp level "
                    "(use_superstep)",
                    "drop superstep_kernel (the jnp superstep fusion is "
                    "the distributed default)"))
        return v

    def check(self, *, distributed: bool | None = None) -> None:
        """Raise :class:`ConfigError` listing every violated rule, if any."""
        violations = self.validate(distributed=distributed)
        if violations:
            raise ConfigError(violations)

    @property
    def backend(self) -> str:
        """The resolved delivery backend ('' defaults to 'onehot')."""
        return self.delivery_backend or "onehot"

    @property
    def fused(self) -> bool:
        """Whether the update phase runs the fused Pallas LIF kernel."""
        if self.fused_update is None:
            return self.backend == "pallas"
        return self.fused_update

    @property
    def use_superstep(self) -> bool:
        """Whether the window runs as one fused D-cycle superstep."""
        if self.schedule != STRUCTURE_AWARE:
            return False
        return True if self.superstep is None else self.superstep


class Engine(NamedTuple):
    init: Callable[[], SimState]
    # Advance one window of D cycles; returns (state', spikes[D, A, n_pad] bool).
    window: Callable[[SimState], tuple[SimState, jax.Array]]
    # Advance n_windows via scan; returns (state', total spikes per window [W]).
    run: Callable[[SimState, int], tuple[SimState, jax.Array]]
    config: EngineConfig
    delay_ratio: int
    # Distributed engines also expose the raw shard_map'd window
    # (state, net, gids) -> (state, block), used by the dry-run to lower with
    # ShapeDtypeStruct connectivity (production scale, no allocation).
    window_raw: Callable | None = None
    # Static mesh-total wire bytes per window of the selected exchange
    # (repro.core.exchange; all zeros for the single-host LocalExchange).
    wire_bytes: dict | None = None
    # Distributed engines: device_put a host/global SimState onto this
    # engine's mesh with the schedule's shardings -- the re-scatter half of
    # checkpoint restore (incl. elastic reshard onto a different group
    # count). None for the single-host engine (restore needs no placement).
    shard_state: Callable | None = None
    # Overlapped pipeline (EngineConfig.overlap_exchange; None otherwise):
    # advance one window while finishing the previous window's in-flight
    # exchange -- (state, InflightWindow) -> (state', InflightWindow',
    # block). `window` stays available as the drained per-window
    # composition (start + immediate finish), bit-identical but unpipelined.
    window_overlap: Callable | None = None
    # Retire an in-flight window: (state, InflightWindow) -> state' with the
    # pending receive scatter applied -- run at checkpoint/preemption/run-end
    # boundaries so the observable state is the sequential trajectory.
    drain: Callable | None = None
    # () -> an empty (scatters-nothing) InflightWindow on this engine's
    # devices: what the pipeline starts from and resets to after a drain.
    init_inflight: Callable | None = None


def make_fused_lif_update(params: neuron_lib.LIFParams):
    """An ``(state, i_in, alive) -> (state', spikes)`` closure over the fused
    Pallas kernel, signature-compatible with :func:`repro.core.neuron.lif_update`."""
    from repro.kernels import ops as kops

    kw = dict(
        p11=params.p11, p21=params.p21, p22=params.p22,
        v_th=params.v_th_mv, v_reset=params.v_reset_mv,
        t_ref_steps=params.t_ref_steps,
    )

    def update(state, i_in, alive):
        v, i_syn, refrac, spikes = kops.lif_update(
            state.v, state.i_syn, state.refrac, i_in, alive, **kw)
        return neuron_lib.LIFState(v=v, i_syn=i_syn, refrac=refrac), spikes

    return update


def resolve_params(net: Network, spec: MultiAreaSpec, cfg: EngineConfig):
    """``(lif_params, drive_rate)`` as the engines actually run them.

    The dt-corrected LIF propagators and the per-neuron external drive rate
    ``rate_hz * (ext_rate_hz / 2.5)`` -- the area rate relative to the 2.5 Hz
    reference scales ``spec.ext_rate_hz`` (Fig. 8b heterogeneity), in the
    exact expression the shared update closure uses
    (:func:`repro.core.schedule.make_update_fn`), so the fused superstep
    kernel and the phase profiler time/drive the same math bit-for-bit.
    """
    lif_params = cfg.lif
    if abs(lif_params.dt_ms - net.dt_ms) > 1e-12:
        lif_params = dataclasses.replace(lif_params, dt_ms=net.dt_ms)
    # ShapeDtypeStruct stand-ins (dry-run lowering) carry no data to scale;
    # the eager drive_rate is only consumed by the single-host fused kernel
    # and the phase profiler, which always hold real networks.
    drive_rate = (
        net.rate_hz * (spec.ext_rate_hz / 2.5)
        if hasattr(net.rate_hz, "__array__") else None
    )
    return lif_params, drive_rate


def make_fused_superstep(
    net: Network,
    spec: MultiAreaSpec,
    cfg: EngineConfig,
    lif_params: neuron_lib.LIFParams,
    drive_rate: jax.Array,
    gids: jax.Array,
):
    """A ``(neuron_state, fut, t0) -> (state', spikes[D, A, n] bool, fut')``
    closure over the fused Pallas superstep kernel (:mod:`repro.kernels.cycle`).

    The kernel advances all D cycles of a window with membrane state and the
    live window slots VMEM-resident, reproducing the unfused cycle body
    bit-for-bit (same LIF propagators, same counter-based drive, 1/256-grid
    intra deposits). With the event backend the kernel's *dense* intra
    delivery has no packet bound, so equality with the unfused event engine
    holds exactly while that engine reports zero overflow (see the
    ``EngineConfig.superstep_kernel`` note).
    """
    from repro.kernels import ops as kops

    D = net.delay_ratio
    steps_lo = net.steps_lo_intra
    r_span = net.r_span_intra if net.k_intra > 0 else 0

    if cfg.neuron_model == "lif":
        p = lif_params
        drive_p = drive_rate * (net.dt_ms * 1e-3)
        kw = dict(
            p11=p.p11, p21=p.p21, p22=p.p22, v_th=p.v_th_mv,
            v_reset=p.v_reset_mv, t_ref_steps=p.t_ref_steps,
            seed=cfg.seed, w_ext=spec.w_ext,
        )

        def run_lif(neuron_state, fut, t0):
            v, i_syn, refrac, fut, spk = kops.superstep_lif(
                neuron_state.v, neuron_state.i_syn, neuron_state.refrac,
                fut, drive_p, gids, net.alive, net.src_intra, net.w_intra,
                net.delay_intra, t0,
                d_win=D, steps_lo=steps_lo, r_span=r_span, **kw)
            state = neuron_lib.LIFState(v=v, i_syn=i_syn, refrac=refrac)
            return state, jnp.moveaxis(spk, 0, 1) != 0, fut

        return run_lif

    # ignore_and_fire: the same static interval/phase rule as the jnp update.
    interval = neuron_lib.iaf_interval(net.rate_hz, net.dt_ms)

    def run_iaf(neuron_state, fut, t0):
        del t0  # emission is input- and time-base-independent
        cd, fut, spk = kops.superstep_iaf(
            neuron_state.countdown, fut, interval, net.alive,
            net.src_intra, net.w_intra, net.delay_intra,
            d_win=D, steps_lo=steps_lo, r_span=r_span)
        return neuron_lib.IafState(countdown=cd), jnp.moveaxis(spk, 0, 1) != 0, fut

    return run_iaf


def _make_engine(
    net: Network,
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
    *,
    gids: jax.Array | None = None,
) -> Engine:
    """Build a jitted reference engine for ``net``.

    The returned callables close over the (host-resident) connectivity; the
    distributed engine in ``dist_engine.py`` shards the same window body
    (:mod:`repro.core.schedule`) over a device mesh.

    ``gids`` overrides the global-id table fed to the counter-based drive
    and the iaf phase rule (default ``arange(A * n_pad)``). The serving
    layer's folded trial batches pass the single-trial ids tiled per copy so
    every copy of the block-diagonal super-network draws the single-trial
    noise stream bit-for-bit.
    """
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    cfg = config
    cfg.check(distributed=False)
    backend = cfg.backend
    if backend == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    lif_params, drive_rate = resolve_params(net, spec, cfg)
    fused_lif = make_fused_lif_update(lif_params) if cfg.fused else None
    if gids is None:
        gids = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    exchange = exchange_lib.LocalExchange(net, cfg)
    update_fn = schedule_lib.make_update_fn(
        cfg, spec, net.dt_ms, lif_params, fused_lif)
    fused_window = (
        make_fused_superstep(net, spec, cfg, lif_params, drive_rate, gids)
        if cfg.superstep_kernel else None
    )
    window_body = schedule_lib.make_window_fn(
        cfg, exchange, update_fn, fused_superstep=fused_window)

    overlap_jit = drain_jit = init_inflight = None
    if cfg.overlap_exchange:
        overlap_body, drain_body = schedule_lib.make_overlap_window_fn(
            cfg, exchange, update_fn, fused_superstep=fused_window)

        @jax.jit
        def overlap_jit(state, inflight):
            return overlap_body(state, inflight, net, gids)

        @jax.jit
        def drain_jit(state, inflight):
            return drain_body(state, inflight, net, gids)

        def init_inflight():
            return exchange.init_inflight(net)

        # The compatibility `window`: one overlapped window drained on the
        # spot -- bit-identical to the sequential window (finish of an empty
        # inflight is a no-op), so every unpipelined caller keeps working.
        @jax.jit
        def window(state: SimState) -> tuple[SimState, jax.Array]:
            st, inf, block = overlap_body(
                state, exchange.init_inflight(net), net, gids)
            return drain_body(st, inf, net, gids), block

    else:
        @jax.jit
        def window(state: SimState) -> tuple[SimState, jax.Array]:
            return window_body(state, net, gids)

    def init(seed=None, stim=None) -> SimState:
        """Fresh state; optional per-neuron drive overrides (serving trials).

        ``seed``/``stim`` become ``[A, n_pad]`` SimState leaves consumed by
        the drive in place of / on top of ``cfg.seed`` and the network rate
        (see :class:`repro.core.schedule.SimState`). Scalars broadcast; a
        broadcast scalar seed is bit-identical to the int-seed path. ``None``
        (the default) adds no pytree leaves, so existing state trees,
        checkpoints and shard specs are structurally unchanged.
        """
        if seed is not None or stim is not None:
            if cfg.neuron_model != "lif":
                raise ValueError(
                    "per-trial seed/stim drive the LIF Poisson input; "
                    "ignore_and_fire has no seed or input dependence"
                )
            if cfg.superstep_kernel:
                raise ValueError(
                    "per-trial seed/stim are not supported under "
                    "superstep_kernel (the fused kernel bakes cfg.seed in)"
                )
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids
            )
        return SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, net.ring_len), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
            overflow=jnp.int32(0),
            shipped_bytes=jnp.float32(0),
            seed=(
                None if seed is None
                else jnp.broadcast_to(
                    jnp.asarray(seed, jnp.uint32), (A, n_pad))
            ),
            stim=(
                None if stim is None
                else jnp.broadcast_to(
                    jnp.asarray(stim, jnp.float32), (A, n_pad))
            ),
        )

    if cfg.overlap_exchange:
        # Pipelined scan threading the in-flight window through, drained
        # once at the end -- the jitted fast path actually runs start/finish
        # split across windows, so XLA's latency-hiding scheduler can move
        # the collectives off the critical path.
        @functools.partial(jax.jit, static_argnums=1)
        def run(state: SimState, n_windows: int):
            def body(carry, _):
                st, inf = carry
                st, inf, spikes = overlap_body(st, inf, net, gids)
                return (st, inf), spikes.sum(dtype=jnp.int32)

            (state, inf), spikes = jax.lax.scan(
                body, (state, exchange.init_inflight(net)), None,
                length=n_windows)
            return drain_body(state, inf, net, gids), spikes
    else:
        @functools.partial(jax.jit, static_argnums=1)
        def run(state: SimState, n_windows: int):
            def body(st, _):
                st, spikes = window_body(st, net, gids)
                return st, spikes.sum(dtype=jnp.int32)

            return jax.lax.scan(body, state, None, length=n_windows)

    return Engine(
        init=init, window=window, run=run, config=cfg, delay_ratio=D,
        wire_bytes=exchange.wire_bytes(net),
        window_overlap=overlap_jit, drain=drain_jit,
        init_inflight=init_inflight,
    )


def make_engine(
    net: Network,
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
    *,
    gids: jax.Array | None = None,
) -> Engine:
    """Deprecated alias for :func:`repro.core.make_simulation`.

    Same engine, same trajectories -- only the entry point moved: the
    unified factory dispatches to this single-host assembly when no mesh is
    given.
    """
    import warnings

    warnings.warn(
        "make_engine is deprecated; use repro.core.make_simulation"
        "(spec, config, net=net) -- it builds the identical single-host "
        "engine when no mesh is given",
        DeprecationWarning,
        stacklevel=2,
    )
    return _make_engine(net, spec, config, gids=gids)
