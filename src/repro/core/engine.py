"""Single-host reference engine: conventional vs structure-aware schedules.

This is the semantic reference for the distributed engine and the Pallas
kernels. It advances the network in *windows* of ``D`` cycles (``D`` = delay
ratio, paper eq. (1)); each cycle is the paper's deliver -> update -> collocate
sequence (Fig. 3):

* ``conventional``: inter-area spikes are delivered every cycle (this is what
  the per-cycle global ``MPI_Alltoall`` achieves in the reference code);
* ``structure_aware``: inter-area spikes are *accumulated* for the whole
  window and delivered in one lumped exchange at the window end. Causality is
  guaranteed because every inter-area delay is >= D steps.

Both schedules produce **bit-identical** spike trains: delivery weights live on
an exact 1/256 grid, so f32 ring accumulation is associative-exact, and the
external drive is a counter-based function of absolute model time.

The per-cycle *deliver* hot path is backend-selectable
(``EngineConfig.delivery_backend``) and shared with the distributed engine --
see :mod:`repro.core.delivery` for the four backends and their cost
trade-offs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import delivery as delivery_lib
from repro.core import neuron as neuron_lib
from repro.core import ring_buffer

__all__ = ["EngineConfig", "SimState", "Engine", "make_engine"]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    neuron_model: str = "lif"  # 'lif' | 'ignore_and_fire'
    schedule: str = STRUCTURE_AWARE  # 'conventional' | 'structure_aware'
    seed: int = 42
    lif: neuron_lib.LIFParams = dataclasses.field(
        default_factory=neuron_lib.LIFParams
    )
    # The per-cycle deliver hot path: 'onehot' | 'scatter' | 'pallas' |
    # 'event' (see repro.core.delivery). The empty string derives the backend
    # from the legacy knobs below, which predate the unified dispatch and are
    # kept so existing configs/tests keep meaning the same thing.
    delivery_backend: str = ""
    # Legacy: one-hot-einsum (True) vs scatter-add (False) deposit.
    deposit_onehot: bool = True
    # Legacy: 'dense' (gather-matvec) vs 'event' (compact + scatter).
    delivery: str = "dense"
    # Use the fused Pallas LIF kernel (kernels.ops.lif_update) for the update
    # phase. None = enable exactly when delivery_backend is 'pallas' (the
    # all-kernel cycle); the flag exists so the fused update can be tested
    # against the jnp chain under every backend.
    fused_update: bool | None = None
    # Event-buffer headroom: s_max = headroom x expected spikes/cycle + slack
    # (cf. NEST's dynamic spike-register resizing; static here). The event
    # path's cost is s_max-bound, so the bound tracks the expected rate;
    # overruns are counted in SimState.overflow.
    s_max_headroom: float = 8.0
    s_max_floor: int = 16
    # Fuse the structure-aware window into one D-cycle superstep: blocked
    # ring read/clear (one [.., D] slice per window instead of D dynamic
    # slot updates), D unrolled cycles with window-static slot indices, and a
    # single-pass lumped inter delivery (delivery.deliver_inter_block) in
    # place of the window-end loop of D sequential deliver_inter calls.
    # None = enabled exactly for the structure-aware schedule (the
    # conventional schedule exchanges every cycle, so there is no window to
    # fuse); False forces the legacy per-cycle scan, kept as the semantic
    # reference for the equivalence/overflow suites.
    superstep: bool | None = None
    # Python-unroll the superstep's D cycles (fully static slot indices).
    # Default False: the cycle loop stays a lax.scan over the *live window
    # buffer* (cheap [.., W] column access instead of full-ring updates) --
    # unrolling the jnp graph multiplies the XLA op count ~Dx, which on the
    # CPU backend costs more in per-op dispatch than the static indices
    # save. The fused Pallas kernel (superstep_kernel) always unrolls
    # in-kernel, where the cycles fuse into one VMEM-resident program.
    superstep_unroll: bool = False
    # Run the window body as the fused Pallas superstep kernel
    # (kernels.cycle): membrane state and the live ring slots stay in VMEM
    # across the D unrolled cycles (update + intra delivery fused); the
    # lumped inter exchange still goes through the selected backend.
    # Single-host structure-aware engine only. NOTE on overflow semantics
    # with delivery_backend='event': the kernel's intra delivery is dense
    # (delay-resolved), so the intra packet bound s_max_area does not apply
    # -- intra spikes can neither drop nor count toward SimState.overflow;
    # only the inter packet bound remains. Identical trajectories to the
    # unfused event engine are therefore guaranteed only while the unfused
    # engine reports overflow == 0 (its own exactness condition anyway).
    superstep_kernel: bool = False

    def __post_init__(self) -> None:
        if self.neuron_model not in ("lif", "ignore_and_fire"):
            raise ValueError(f"unknown neuron model {self.neuron_model!r}")
        if self.schedule not in (CONVENTIONAL, STRUCTURE_AWARE):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.delivery not in ("dense", "event"):
            raise ValueError(f"unknown delivery {self.delivery!r}")
        if self.delivery_backend not in ("",) + delivery_lib.BACKENDS:
            raise ValueError(
                f"unknown delivery_backend {self.delivery_backend!r} "
                f"(expected one of {delivery_lib.BACKENDS})"
            )
        if self.superstep is True and self.schedule != STRUCTURE_AWARE:
            raise ValueError(
                "superstep=True requires the structure-aware schedule; "
                "the conventional schedule exchanges every cycle and has "
                "no window to fuse"
            )
        if self.superstep_kernel:
            if self.schedule != STRUCTURE_AWARE:
                raise ValueError(
                    "superstep_kernel fuses the structure-aware window; "
                    "the conventional schedule has no window to fuse"
                )
            if self.superstep is False:
                raise ValueError(
                    "superstep_kernel=True conflicts with superstep=False"
                )

    @property
    def backend(self) -> str:
        """The resolved delivery backend (legacy knobs folded in)."""
        if self.delivery_backend:
            return self.delivery_backend
        if self.delivery == "event":
            return "event"
        return "onehot" if self.deposit_onehot else "scatter"

    @property
    def fused(self) -> bool:
        """Whether the update phase runs the fused Pallas LIF kernel."""
        if self.fused_update is None:
            return self.backend == "pallas"
        return self.fused_update

    @property
    def use_superstep(self) -> bool:
        """Whether the window runs as one fused D-cycle superstep."""
        if self.schedule != STRUCTURE_AWARE:
            return False
        return True if self.superstep is None else self.superstep


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes
    # Scalar int32: spikes dropped because an event-path packet exceeded its
    # static s_max bound (0 unless delivery_backend == 'event'; any nonzero
    # value means the run is no longer exact and s_max_headroom/floor must be
    # raised).
    overflow: Any = None


class Engine(NamedTuple):
    init: Callable[[], SimState]
    # Advance one window of D cycles; returns (state', spikes[D, A, n_pad] bool).
    window: Callable[[SimState], tuple[SimState, jax.Array]]
    # Advance n_windows via scan; returns (state', total spikes per window [W]).
    run: Callable[[SimState, int], tuple[SimState, jax.Array]]
    config: EngineConfig
    delay_ratio: int
    # Distributed engines also expose the raw shard_map'd window
    # (state, net, gids) -> (state, block), used by the dry-run to lower with
    # ShapeDtypeStruct connectivity (production scale, no allocation).
    window_raw: Callable | None = None


def make_fused_lif_update(params: neuron_lib.LIFParams):
    """An ``(state, i_in, alive) -> (state', spikes)`` closure over the fused
    Pallas kernel, signature-compatible with :func:`repro.core.neuron.lif_update`."""
    from repro.kernels import ops as kops

    kw = dict(
        p11=params.p11, p21=params.p21, p22=params.p22,
        v_th=params.v_th_mv, v_reset=params.v_reset_mv,
        t_ref_steps=params.t_ref_steps,
    )

    def update(state, i_in, alive):
        v, i_syn, refrac, spikes = kops.lif_update(
            state.v, state.i_syn, state.refrac, i_in, alive, **kw)
        return neuron_lib.LIFState(v=v, i_syn=i_syn, refrac=refrac), spikes

    return update


def resolve_params(net: Network, spec: MultiAreaSpec, cfg: EngineConfig):
    """``(lif_params, drive_rate)`` as the engines actually run them.

    The dt-corrected LIF propagators and the per-neuron external drive rate
    (area rate relative to the 2.5 Hz reference scales ``spec.ext_rate_hz``,
    the Fig. 8b heterogeneity). Single source of truth shared by both
    engines and the phase profiler (``launch/simulate.py --profile``), so
    profiling always times the same math the engine executes.
    """
    lif_params = cfg.lif
    if abs(lif_params.dt_ms - net.dt_ms) > 1e-12:
        lif_params = dataclasses.replace(lif_params, dt_ms=net.dt_ms)
    drive_rate = net.rate_hz / 2.5 * spec.ext_rate_hz
    return lif_params, drive_rate


def make_fused_superstep(
    net: Network,
    spec: MultiAreaSpec,
    cfg: EngineConfig,
    lif_params: neuron_lib.LIFParams,
    drive_rate: jax.Array,
    gids: jax.Array,
):
    """A ``(neuron_state, fut, t0) -> (state', spikes[D, A, n] bool, fut')``
    closure over the fused Pallas superstep kernel (:mod:`repro.kernels.cycle`).

    The kernel advances all D cycles of a window with membrane state and the
    live window slots VMEM-resident, reproducing the unfused cycle body
    bit-for-bit (same LIF propagators, same counter-based drive, 1/256-grid
    intra deposits). With the event backend the kernel's *dense* intra
    delivery has no packet bound, so equality with the unfused event engine
    holds exactly while that engine reports zero overflow (see the
    ``EngineConfig.superstep_kernel`` note).
    """
    from repro.kernels import ops as kops

    D = net.delay_ratio
    steps_lo = net.steps_lo_intra
    r_span = net.r_span_intra if net.k_intra > 0 else 0

    if cfg.neuron_model == "lif":
        p = lif_params
        drive_p = drive_rate * (net.dt_ms * 1e-3)
        kw = dict(
            p11=p.p11, p21=p.p21, p22=p.p22, v_th=p.v_th_mv,
            v_reset=p.v_reset_mv, t_ref_steps=p.t_ref_steps,
            seed=cfg.seed, w_ext=spec.w_ext,
        )

        def run_lif(neuron_state, fut, t0):
            v, i_syn, refrac, fut, spk = kops.superstep_lif(
                neuron_state.v, neuron_state.i_syn, neuron_state.refrac,
                fut, drive_p, gids, net.alive, net.src_intra, net.w_intra,
                net.delay_intra, t0,
                d_win=D, steps_lo=steps_lo, r_span=r_span, **kw)
            state = neuron_lib.LIFState(v=v, i_syn=i_syn, refrac=refrac)
            return state, jnp.moveaxis(spk, 0, 1) != 0, fut

        return run_lif

    # ignore_and_fire: the same static interval/phase rule as the jnp update.
    interval = neuron_lib.iaf_interval(net.rate_hz, net.dt_ms)

    def run_iaf(neuron_state, fut, t0):
        del t0  # emission is input- and time-base-independent
        cd, fut, spk = kops.superstep_iaf(
            neuron_state.countdown, fut, interval, net.alive,
            net.src_intra, net.w_intra, net.delay_intra,
            d_win=D, steps_lo=steps_lo, r_span=r_span)
        return neuron_lib.IafState(countdown=cd), jnp.moveaxis(spk, 0, 1) != 0, fut

    return run_iaf


def make_engine(
    net: Network,
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
) -> Engine:
    """Build a jitted reference engine for ``net``.

    The returned callables close over the (host-resident) connectivity; the
    distributed engine in ``dist_engine.py`` shards the same computation.
    """
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    cfg = config
    backend = cfg.backend
    if backend == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    lif_params, drive_rate = resolve_params(net, spec, cfg)
    fused_lif = make_fused_lif_update(lif_params) if cfg.fused else None
    gids = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    def _update(neuron_state, i_in, t):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, drive_rate, net.dt_ms, spec.w_ext
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, net.alive)
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params
            )
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, net.dt_ms
        )

    s_max_area, s_max_all = delivery_lib.event_bounds(
        net, headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)

    def _deliver_intra(ring, spikes_f32, t):
        return delivery_lib.deliver_intra(
            ring, spikes_f32, net, t, backend=backend, s_max=s_max_area)

    def _deliver_inter(ring, spikes_f32, t):
        return delivery_lib.deliver_inter(
            ring, spikes_f32.reshape(-1), net, t,
            backend=backend, s_max=s_max_all)

    def _overflow(spikes, deliver_inter_now: bool):
        """Spikes dropped by the event path's static packet bounds."""
        if backend != "event":
            return jnp.int32(0)
        per_area = spikes.sum(axis=-1, dtype=jnp.int32)   # [A]
        over = jnp.int32(0)
        if net.k_intra > 0:
            over = jnp.maximum(per_area - s_max_area, 0).sum()
        if deliver_inter_now and net.k_inter > 0:
            over = over + jnp.maximum(per_area.sum() - s_max_all, 0)
        return over

    def _cycle(state: SimState, deliver_inter_now: bool):
        """deliver -> update -> collocate for one dt step."""
        i_in, ring = ring_buffer.read_and_clear(state.ring, state.t)
        neuron_state, spikes = _update(state.neuron, i_in, state.t)
        sf = spikes.astype(jnp.float32)
        ring = _deliver_intra(ring, sf, state.t)
        if deliver_inter_now:
            ring = _deliver_inter(ring, sf, state.t)
        new_state = SimState(
            neuron=neuron_state,
            ring=ring,
            t=state.t + 1,
            spike_count=state.spike_count + spikes.astype(jnp.int32),
            overflow=state.overflow + _overflow(spikes, deliver_inter_now),
        )
        return new_state, spikes

    # Live-window width of the fused superstep: relative slots [0, D) are the
    # window's own input columns, [D, W) the overhang that intra deposits can
    # reach past the window end; every within-window slot index is wrap-free
    # (see Network.live_window).
    W = net.live_window

    fused_window = (
        make_fused_superstep(net, spec, cfg, lif_params, drive_rate, gids)
        if cfg.superstep_kernel else None
    )

    def window_superstep(state: SimState) -> tuple[SimState, jax.Array]:
        """One fused D-cycle superstep (structure-aware schedule).

        Blocked ring access: windows are phase-aligned (t0 ≡ 0 mod D and
        ring_len ≡ 0 mod D), so the window's D input slots are one contiguous
        block -- read and cleared once, consumed at static indices.
        """
        t0 = state.t
        fut, ring = ring_buffer.open_window(state.ring, t0, D, W)
        neuron_state = state.neuron
        over = state.overflow
        if fused_window is not None:
            neuron_state, spikes_blk, fut = fused_window(
                neuron_state, fut, t0)
        elif cfg.superstep_unroll:
            cols = []
            for s in range(D):  # unrolled: s is static, slot math vanishes
                neuron_state, spikes = _update(
                    neuron_state, fut[..., s], t0 + s)
                fut = _deliver_intra(fut, spikes.astype(jnp.float32), s)
                over = over + _overflow(spikes, deliver_inter_now=False)
                cols.append(spikes)
            spikes_blk = jnp.stack(cols)
        else:
            # Scan over the live window: slot access touches only the small
            # [.., W] buffer (wrap-free by construction), never the ring.
            def body(carry, s):
                neuron_state, fut, over = carry
                neuron_state, spikes = _update(
                    neuron_state, fut[..., s], t0 + s)
                fut = _deliver_intra(fut, spikes.astype(jnp.float32), s)
                over = over + _overflow(spikes, deliver_inter_now=False)
                return (neuron_state, fut, over), spikes

            (neuron_state, fut, over), spikes_blk = jax.lax.scan(
                body, (neuron_state, fut, over),
                jnp.arange(D, dtype=jnp.int32))
        ring = ring_buffer.merge_window_tail(ring, fut[..., D:], t0 + D)

        # The lumped 'global communication', single pass: the whole [D, A, N]
        # block through deliver_inter_block. Every inter-area delay is >= D,
        # so slot (t0+s+d) is strictly in the future of the window -- causal
        # (paper §2.1) and bit-identical to D per-cycle deliveries.
        if net.k_inter > 0:
            block_flat = spikes_blk.reshape(D, -1).astype(jnp.float32)
            ring = delivery_lib.deliver_inter_block(
                ring, block_flat, net, t0, backend=backend, s_max=s_max_all)
            if backend == "event":
                counts = spikes_blk.reshape(D, -1).sum(
                    axis=-1, dtype=jnp.int32)
                over = over + jnp.maximum(counts - s_max_all, 0).sum()
        new_state = SimState(
            neuron=neuron_state,
            ring=ring,
            t=t0 + D,
            spike_count=state.spike_count + spikes_blk.astype(jnp.int32).sum(0),
            overflow=over,
        )
        return new_state, spikes_blk

    def window(state: SimState) -> tuple[SimState, jax.Array]:
        t0 = state.t
        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence inter delivery) every cycle.
            def body(st, _):
                return _cycle(st, deliver_inter_now=True)

            state, spikes = jax.lax.scan(body, state, None, length=D)
            return state, spikes

        if cfg.use_superstep:
            return window_superstep(state)

        # Legacy structure-aware window (the semantic reference for the
        # superstep): per-cycle scan + a fori_loop of D inter deliveries.
        def body(st, _):
            return _cycle(st, deliver_inter_now=False)

        state, spikes = jax.lax.scan(body, state, None, length=D)

        def deliver_s(s, carry):
            ring, over = carry
            sp = spikes[s]
            ring = _deliver_inter(ring, sp.astype(jnp.float32), t0 + s)
            if backend == "event" and net.k_inter > 0:
                over = over + jnp.maximum(
                    sp.sum(dtype=jnp.int32) - s_max_all, 0)
            return ring, over

        ring, over = jax.lax.fori_loop(
            0, D, deliver_s, (state.ring, state.overflow))
        return dataclasses.replace(state, ring=ring, overflow=over), spikes

    window_jit = jax.jit(window)

    def init() -> SimState:
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids
            )
        return SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, net.ring_len), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
            overflow=jnp.int32(0),
        )

    @functools.partial(jax.jit, static_argnums=1)
    def run(state: SimState, n_windows: int) -> tuple[SimState, jax.Array]:
        def body(st, _):
            st, spikes = window(st)
            return st, spikes.sum(dtype=jnp.int32)

        return jax.lax.scan(body, state, None, length=n_windows)

    return Engine(
        init=init, window=window_jit, run=run, config=cfg, delay_ratio=D
    )
