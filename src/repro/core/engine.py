"""Single-host reference engine: conventional vs structure-aware schedules.

This is the semantic reference for the distributed engine and the Pallas
kernels. It advances the network in *windows* of ``D`` cycles (``D`` = delay
ratio, paper eq. (1)); each cycle is the paper's deliver -> update -> collocate
sequence (Fig. 3):

* ``conventional``: inter-area spikes are delivered every cycle (this is what
  the per-cycle global ``MPI_Alltoall`` achieves in the reference code);
* ``structure_aware``: inter-area spikes are *accumulated* for the whole
  window and delivered in one lumped exchange at the window end. Causality is
  guaranteed because every inter-area delay is >= D steps.

Both schedules produce **bit-identical** spike trains: delivery weights live on
an exact 1/256 grid, so f32 ring accumulation is associative-exact, and the
external drive is a counter-based function of absolute model time.

The per-cycle *deliver* hot path is backend-selectable
(``EngineConfig.delivery_backend``) and shared with the distributed engine --
see :mod:`repro.core.delivery` for the four backends and their cost
trade-offs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.areas import MultiAreaSpec
from repro.core.connectivity import Network
from repro.core import delivery as delivery_lib
from repro.core import neuron as neuron_lib
from repro.core import ring_buffer

__all__ = ["EngineConfig", "SimState", "Engine", "make_engine"]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    neuron_model: str = "lif"  # 'lif' | 'ignore_and_fire'
    schedule: str = STRUCTURE_AWARE  # 'conventional' | 'structure_aware'
    seed: int = 42
    lif: neuron_lib.LIFParams = dataclasses.field(
        default_factory=neuron_lib.LIFParams
    )
    # The per-cycle deliver hot path: 'onehot' | 'scatter' | 'pallas' |
    # 'event' (see repro.core.delivery). The empty string derives the backend
    # from the legacy knobs below, which predate the unified dispatch and are
    # kept so existing configs/tests keep meaning the same thing.
    delivery_backend: str = ""
    # Legacy: one-hot-einsum (True) vs scatter-add (False) deposit.
    deposit_onehot: bool = True
    # Legacy: 'dense' (gather-matvec) vs 'event' (compact + scatter).
    delivery: str = "dense"
    # Use the fused Pallas LIF kernel (kernels.ops.lif_update) for the update
    # phase. None = enable exactly when delivery_backend is 'pallas' (the
    # all-kernel cycle); the flag exists so the fused update can be tested
    # against the jnp chain under every backend.
    fused_update: bool | None = None
    # Event-buffer headroom: s_max = headroom x expected spikes/cycle + slack
    # (cf. NEST's dynamic spike-register resizing; static here). The event
    # path's cost is s_max-bound, so the bound tracks the expected rate;
    # overruns are counted in SimState.overflow.
    s_max_headroom: float = 8.0
    s_max_floor: int = 16

    def __post_init__(self) -> None:
        if self.neuron_model not in ("lif", "ignore_and_fire"):
            raise ValueError(f"unknown neuron model {self.neuron_model!r}")
        if self.schedule not in (CONVENTIONAL, STRUCTURE_AWARE):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.delivery not in ("dense", "event"):
            raise ValueError(f"unknown delivery {self.delivery!r}")
        if self.delivery_backend not in ("",) + delivery_lib.BACKENDS:
            raise ValueError(
                f"unknown delivery_backend {self.delivery_backend!r} "
                f"(expected one of {delivery_lib.BACKENDS})"
            )

    @property
    def backend(self) -> str:
        """The resolved delivery backend (legacy knobs folded in)."""
        if self.delivery_backend:
            return self.delivery_backend
        if self.delivery == "event":
            return "event"
        return "onehot" if self.deposit_onehot else "scatter"

    @property
    def fused(self) -> bool:
        """Whether the update phase runs the fused Pallas LIF kernel."""
        if self.fused_update is None:
            return self.backend == "pallas"
        return self.fused_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes
    # Scalar int32: spikes dropped because an event-path packet exceeded its
    # static s_max bound (0 unless delivery_backend == 'event'; any nonzero
    # value means the run is no longer exact and s_max_headroom/floor must be
    # raised).
    overflow: Any = None


class Engine(NamedTuple):
    init: Callable[[], SimState]
    # Advance one window of D cycles; returns (state', spikes[D, A, n_pad] bool).
    window: Callable[[SimState], tuple[SimState, jax.Array]]
    # Advance n_windows via scan; returns (state', total spikes per window [W]).
    run: Callable[[SimState, int], tuple[SimState, jax.Array]]
    config: EngineConfig
    delay_ratio: int
    # Distributed engines also expose the raw shard_map'd window
    # (state, net, gids) -> (state, block), used by the dry-run to lower with
    # ShapeDtypeStruct connectivity (production scale, no allocation).
    window_raw: Callable | None = None


def make_fused_lif_update(params: neuron_lib.LIFParams):
    """An ``(state, i_in, alive) -> (state', spikes)`` closure over the fused
    Pallas kernel, signature-compatible with :func:`repro.core.neuron.lif_update`."""
    from repro.kernels import ops as kops

    kw = dict(
        p11=params.p11, p21=params.p21, p22=params.p22,
        v_th=params.v_th_mv, v_reset=params.v_reset_mv,
        t_ref_steps=params.t_ref_steps,
    )

    def update(state, i_in, alive):
        v, i_syn, refrac, spikes = kops.lif_update(
            state.v, state.i_syn, state.refrac, i_in, alive, **kw)
        return neuron_lib.LIFState(v=v, i_syn=i_syn, refrac=refrac), spikes

    return update


def make_engine(
    net: Network,
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
) -> Engine:
    """Build a jitted reference engine for ``net``.

    The returned callables close over the (host-resident) connectivity; the
    distributed engine in ``dist_engine.py`` shards the same computation.
    """
    D = net.delay_ratio
    A, n_pad = net.alive.shape
    cfg = config
    backend = cfg.backend
    if backend == "event" and net.tgt_intra is None:
        raise ValueError("event delivery needs build_network(outgoing=True)")
    lif_params = cfg.lif
    if abs(lif_params.dt_ms - net.dt_ms) > 1e-12:
        lif_params = dataclasses.replace(lif_params, dt_ms=net.dt_ms)
    fused_lif = make_fused_lif_update(lif_params) if cfg.fused else None

    # Per-neuron external drive rate for LIF: scaled by the area's target rate
    # relative to the 2.5 Hz reference, which induces the across-area activity
    # heterogeneity studied in Fig. 8b / §2.4.3.
    drive_rate = net.rate_hz / 2.5 * spec.ext_rate_hz
    gids = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)

    def _update(neuron_state, i_in, t):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, drive_rate, net.dt_ms, spec.w_ext
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, net.alive)
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params
            )
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, net.dt_ms
        )

    s_max_area, s_max_all = delivery_lib.event_bounds(
        net, headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)

    def _deliver_intra(ring, spikes_f32, t):
        return delivery_lib.deliver_intra(
            ring, spikes_f32, net, t, backend=backend, s_max=s_max_area)

    def _deliver_inter(ring, spikes_f32, t):
        return delivery_lib.deliver_inter(
            ring, spikes_f32.reshape(-1), net, t,
            backend=backend, s_max=s_max_all)

    def _overflow(spikes, deliver_inter_now: bool):
        """Spikes dropped by the event path's static packet bounds."""
        if backend != "event":
            return jnp.int32(0)
        per_area = spikes.sum(axis=-1, dtype=jnp.int32)   # [A]
        over = jnp.int32(0)
        if net.k_intra > 0:
            over = jnp.maximum(per_area - s_max_area, 0).sum()
        if deliver_inter_now and net.k_inter > 0:
            over = over + jnp.maximum(per_area.sum() - s_max_all, 0)
        return over

    def _cycle(state: SimState, deliver_inter_now: bool):
        """deliver -> update -> collocate for one dt step."""
        i_in, ring = ring_buffer.read_and_clear(state.ring, state.t)
        neuron_state, spikes = _update(state.neuron, i_in, state.t)
        sf = spikes.astype(jnp.float32)
        ring = _deliver_intra(ring, sf, state.t)
        if deliver_inter_now:
            ring = _deliver_inter(ring, sf, state.t)
        new_state = SimState(
            neuron=neuron_state,
            ring=ring,
            t=state.t + 1,
            spike_count=state.spike_count + spikes.astype(jnp.int32),
            overflow=state.overflow + _overflow(spikes, deliver_inter_now),
        )
        return new_state, spikes

    def window(state: SimState) -> tuple[SimState, jax.Array]:
        t0 = state.t
        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence inter delivery) every cycle.
            def body(st, _):
                return _cycle(st, deliver_inter_now=True)

            state, spikes = jax.lax.scan(body, state, None, length=D)
            return state, spikes

        # Structure-aware: local-only cycles, lumped inter delivery at the end.
        def body(st, _):
            return _cycle(st, deliver_inter_now=False)

        state, spikes = jax.lax.scan(body, state, None, length=D)

        # The lumped 'global communication': deliver the whole [D, A, N] block.
        # Every inter-area delay is >= D, so slot (t0+s+d) is strictly in the
        # future of the last cycle read -- causality is preserved (paper §2.1).
        def deliver_s(s, carry):
            ring, over = carry
            sp = spikes[s]
            ring = _deliver_inter(ring, sp.astype(jnp.float32), t0 + s)
            if backend == "event" and net.k_inter > 0:
                over = over + jnp.maximum(
                    sp.sum(dtype=jnp.int32) - s_max_all, 0)
            return ring, over

        ring, over = jax.lax.fori_loop(
            0, D, deliver_s, (state.ring, state.overflow))
        return dataclasses.replace(state, ring=ring, overflow=over), spikes

    window_jit = jax.jit(window)

    def init() -> SimState:
        if cfg.neuron_model == "lif":
            nstate = neuron_lib.lif_init((A, n_pad))
        else:
            nstate = neuron_lib.ignore_and_fire_init(
                net.alive, net.rate_hz, net.dt_ms, gids
            )
        return SimState(
            neuron=nstate,
            ring=jnp.zeros((A, n_pad, net.ring_len), jnp.float32),
            t=jnp.int32(0),
            spike_count=jnp.zeros((A, n_pad), jnp.int32),
            overflow=jnp.int32(0),
        )

    @functools.partial(jax.jit, static_argnums=1)
    def run(state: SimState, n_windows: int) -> tuple[SimState, jax.Array]:
        def body(st, _):
            st, spikes = window(st)
            return st, spikes.sum(dtype=jnp.int32)

        return jax.lax.scan(body, state, None, length=n_windows)

    return Engine(
        init=init, window=window_jit, run=run, config=cfg, delay_ratio=D
    )
