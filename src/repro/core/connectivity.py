"""Network instantiation: fixed-indegree connectivity with tiered delays.

NEST stores connections in per-thread *connection/source/target tables* and the
structure-aware implementation duplicates them into short-range and long-range
variants (paper §4.1.2, Fig. 10). The TPU-native rethink keeps the same split
but replaces pointer-chasing tables with rectangular tensors:

* intra-area synapses of area ``a``:  ``src_intra[a, n, k]`` (index *within*
  the area), ``w_intra[a, n, k]``, ``delay_intra[a, n, k]`` (steps).
* inter-area synapses: ``src_inter[a, n, k]`` holds *global* source ids
  (``area * n_pad + index``), with delays ``>= D`` steps (the paper's
  ``d_min_inter`` cutoff).

Areas are padded to a common ``n_pad`` ('ghost neurons', §4.1.1); the
``alive`` mask freezes the padding. Weights are drawn on a 1/256 grid --
every sum of such weights below 2^23/256 is exactly representable in f32, so
ring-buffer accumulation is associative-exact and the two communication
schedules (and all four delivery backends) are bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.areas import MultiAreaSpec
from repro.core.partition import shard_pathway_rows

__all__ = [
    "Network",
    "build_network",
    "network_sds",
    "area_adjacency",
    "shard_inter_tables",
    "draw_pathway_rows",
    "ShardedBuildPlan",
    "sharded_build_plan",
    "cached_sharded_build_plan",
    "plan_cache_key",
    "build_shard_tables",
    "build_group_intra_tables",
    "build_lane_intra_tables",
    "construction_cost_model",
    "tile_network",
    "tile_gids",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Network:
    """Instantiated multi-area network (a pytree of arrays).

    Shapes: ``A`` areas, ``n_pad`` padded neurons per area, ``K_i``/``K_e``
    intra-/inter-area in-degrees.
    """

    # [A, n_pad] bool -- live-neuron mask (False = ghost/frozen neuron).
    alive: jax.Array
    # [A, n_pad] f32 -- per-neuron target rate (drive/emission), Hz.
    rate_hz: jax.Array
    # intra-area synapses ---------------------------------------------------
    # Delays are stored int8 whenever the spec's step cutoffs fit in [1, 127]
    # (the production MAM tops out at steps_inter_max=100) and widened to
    # int32 only at the gather/deposit sites -- a third off every synapse's
    # delay bytes. Tables fall back to int32 for exotic specs.
    src_intra: jax.Array    # [A, n_pad, K_i] int32, index within the same area
    w_intra: jax.Array      # [A, n_pad, K_i] f32
    delay_intra: jax.Array  # [A, n_pad, K_i] int8/int32, steps in [1, steps_intra_max]
    # inter-area synapses ---------------------------------------------------
    src_inter: jax.Array    # [A, n_pad, K_e] int32, global id = area * n_pad + idx
    w_inter: jax.Array      # [A, n_pad, K_e] f32
    delay_inter: jax.Array  # [A, n_pad, K_e] int8/int32, steps in [D, steps_inter_max]

    # Optional *outgoing* adjacency (event-driven delivery, see
    # kernels/ops.event_deliver): per source neuron, padded target lists.
    # Built by build_network(outgoing=True); None otherwise.
    tgt_intra: jax.Array | None = None   # [A, n_pad, K_out_i] target idx in area
    wout_intra: jax.Array | None = None
    dout_intra: jax.Array | None = None
    tgt_inter: jax.Array | None = None   # [A, n_pad, K_out_e] global target ids
    wout_inter: jax.Array | None = None
    dout_inter: jax.Array | None = None

    # *Sharded* inbound inter-area tables (the distributed event/routed
    # receive path, see :func:`shard_inter_tables`): the replicated
    # ``tgt_inter`` table re-cut into per-target-shard slices. Row layout
    # ``[S, A * n_pad, K_in]``: shard ``s`` of the leading axis holds, for
    # every *source* row (global id order -- so rows are naturally grouped
    # by source device group), only the outgoing synapses whose target
    # lives in shard ``s``. Targets stay global ids (the receive side's
    # ``tgt_map`` remaps them exactly as for the replicated table), padded
    # with -1 / weight 0. ``K_in`` ~= K_out / S, so each device holds
    # ~1/S of the replicated table bytes.
    #
    # With ``subgroup > 1`` the tables are additionally sliced over the
    # within-group neuron-window axis: ``[S, gsz, A * n_pad, K_in]`` where
    # lane ``l`` of group ``s`` keeps only the synapses landing in its own
    # ``n_pad / gsz`` window of each owned area -- each *device* (not just
    # each group) holds ~1/(S * gsz) of the inter edges, and ``K_in``
    # shrinks another ~gsz x.
    tgt_inter_in: jax.Array | None = None   # [S(, gsz), A*n_pad, K_in] int32
    wout_inter_in: jax.Array | None = None  # [S(, gsz), A*n_pad, K_in] f32
    dout_inter_in: jax.Array | None = None  # [S(, gsz), A*n_pad, K_in] int8/int32

    # static metadata (ints are fine as static fields of the dataclass pytree)
    n_pad: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_areas: int = dataclasses.field(metadata=dict(static=True), default=0)
    ring_len: int = dataclasses.field(metadata=dict(static=True), default=0)
    delay_ratio: int = dataclasses.field(metadata=dict(static=True), default=1)
    dt_ms: float = dataclasses.field(metadata=dict(static=True), default=0.1)
    # Per-pathway delay windows, computed at build time from the actual delay
    # draws: all intra delays live in [steps_lo_intra, steps_lo_intra +
    # r_span_intra) and likewise for inter. Delay-resolved delivery (the
    # Pallas backend, see core/delivery.py) iterates only over this window
    # instead of the full ring -- the short/long pathway split of §4.1.2 is
    # what keeps each window narrow. r_span == 0 means "no synapses".
    steps_lo_intra: int = dataclasses.field(metadata=dict(static=True), default=1)
    r_span_intra: int = dataclasses.field(metadata=dict(static=True), default=0)
    steps_lo_inter: int = dataclasses.field(metadata=dict(static=True), default=1)
    r_span_inter: int = dataclasses.field(metadata=dict(static=True), default=0)
    # How the ``*_inter_in`` tables slice their targets (see
    # :func:`shard_inter_tables`): '' (no sharded tables), 'group' (shard =
    # device area group, the structure-aware placement) or 'window' (shard =
    # within-area neuron window, the conventional round-robin placement).
    # Static so engine assembly can validate the tables match the mesh.
    inter_shard_mode: str = dataclasses.field(
        metadata=dict(static=True), default="")
    # The *realised* area->area adjacency as nested tuples (hashable, so it
    # can ride along as static metadata): ``area_adj[src][tgt]`` truthy iff
    # any neuron of target area ``tgt`` drew a source from area ``src``.
    # Set by the sharded (host-free) build path, where the dense incoming
    # ``src_inter`` tensors :func:`area_adjacency` would otherwise inspect
    # are zero-row stand-ins; ``None`` means "inspect the tensors/spec".
    area_adj: tuple | None = dataclasses.field(
        metadata=dict(static=True), default=None)

    @property
    def k_intra(self) -> int:
        return self.src_intra.shape[-1]

    @property
    def live_window(self) -> int:
        """Width W of the superstep's live window buffer (static).

        Relative slots [0, D) are the window's own input columns; intra
        deposits (delay <= steps_lo + r_span - 1) reach at most slot
        D - 1 + max_intra_delay, so W = D + max_intra_delay makes every
        within-window slot index wrap-free. Shared by both engines -- the
        single source of truth for the window-width formula.
        """
        if self.k_intra == 0:
            return self.delay_ratio
        return self.delay_ratio + self.steps_lo_intra + self.r_span_intra - 1

    @property
    def k_inter(self) -> int:
        return self.src_inter.shape[-1]

    @property
    def n_total_padded(self) -> int:
        return self.n_areas * self.n_pad

    def bytes_per_synapse(self) -> int:
        # src/tgt int32 + weight f32 + the delay table's own dtype: int8 (9
        # B/syn) whenever the spec's step cutoffs fit [1, 127] -- every
        # production config -- int32 (12 B/syn) otherwise. Delays widen to
        # int32 only at the gather sites.
        return 8 + np.dtype(self.delay_inter.dtype).itemsize

    def synapse_count(self) -> int:
        return int(
            self.alive.sum() * (self.k_intra + self.k_inter)
        )


def _outgoing_k_bound(k: int) -> int:
    """Deterministic upper estimate of ``build_network``'s outgoing row width.

    The real ``K_out`` is the maximum in-edge count over source neurons --
    data-dependent, concentrated around the in-degree ``k`` with Poisson
    fluctuations. The dry-run only needs a shape of the right order to lower
    and compile, so we take mean + ~6 sigma (+ slack for tiny ``k``).
    """
    import math

    if k <= 0:
        return 0
    return int(k + math.ceil(6.0 * math.sqrt(k)) + 8)


def _delay_dtype(hi_steps: int):
    """The narrowest delay-table dtype covering ``[1, hi_steps]``.

    int8 whenever the pathway's step cutoff fits in 127 (the production MAM
    tops out at ``steps_inter_max=100``); int32 otherwise. Every consumer
    widens to int32 at its gather/deposit site, so the choice is pure
    storage layout -- trajectories are bitwise identical either way.
    """
    return np.int8 if hi_steps <= 127 else np.int32


def _inbound_k_bound(k: int, n_shards: int) -> int:
    """Deterministic upper estimate of one shard's inbound row width.

    A source's outgoing inter-area synapses spread ~uniformly over the
    target shards, so the per-(source row, shard) count concentrates around
    ``k / n_shards`` with Poisson fluctuations -- but the max is now taken
    over ``n_shards`` x more cells than :func:`_outgoing_k_bound` covers,
    so the slack is a little wider (+6 sigma + 16). The dry-run lowers with
    this bound; instantiated widths are data-dependent and smaller.
    """
    import math

    if k <= 0 or n_shards <= 0:
        return 0
    k_s = -(-k // n_shards)  # ceil
    return int(k_s + math.ceil(6.0 * math.sqrt(k_s)) + 16)


def network_sds(
    spec: MultiAreaSpec,
    *,
    size_multiple: int = 1,
    outgoing: bool = False,
    inter_shards: int = 0,
    inter_shard_mode: str = "group",
    subgroup: int = 1,
) -> Network:
    """ShapeDtypeStruct stand-in for :func:`build_network` (no allocation).

    The production-scale MAM has ~25 billion synapses (~300 GB of
    connectivity tensors) -- far beyond this host. The dry-run only needs
    shapes/dtypes to lower and compile, so this constructs the Network pytree
    with ShapeDtypeStruct leaves, mirroring build_network -- including, with
    ``outgoing=True``, the inverted ``tgt_*/wout_*/dout_*`` tables the event
    backend (and the routed exchange's global pathway) scatter through, so
    ``launch/dryrun.py`` can lower those paths at production scale. The
    outgoing row width is the deterministic bound of
    :func:`_outgoing_k_bound` (the instantiated width is data-dependent).

    ``inter_shards > 0`` mirrors :func:`shard_inter_tables` instead: the
    stand-in carries the ``[S, A * n_pad, K_in]`` *inbound* inter tables
    (width bound :func:`_inbound_k_bound`) and no replicated inter tables,
    so the dry-run lowers -- and its memory analysis prices -- the sharded
    receive path at production scale. ``subgroup > 1`` additionally slices
    the inbound stand-in over the within-group neuron-window axis
    (``[S, subgroup, A * n_pad, K_in]``, width bound over ``S * subgroup``
    effective shards), matching ``shard_inter_tables(subgroup=)`` -- and
    the outgoing intra tables the same way (``[subgroup, A, n_pad,
    K_lane]``, matching :func:`slice_intra_tables`), since their
    lane-replication otherwise dominates the event path's per-device HBM.
    """
    import jax

    A = spec.n_areas
    n_pad = spec.padded_area_size(size_multiple)
    K_i, K_e = spec.k_intra, spec.k_inter
    dt_i = _delay_dtype(spec.steps_intra_max)
    dt_e = _delay_dtype(spec.steps_inter_max)
    s = jax.ShapeDtypeStruct
    out: dict = {}
    if outgoing:
        if subgroup > 1:
            # Subgroup-sliced outgoing intra tables
            # (:func:`slice_intra_tables`): [gsz, A, n_pad, K_lane], the
            # leading lane axis sharded over the subgroup so the local
            # pathway's tables stop being lane-replicated.
            k_li = _inbound_k_bound(K_i, subgroup)
            out.update(
                tgt_intra=s((subgroup, A, n_pad, k_li), jnp.int32),
                wout_intra=s((subgroup, A, n_pad, k_li), jnp.float32),
                dout_intra=s((subgroup, A, n_pad, k_li), dt_i),
            )
        else:
            k_oi = _outgoing_k_bound(K_i)
            out.update(
                tgt_intra=s((A, n_pad, k_oi), jnp.int32),
                wout_intra=s((A, n_pad, k_oi), jnp.float32),
                dout_intra=s((A, n_pad, k_oi), dt_i),
            )
        if K_e > 0 and inter_shards > 0:
            if subgroup > 1 and inter_shard_mode != "group":
                raise ValueError(
                    "subgroup slicing applies to the 'group' mode only "
                    "(the 'window' mode is already per-device)")
            k_ie = _inbound_k_bound(K_e, inter_shards * max(subgroup, 1))
            lead = ((inter_shards, subgroup) if subgroup > 1
                    else (inter_shards,))
            out.update(
                tgt_inter_in=s((*lead, A * n_pad, k_ie), jnp.int32),
                wout_inter_in=s((*lead, A * n_pad, k_ie), jnp.float32),
                dout_inter_in=s((*lead, A * n_pad, k_ie), dt_e),
                inter_shard_mode=inter_shard_mode,
            )
        elif K_e > 0:
            k_oe = _outgoing_k_bound(K_e)
            out.update(
                tgt_inter=s((A, n_pad, k_oe), jnp.int32),
                wout_inter=s((A, n_pad, k_oe), jnp.float32),
                dout_inter=s((A, n_pad, k_oe), dt_e),
            )
    return Network(
        alive=s((A, n_pad), jnp.bool_),
        rate_hz=s((A, n_pad), jnp.float32),
        src_intra=s((A, n_pad, K_i), jnp.int32),
        w_intra=s((A, n_pad, K_i), jnp.float32),
        delay_intra=s((A, n_pad, K_i), dt_i),
        src_inter=s((A, n_pad, K_e), jnp.int32),
        w_inter=s((A, n_pad, K_e), jnp.float32),
        delay_inter=s((A, n_pad, K_e), dt_e),
        n_pad=n_pad,
        n_areas=A,
        ring_len=spec.ring_len,
        delay_ratio=spec.delay_ratio,
        dt_ms=spec.dt_ms,
        # No delay draws to inspect: use the spec's tier cutoffs (a superset
        # of any instantiated window, so lowering covers the real kernel).
        steps_lo_intra=1,
        r_span_intra=spec.steps_intra_max if K_i > 0 else 0,
        steps_lo_inter=spec.steps_inter_min,
        r_span_inter=(spec.steps_inter_max - spec.steps_inter_min + 1)
        if K_e > 0 else 0,
        **out,
    )


def _quantize_weights(w: np.ndarray, grid: float = 1.0 / 256.0) -> np.ndarray:
    """Snap weights onto an exactly-representable grid (see module docstring)."""
    return np.round(w / grid) * grid


# ---------------------------------------------------------------------------
# Counter-based draws: every synapse attribute is a pure function of
# (seed, pathway tag, flat synapse index), where the flat index is
# ``global_target_row * K + k``. Any subset of target rows therefore
# regenerates *exactly* the values the full build would have drawn for them
# -- the init-sharding property the host-free construction path relies on
# (each shard draws only its own rows; no sequential RNG stream to replay).
# The mixer mirrors ``repro.core.neuron._splitmix32`` (the drive's
# counter-based RNG) in numpy.
# ---------------------------------------------------------------------------

# Per-draw-site domain tags: each (tag, index) pair is hashed independently,
# so e.g. a synapse's source pick and its weight magnitude are uncorrelated.
_TAG_SRC_INTRA = 1
_TAG_SRC_AREA = 2
_TAG_SRC_IDX = 3
_TAG_W_INTRA = 4
_TAG_W_INTER = 5
_TAG_D_INTRA_U1 = 6
_TAG_D_INTRA_U2 = 7
_TAG_D_INTER_U1 = 8
_TAG_D_INTER_U2 = 9


def _np_mix32(x: np.ndarray) -> np.ndarray:
    """numpy mirror of ``neuron._splitmix32`` (uint32 wraparound arithmetic)."""
    x = x.astype(np.uint32, copy=True)
    x += np.uint32(0x9E3779B9)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x21F0AAAD)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x735A2D97)
    return x ^ (x >> np.uint32(15))


def _counter_hash(seed: int, tag: int, idx: np.ndarray) -> np.ndarray:
    """uint32 hash of (seed, tag, flat synapse index).

    ``idx`` may exceed 2^32 (production: 4.2M rows x 4200 K), so it is
    folded in as two uint32 words through chained mixes.
    """
    idx = np.asarray(idx, dtype=np.uint64)
    lo = (idx & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (idx >> np.uint64(32)).astype(np.uint32)
    s0 = np.uint32((int(seed) + int(tag) * 0x85EBCA6B) & 0xFFFFFFFF)
    return _np_mix32(_np_mix32(_np_mix32(lo + s0) + hi))


def _counter_uniform(seed: int, tag: int, idx: np.ndarray) -> np.ndarray:
    """Uniform draw strictly inside (0, 1) (Box-Muller-safe: log never sees 0)."""
    h = _counter_hash(seed, tag, idx)
    return (h.astype(np.float64) + 0.5) * (2.0 ** -32)


def _flat_idx(rows: np.ndarray, k: int) -> np.ndarray:
    """[R, k] uint64 flat synapse indices ``row * k + j`` for global rows."""
    return (np.asarray(rows, dtype=np.uint64)[:, None] * np.uint64(k)
            + np.arange(k, dtype=np.uint64)[None, :])


def _counter_weights(
    spec: MultiAreaSpec,
    seed: int,
    tag: int,
    idx: np.ndarray,
    src_idx_within_area: np.ndarray,
    sizes_of_src: np.ndarray,
) -> np.ndarray:
    """80/20 excitatory/inhibitory by source index, on the 1/256 grid."""
    exc = src_idx_within_area < np.maximum(
        1, (spec.exc_fraction * sizes_of_src).astype(np.int64))
    u = _counter_uniform(seed, tag, idx)
    mag = _quantize_weights((0.5 + u) * spec.w_exc).astype(np.float32)
    return np.where(exc, mag, -spec.g * mag).astype(np.float32)


def _counter_delays(
    seed: int,
    tag_u1: int,
    tag_u2: int,
    idx: np.ndarray,
    mean_ms: float,
    std_ms: float,
    lo_steps: int,
    hi_steps: int,
    dt_ms: float,
) -> np.ndarray:
    """Gaussian delays on the dt grid with [lo, hi] cutoffs (paper §4.2),
    via Box-Muller over two independent counter-uniform draws."""
    u1 = _counter_uniform(seed, tag_u1, idx)
    u2 = _counter_uniform(seed, tag_u2, idx)
    z = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
    d = (mean_ms + std_ms * z) / dt_ms
    return np.clip(np.round(d), lo_steps, hi_steps).astype(_delay_dtype(hi_steps))


def _allowed_source_areas(spec: MultiAreaSpec):
    """Padded per-target-area source-area lists from the spec adjacency.

    ``(allowed[A, max_deg] int64, n_allowed[A] int64)`` -- row ``a`` lists
    the areas allowed to project into ``a`` (garbage past ``n_allowed[a]``).
    """
    adj = spec.adjacency_matrix()
    A = spec.n_areas
    n_allowed = adj.sum(axis=0).astype(np.int64)
    allowed = np.zeros((A, max(int(n_allowed.max(initial=0)), 1)), np.int64)
    for a in range(A):
        srcs = np.flatnonzero(adj[:, a])
        allowed[a, : len(srcs)] = srcs
    return allowed, n_allowed


def _intra_src_rows(spec, seed, rows, n_pad, sizes) -> np.ndarray:
    """[R, K_i] int32 within-area source indices for global target rows."""
    rows = np.asarray(rows, dtype=np.int64)
    idx = _flat_idx(rows, spec.k_intra)
    sz = sizes.astype(np.int64)[rows // n_pad][:, None]
    h = _counter_hash(seed, _TAG_SRC_INTRA, idx)
    return (h.astype(np.int64) % sz).astype(np.int32)


def _intra_delay_rows(spec, seed, rows) -> np.ndarray:
    idx = _flat_idx(np.asarray(rows, np.int64), spec.k_intra)
    return _counter_delays(
        seed, _TAG_D_INTRA_U1, _TAG_D_INTRA_U2, idx,
        spec.delay_intra_mean_ms, spec.delay_intra_std_ms,
        1, spec.steps_intra_max, spec.dt_ms)


def _intra_rows(spec, seed, rows, n_pad, sizes):
    """(src, w, delay) intra-area tables [R, K_i] for global target rows."""
    rows = np.asarray(rows, dtype=np.int64)
    R, K_i = len(rows), spec.k_intra
    if K_i == 0:
        return (np.zeros((R, 0), np.int32), np.zeros((R, 0), np.float32),
                np.zeros((R, 0), _delay_dtype(spec.steps_intra_max)))
    src = _intra_src_rows(spec, seed, rows, n_pad, sizes)
    sz = sizes.astype(np.int64)[rows // n_pad][:, None]
    w = _counter_weights(
        spec, seed, _TAG_W_INTRA, _flat_idx(rows, K_i),
        src.astype(np.int64), sz)
    return src, w, _intra_delay_rows(spec, seed, rows)


def _inter_src_rows(spec, seed, rows, n_pad, sizes, allowed, n_allowed):
    """[R, K_e] int32 global source ids (``area * n_pad + idx``)."""
    rows = np.asarray(rows, dtype=np.int64)
    a_of = rows // n_pad
    idx = _flat_idx(rows, spec.k_inter)
    pick = (_counter_hash(seed, _TAG_SRC_AREA, idx).astype(np.int64)
            % n_allowed[a_of][:, None])
    src_area = np.take_along_axis(allowed[a_of], pick, axis=1)
    src_idx = (_counter_hash(seed, _TAG_SRC_IDX, idx).astype(np.int64)
               % sizes.astype(np.int64)[src_area])
    return (src_area * n_pad + src_idx).astype(np.int32)


def _inter_delay_rows(spec, seed, rows) -> np.ndarray:
    idx = _flat_idx(np.asarray(rows, np.int64), spec.k_inter)
    return _counter_delays(
        seed, _TAG_D_INTER_U1, _TAG_D_INTER_U2, idx,
        spec.delay_inter_mean_ms, spec.delay_inter_std_ms,
        spec.steps_inter_min, spec.steps_inter_max, spec.dt_ms)


def _inter_rows(spec, seed, rows, n_pad, sizes, allowed=None, n_allowed=None):
    """(src, w, delay) inter-area tables [R, K_e] for global target rows."""
    rows = np.asarray(rows, dtype=np.int64)
    R, K_e = len(rows), spec.k_inter
    if K_e == 0:
        return (np.zeros((R, 0), np.int32), np.zeros((R, 0), np.float32),
                np.zeros((R, 0), _delay_dtype(spec.steps_inter_max)))
    if allowed is None:
        allowed, n_allowed = _allowed_source_areas(spec)
    src = _inter_src_rows(spec, seed, rows, n_pad, sizes, allowed, n_allowed)
    src_area = src.astype(np.int64) // n_pad
    src_idx = src.astype(np.int64) % n_pad
    w = _counter_weights(
        spec, seed, _TAG_W_INTER, _flat_idx(rows, K_e),
        src_idx, sizes.astype(np.int64)[src_area])
    return src, w, _inter_delay_rows(spec, seed, rows)


def draw_pathway_rows(
    spec: MultiAreaSpec,
    seed: int,
    rows: np.ndarray,
    *,
    pathway: str,
    size_multiple: int = 1,
):
    """Counter-based (src, w, delay) draws for the given *global* target rows.

    The row-subset identity that makes construction shardable: for any
    subset (in any order) of ``arange(A * n_pad)``, the returned ``[R, K]``
    tables equal the corresponding rows of :func:`build_network`'s global
    tensors, bitwise -- each synapse is a pure function of
    ``(seed, pathway, row, k)``, never of which other rows were drawn.
    ``pathway`` is ``'intra'`` (src = index within the target's area) or
    ``'inter'`` (src = global id ``area * n_pad + idx``).
    """
    n_pad = spec.padded_area_size(size_multiple)
    sizes = spec.area_sizes()
    rows = np.asarray(rows, dtype=np.int64)
    if pathway == "intra":
        return _intra_rows(spec, seed, rows, n_pad, sizes)
    if pathway == "inter":
        return _inter_rows(spec, seed, rows, n_pad, sizes)
    raise ValueError(f"unknown pathway {pathway!r} ('intra' | 'inter')")


def _invert_adjacency(
    src: np.ndarray,      # [N_tgt, K] source ids (within some id space)
    w: np.ndarray,        # [N_tgt, K]
    d: np.ndarray,        # [N_tgt, K]
    n_src: int,
    tgt_base: int = 0,
    tgt_ids: np.ndarray | None = None,   # [N_tgt] explicit target ids
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Incoming [N_tgt, K] tables -> outgoing padded [n_src, K_out_max].

    Rows are padded with target id ``-1`` / weight 0 (event_deliver masks
    weight-0 entries into the absorbing row). Target ids default to
    ``arange(N_tgt) + tgt_base``; ``tgt_ids`` overrides them for
    non-contiguous target selections (the per-shard inbound slices of
    :func:`shard_inter_tables`).
    """
    n_tgt, k = src.shape
    flat_src = src.reshape(-1)
    order = np.argsort(flat_src, kind="stable")
    sorted_src = flat_src[order]
    counts = np.bincount(sorted_src, minlength=n_src)
    k_out = int(counts.max()) if counts.size else 0
    tgt = np.full((n_src, k_out), -1, dtype=np.int32)
    wout = np.zeros((n_src, k_out), dtype=np.float32)
    # Preserve the incoming delay dtype (int8 narrow tables stay narrow).
    dout = np.ones((n_src, k_out), dtype=d.dtype)
    if tgt_ids is None:
        tgt_ids = np.arange(n_tgt, dtype=np.int64) + tgt_base
    tgt_ids = np.repeat(np.asarray(tgt_ids, dtype=np.int64), k)[order]
    w_flat = w.reshape(-1)[order]
    d_flat = d.reshape(-1)[order]
    # position within each source's run
    starts = np.zeros(n_src + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    pos = np.arange(len(sorted_src)) - starts[sorted_src]
    tgt[sorted_src, pos] = tgt_ids.astype(np.int32)
    wout[sorted_src, pos] = w_flat
    dout[sorted_src, pos] = d_flat
    return tgt, wout, dout


def build_network(
    spec: MultiAreaSpec,
    *,
    seed: int = 12,
    size_multiple: int = 1,
    outgoing: bool | str = False,
) -> Network:
    """Instantiate the connectivity tensors for ``spec``.

    Connectivity generation is deterministic in ``seed`` (the paper runs seeds
    {12, 654, 91856}); every synapse attribute is a *counter-based* pure
    function of ``(seed, pathway, global target row, k)`` (see
    :func:`draw_pathway_rows`), so this host build is definitionally
    bitwise-identical to generating any partition of the rows shard-locally
    (:func:`build_shard_tables` and friends) -- construction is a separate
    phase from state propagation, exactly as in the reference code.

    ``size_multiple`` rounds the padded per-area size up so that device
    sharding (e.g. 16-way model parallel) and VMEM tiling divide evenly.
    ``outgoing`` builds the inverted target tables: ``True`` for both
    pathways, ``'intra'`` for the intra tier only -- the cheap subset that
    suffices when the inter receive path uses the *inbound* slices of
    :func:`shard_inter_tables` (which never read the outgoing inter tables).
    """
    if outgoing not in (False, True, "intra"):
        raise ValueError(f"outgoing={outgoing!r} (expected bool or 'intra')")
    A = spec.n_areas
    n_pad = spec.padded_area_size(size_multiple)
    sizes = spec.area_sizes()  # [A]
    D = spec.delay_ratio

    alive = np.zeros((A, n_pad), dtype=bool)
    for a in range(A):
        alive[a, : sizes[a]] = True

    rate = np.zeros((A, n_pad), dtype=np.float32)
    for a, ar in enumerate(spec.areas):
        rate[a, : sizes[a]] = ar.rate_hz

    K_i, K_e = spec.k_intra, spec.k_inter
    rows = np.arange(A * n_pad, dtype=np.int64)

    # ---- intra-area: uniform sources within the same area; inter-area:
    # uniform source area over the allowed adjacency (all-to-all by
    # default), then uniform neuron within the source area. Weights 80/20
    # excitatory/inhibitory by source index on the 1/256 grid; delays on
    # the dt grid with tiered cutoffs (eq. (1) and §4.2). All draws are
    # the shared counter-based row functions.
    s_, w_, d_ = _intra_rows(spec, seed, rows, n_pad, sizes)
    src_intra = s_.reshape(A, n_pad, K_i)
    w_intra = w_.reshape(A, n_pad, K_i)
    delay_intra = d_.reshape(A, n_pad, K_i)
    s_, w_, d_ = _inter_rows(spec, seed, rows, n_pad, sizes)
    src_inter = s_.reshape(A, n_pad, K_e)
    w_inter = w_.reshape(A, n_pad, K_e)
    delay_inter = d_.reshape(A, n_pad, K_e)

    out: dict = {}
    if outgoing:
        # Invert the incoming tables per tier (paper's short/long split).
        ti, wi, di = [], [], []
        for a in range(A):
            t_, w_, d_ = _invert_adjacency(
                src_intra[a], w_intra[a], delay_intra[a], n_pad)
            ti.append(t_), wi.append(w_), di.append(d_)
        k_i = max(t.shape[1] for t in ti)

        def padk(x, k, fill):
            return np.pad(x, ((0, 0), (0, k - x.shape[1])),
                          constant_values=fill)

        out["tgt_intra"] = jnp.asarray(
            np.stack([padk(t, k_i, -1) for t in ti]))
        out["wout_intra"] = jnp.asarray(
            np.stack([padk(w, k_i, 0.0) for w in wi]))
        out["dout_intra"] = jnp.asarray(
            np.stack([padk(d, k_i, 1) for d in di]))
        if K_e > 0 and outgoing != "intra":
            # Global id space for both sources and targets.
            t_, w_, d_ = _invert_adjacency(
                src_inter.reshape(A * n_pad, K_e),
                w_inter.reshape(A * n_pad, K_e),
                delay_inter.reshape(A * n_pad, K_e),
                A * n_pad,
            )
            out["tgt_inter"] = jnp.asarray(t_.reshape(A, n_pad, -1))
            out["wout_inter"] = jnp.asarray(w_.reshape(A, n_pad, -1))
            out["dout_inter"] = jnp.asarray(d_.reshape(A, n_pad, -1))

    # Delay-window metadata for delay-resolved delivery: the tightest
    # [lo, lo + span) covering the actual draws of each pathway table.
    lo_i = int(delay_intra.min()) if delay_intra.size else 1
    span_i = int(delay_intra.max()) - lo_i + 1 if delay_intra.size else 0
    lo_e = int(delay_inter.min()) if delay_inter.size else D
    span_e = int(delay_inter.max()) - lo_e + 1 if delay_inter.size else 0

    return Network(
        alive=jnp.asarray(alive),
        rate_hz=jnp.asarray(rate),
        src_intra=jnp.asarray(src_intra),
        w_intra=jnp.asarray(w_intra),
        delay_intra=jnp.asarray(delay_intra),
        src_inter=jnp.asarray(src_inter),
        w_inter=jnp.asarray(w_inter),
        delay_inter=jnp.asarray(delay_inter),
        n_pad=n_pad,
        n_areas=A,
        ring_len=spec.ring_len,
        delay_ratio=D,
        dt_ms=spec.dt_ms,
        steps_lo_intra=lo_i,
        r_span_intra=span_i,
        steps_lo_inter=lo_e,
        r_span_inter=span_e,
        **out,
    )


def _inbound_target_rows(
    mode: str, shard: int, n_shards: int, n_areas: int, n_pad: int,
    subgroup: int = 1, lane: int = 0,
) -> np.ndarray:
    """Global row ids of the targets shard ``shard`` (lane ``lane``) owns.

    Thin alias of :func:`repro.core.partition.shard_pathway_rows`, where the
    shard -> pathway-row-range derivation now lives (the sharded build path
    needs it without importing connectivity).
    """
    return shard_pathway_rows(
        mode, shard, n_shards, n_areas, n_pad, subgroup=subgroup, lane=lane)


def shard_inter_tables(
    net: Network, n_shards: int, *, mode: str = "group", subgroup: int = 1
) -> Network:
    """Re-cut the replicated outgoing inter tables into per-shard inbound
    slices (the tentpole of the sharded receive path).

    The replicated ``tgt_inter/wout_inter/dout_inter`` tables make every
    device hold (and scan) *all* ``A * n_pad x K_out`` inter-area synapses
    -- the NEST every-rank-scans-all-spikes pattern the paper identifies as
    the scaling wall (~171 GiB/device at production MAM scale, see
    EXPERIMENTS.md). This builds the inbound-edge representation instead:
    ``tgt_inter_in[s]`` holds, for every source row, only the synapses
    whose target lives in shard ``s`` -- a ``[S, A * n_pad, K_in]`` stack
    whose leading axis the distributed engine shards over the device
    groups, so each device stores and scatters only the ~1/S of edges it
    actually owns. Because groups own consecutive areas, the row range
    ``[g * rows_loc, (g+1) * rows_loc)`` of a shard's table *is* the
    (source group ``g`` -> this shard) edge table -- arriving id packets
    index it directly, no extra indirection.

    Targets stay *global* ids (remapped by the receive side's ``tgt_map``
    exactly like the replicated path), weights stay on the 1/256 grid, and
    each synapse appears in exactly one shard -- so delivery is
    bit-identical to the replicated table by construction.

    Returns a new :class:`Network` carrying the sharded tables with any
    replicated inter tables dropped (``tgt_intra`` untouched -- its
    subgroup cut is the separate :func:`slice_intra_tables`). Built entirely from the
    *incoming* ``src_inter/w_inter/delay_inter`` tensors, so the replicated
    outgoing tables never need to exist: a production engine can go
    straight from ``build_network()`` to the ~1/S inbound slices without
    materialising the ~150 GiB replicated layout this refactor removes.
    With ``subgroup > 1`` ('group' mode only) the slices are cut once more
    over the within-group neuron-window axis into a
    ``[S, subgroup, A * n_pad, K_in]`` stack: lane ``l`` of group ``s``
    keeps only the synapses landing in its own ``n_pad / subgroup`` window
    of each owned area. The distributed engine shards BOTH leading axes
    (area groups x subgroup lanes), so each device holds ~1/(S * subgroup)
    of the inter edges and ``K_in`` shrinks another ~subgroup x. Delivery
    stays bitwise: every lane's receive ``tgt_map`` already masks targets
    outside its window to the absorbing row, so removing those synapses
    from its slice changes nothing it would have kept.

    Works on ShapeDtypeStruct stand-ins too (dry-run lowering), where the
    width is the deterministic bound of :func:`_inbound_k_bound`.
    """
    if subgroup > 1 and mode != "group":
        raise ValueError(
            "subgroup slicing applies to the 'group' mode only (the "
            "'window' mode is already per-device)")
    if net.k_inter == 0:
        return dataclasses.replace(net, inter_shard_mode=mode)
    A, n_pad = net.n_areas, net.n_pad
    if mode == "group" and A % n_shards != 0:
        raise ValueError(f"n_areas={A} not divisible by {n_shards} shards")
    if mode == "window" and n_pad % n_shards != 0:
        raise ValueError(f"n_pad={n_pad} not divisible by {n_shards} shards")
    if subgroup > 1 and n_pad % subgroup != 0:
        raise ValueError(
            f"n_pad={n_pad} not divisible by subgroup={subgroup}")
    n_rows = A * n_pad
    drop = dict(tgt_inter=None, wout_inter=None, dout_inter=None)
    lead = (n_shards, subgroup) if subgroup > 1 else (n_shards,)

    if not hasattr(net.src_inter, "__array__"):  # ShapeDtypeStruct stand-in
        k_in = _inbound_k_bound(net.k_inter, n_shards * max(subgroup, 1))
        s = jax.ShapeDtypeStruct
        return dataclasses.replace(
            net,
            tgt_inter_in=s((*lead, n_rows, k_in), jnp.int32),
            wout_inter_in=s((*lead, n_rows, k_in), jnp.float32),
            dout_inter_in=s((*lead, n_rows, k_in), net.delay_inter.dtype),
            inter_shard_mode=mode,
            **drop,
        )

    K_e = net.k_inter
    src = np.asarray(net.src_inter).reshape(n_rows, K_e)
    w = np.asarray(net.w_inter).reshape(n_rows, K_e)
    d = np.asarray(net.delay_inter).reshape(n_rows, K_e)
    ts, ws, ds = [], [], []
    for shard in range(n_shards):
        for lane in range(max(subgroup, 1)):
            rows = _inbound_target_rows(
                mode, shard, n_shards, A, n_pad, max(subgroup, 1), lane)
            t_, w_, d_ = _invert_adjacency(
                src[rows], w[rows], d[rows], n_rows, tgt_ids=rows)
            ts.append(t_), ws.append(w_), ds.append(d_)
    k_in = max(t.shape[1] for t in ts)

    def padk(x, fill):
        return np.pad(x, ((0, 0), (0, k_in - x.shape[1])),
                      constant_values=fill)

    def stack(parts, fill):
        out = np.stack([padk(p, fill) for p in parts])
        return jnp.asarray(out.reshape(*lead, n_rows, k_in))

    return dataclasses.replace(
        net,
        tgt_inter_in=stack(ts, -1),
        wout_inter_in=stack(ws, 0.0),
        dout_inter_in=stack(ds, 1),
        inter_shard_mode=mode,
        **drop,
    )


def slice_intra_tables(net: Network, subgroup: int) -> Network:
    """Slice the outgoing intra (local-pathway) tables over the subgroup
    (within-group neuron-window) axis.

    The structure-aware event path receives the *whole group's* fired ids
    each cycle (subgroup all-gather) and every lane scatters through the
    full ``[A, n_pad, K_out]`` outgoing intra tables, masking targets
    outside its own ``n_pad / subgroup`` window to the absorbing row
    (``to_local``). Those tables are therefore replicated over the
    subgroup axis -- at production MAM scale that replication, not the
    inter tables, dominates per-device HBM (~15 GiB of the event path's
    footprint). This cuts them the same way :func:`shard_inter_tables`
    cuts the inbound inter slices: lane ``l`` keeps, per source row, only
    the synapses whose within-area target lands in its own window, stacked
    into a ``[subgroup, A, n_pad, K_lane]`` table whose leading axis the
    distributed engine shards over the subgroup -- ``K_lane`` shrinks
    ~subgroup x and the replication is gone.

    Bitwise-safe by the same argument as the inter cut: the surviving
    entries of each row keep their original relative order (stable
    compaction), and the entries removed are exactly the ones the lane's
    ``tgt_map`` already masked out -- the ring-buffer deposits a lane
    actually makes are the same values in the same order.

    Works on ShapeDtypeStruct stand-ins too (dry-run lowering), where the
    width is the deterministic bound of :func:`_inbound_k_bound` (a
    source's intra targets spread ~uniformly over the lanes, like inter
    targets over shards).
    """
    if subgroup <= 1 or net.tgt_intra is None:
        return net
    if net.tgt_intra.ndim == 4:
        raise ValueError("outgoing intra tables are already subgroup-sliced")
    A, n_pad = net.n_areas, net.n_pad
    if n_pad % subgroup != 0:
        raise ValueError(
            f"n_pad={n_pad} not divisible by subgroup={subgroup}")

    if not hasattr(net.tgt_intra, "__array__"):  # ShapeDtypeStruct stand-in
        k_li = _inbound_k_bound(net.k_intra, subgroup)
        s = jax.ShapeDtypeStruct
        return dataclasses.replace(
            net,
            tgt_intra=s((subgroup, A, n_pad, k_li), jnp.int32),
            wout_intra=s((subgroup, A, n_pad, k_li), jnp.float32),
            dout_intra=s((subgroup, A, n_pad, k_li), net.dout_intra.dtype),
        )

    tgt = np.asarray(net.tgt_intra).reshape(A * n_pad, -1)
    w = np.asarray(net.wout_intra).reshape(A * n_pad, -1)
    d = np.asarray(net.dout_intra).reshape(A * n_pad, -1)
    K = tgt.shape[-1]
    n_loc = n_pad // subgroup
    cols = np.arange(K, dtype=np.int64)[None, :]
    lanes = []
    k_lane = 0
    for lane in range(subgroup):
        lo = lane * n_loc
        keep = (tgt >= lo) & (tgt < lo + n_loc)   # -1 padding never kept
        order = np.argsort(~keep, axis=1, kind="stable")
        cnt = keep.sum(axis=1)
        valid = cols < cnt[:, None]
        lanes.append((
            np.where(valid, np.take_along_axis(tgt, order, axis=1),
                     tgt.dtype.type(-1)),
            np.where(valid, np.take_along_axis(w, order, axis=1),
                     w.dtype.type(0)),
            np.where(valid, np.take_along_axis(d, order, axis=1),
                     d.dtype.type(1)),
        ))
        k_lane = max(k_lane, int(cnt.max(initial=0)))

    def stack(i):
        return jnp.asarray(
            np.stack([ln[i][:, :k_lane] for ln in lanes])
            .reshape(subgroup, A, n_pad, k_lane))

    return dataclasses.replace(
        net, tgt_intra=stack(0), wout_intra=stack(1), dout_intra=stack(2))


def area_adjacency(
    net: Network, spec: MultiAreaSpec | None = None
) -> np.ndarray:
    """The realised area->area adjacency: ``adj[src, tgt]`` iff any neuron of
    target area ``tgt`` (live or ghost -- ghosts receive deposits too, so the
    routed exchange must ship to them for bit-identical rings) draws a source
    from area ``src``.

    Computed from the instantiated ``src_inter`` tables when the network
    carries data; for a :func:`network_sds` stand-in (ShapeDtypeStruct
    leaves, nothing to inspect) it falls back to the *spec-level* adjacency
    (``MultiAreaSpec.area_adjacency``, all-to-all by default) -- a superset
    of any instantiation, which is the safe direction: routing over a
    superset ships some empty packets but never drops a synapse.
    """
    A = net.n_areas
    if net.k_inter == 0:
        return np.zeros((A, A), dtype=bool)
    if net.area_adj is not None:
        # Sharded (host-free) build: the realised adjacency was computed at
        # plan time and rides along as static metadata -- the dense incoming
        # tensors below are zero-row stand-ins with nothing to inspect.
        return np.asarray(net.area_adj, dtype=bool)
    if not hasattr(net.src_inter, "__array__"):  # ShapeDtypeStruct stand-in
        if spec is None:
            return ~np.eye(A, dtype=bool)
        return spec.adjacency_matrix()
    src_area = np.asarray(net.src_inter) // net.n_pad        # [A_tgt, n, K]
    adj = np.zeros((A, A), dtype=bool)
    for tgt in range(A):
        adj[np.unique(src_area[tgt]), tgt] = True
    return adj


# ---------------------------------------------------------------------------
# Host-free sharded construction.
#
# The counter-based draws above make every synapse a pure function of
# (seed, pathway, global target row, k) -- so a shard can regenerate exactly
# its own rows and invert them locally, bitwise-identical to slicing the
# host-built global network, without any process ever materialising the
# global src_inter/w_inter/delay_inter tensors. The only *global* facts a
# shard needs are the padded table widths (the stacked layouts pad every
# shard/lane to the max width over all of them) and the delay-window
# metadata -- both derivable from counts alone. sharded_build_plan computes
# them in one streaming pass whose peak RSS is a single row chunk, and the
# per-shard builders below consume the plan.
# ---------------------------------------------------------------------------

# Streaming chunk size for the planning pass, in synapses (rows x K): caps
# the pass's peak RSS at a few hundred MB regardless of model scale.
_PLAN_CHUNK_SYNAPSES = 4_000_000


@dataclasses.dataclass(frozen=True)
class ShardedBuildPlan:
    """Global layout facts for host-free per-shard table construction.

    Everything here is derived from *counts* of the counter-based draws
    (one streaming pass, no global tensor): the padded widths every
    shard/lane table must share, the realised delay windows, and the
    realised area adjacency. Hashable (nested tuples only), so it can ride
    into static Network metadata.
    """

    n_shards: int
    subgroup: int
    mode: str            # 'group' | 'window' (see shard_pathway_rows)
    size_multiple: int
    n_pad: int
    # Padded widths (max over all shards/lanes -- identical to what
    # shard_inter_tables / build_network(outgoing) / slice_intra_tables
    # would compute from the global tensors).
    k_in: int            # inbound inter slice width
    k_out_intra: int     # outgoing intra width (subgroup == 1 layout)
    k_lane_intra: int    # lane-cut outgoing intra width (subgroup > 1)
    # Realised delay windows (build_network metadata).
    steps_lo_intra: int
    r_span_intra: int
    steps_lo_inter: int
    r_span_inter: int
    # Realised area->area adjacency as nested tuples of 0/1.
    area_adj: tuple


def _plan_row_chunks(rows: np.ndarray, k: int):
    step = max(1, _PLAN_CHUNK_SYNAPSES // max(k, 1))
    for i in range(0, len(rows), step):
        yield rows[i: i + step]


def sharded_build_plan(
    spec: MultiAreaSpec,
    seed: int,
    n_shards: int,
    *,
    mode: str = "group",
    subgroup: int = 1,
    size_multiple: int = 1,
) -> ShardedBuildPlan:
    """Pass 1 of the host-free build: global widths/windows/adjacency.

    Streams over the counter-based draws in bounded chunks (peak RSS ~ one
    chunk, independent of model size) and records exactly the global facts
    the host path's ``max over shards`` padding and ``min/max over draws``
    metadata would produce -- so pass-2 shard tables padded to these widths
    are bitwise-identical to slicing the host-built network.
    """
    A = spec.n_areas
    n_pad = spec.padded_area_size(size_multiple)
    sizes = spec.area_sizes()
    K_i, K_e = spec.k_intra, spec.k_inter
    sub = max(subgroup, 1)
    if sub > 1 and mode != "group":
        raise ValueError(
            "subgroup slicing applies to the 'group' mode only (the "
            "'window' mode is already per-device)")
    if mode == "group" and A % n_shards != 0:
        raise ValueError(f"n_areas={A} not divisible by {n_shards} shards")
    if mode == "window" and n_pad % n_shards != 0:
        raise ValueError(f"n_pad={n_pad} not divisible by {n_shards} shards")
    if sub > 1 and n_pad % sub != 0:
        raise ValueError(f"n_pad={n_pad} not divisible by subgroup={sub}")
    if mode not in ("group", "window"):
        raise ValueError(f"unknown inter_shard_mode {mode!r}")

    # ---- intra pathway: per-area outgoing widths + delay window.
    k_out_intra = 0
    k_lane_intra = 0
    lo_i, hi_i = None, None
    n_loc = n_pad // sub
    if K_i > 0:
        counts = np.zeros(n_pad, dtype=np.int64)
        lane_counts = np.zeros(n_pad * sub, dtype=np.int64)
        for a in range(A):
            counts[:] = 0
            lane_counts[:] = 0
            area_rows = np.arange(a * n_pad, (a + 1) * n_pad, dtype=np.int64)
            for rows in _plan_row_chunks(area_rows, K_i):
                src = _intra_src_rows(spec, seed, rows, n_pad, sizes)
                d = _intra_delay_rows(spec, seed, rows)
                lo_c, hi_c = int(d.min()), int(d.max())
                lo_i = lo_c if lo_i is None else min(lo_i, lo_c)
                hi_i = hi_c if hi_i is None else max(hi_i, hi_c)
                counts += np.bincount(src.reshape(-1), minlength=n_pad)
                if sub > 1:
                    lane_of_tgt = (rows % n_pad) // n_loc        # [R]
                    key = (src.astype(np.int64) * sub
                           + lane_of_tgt[:, None])
                    lane_counts += np.bincount(
                        key.reshape(-1), minlength=n_pad * sub)
            k_out_intra = max(k_out_intra, int(counts.max(initial=0)))
            if sub > 1:
                k_lane_intra = max(
                    k_lane_intra, int(lane_counts.max(initial=0)))

    # ---- inter pathway: per-(shard, lane) inbound widths + window + adj.
    k_in = 0
    lo_e, hi_e = None, None
    adj = np.zeros((A, A), dtype=bool)
    if K_e > 0:
        allowed, n_allowed = _allowed_source_areas(spec)
        counts = np.zeros(A * n_pad, dtype=np.int64)
        for shard in range(n_shards):
            for lane in range(sub):
                counts[:] = 0
                own = shard_pathway_rows(
                    mode, shard, n_shards, A, n_pad, subgroup=sub, lane=lane)
                for rows in _plan_row_chunks(own, K_e):
                    src = _inter_src_rows(
                        spec, seed, rows, n_pad, sizes, allowed, n_allowed)
                    d = _inter_delay_rows(spec, seed, rows)
                    lo_c, hi_c = int(d.min()), int(d.max())
                    lo_e = lo_c if lo_e is None else min(lo_e, lo_c)
                    hi_e = hi_c if hi_e is None else max(hi_e, hi_c)
                    counts += np.bincount(
                        src.reshape(-1), minlength=A * n_pad)
                    # Realised adjacency: flat (src_area, tgt_area) pairs.
                    pairs = np.unique(
                        (src.astype(np.int64) // n_pad) * A
                        + (rows // n_pad)[:, None])
                    adj.reshape(-1)[pairs] = True
                k_in = max(k_in, int(counts.max(initial=0)))

    return ShardedBuildPlan(
        n_shards=n_shards,
        subgroup=sub,
        mode=mode,
        size_multiple=size_multiple,
        n_pad=n_pad,
        k_in=k_in,
        k_out_intra=k_out_intra,
        k_lane_intra=k_lane_intra,
        steps_lo_intra=lo_i if lo_i is not None else 1,
        r_span_intra=(hi_i - lo_i + 1) if lo_i is not None else 0,
        steps_lo_inter=lo_e if lo_e is not None else spec.delay_ratio,
        r_span_inter=(hi_e - lo_e + 1) if lo_e is not None else 0,
        area_adj=tuple(tuple(int(v) for v in row) for row in adj),
    )


# ---------------------------------------------------------------------------
# Plan de-duplication. The planning pass is deterministic in
# (spec, seed, shard layout) but costs a full streaming sweep over every
# synapse draw -- and in a multi-process run each process used to repeat it
# identically. The keyed cache below computes it ONCE (process 0, or
# whichever process first takes the key) and shares it: in-memory memo for
# repeat builds in one process, an atomic JSON file for the other processes
# (ShardedBuildPlan is counts-only -- ints and a 0/1 adjacency -- so JSON
# round-trips it exactly).
# ---------------------------------------------------------------------------

_PLAN_MEMO: "dict[str, ShardedBuildPlan]" = {}

# Seconds a non-computing process waits for the computing one's file.
_PLAN_CACHE_WAIT_S = 600.0


def plan_cache_key(
    spec: MultiAreaSpec,
    seed: int,
    n_shards: int,
    *,
    mode: str = "group",
    subgroup: int = 1,
    size_multiple: int = 1,
) -> str:
    """Content digest keying one planning pass (spec + draw + layout)."""
    import hashlib
    import json

    payload = json.dumps(
        {
            "spec": dataclasses.asdict(spec),
            "seed": int(seed),
            "n_shards": int(n_shards),
            "mode": mode,
            "subgroup": int(subgroup),
            "size_multiple": int(size_multiple),
        },
        sort_keys=True,
        default=repr,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:24]


def _plan_to_json(plan: ShardedBuildPlan) -> dict:
    return dataclasses.asdict(plan)


def _plan_from_json(d: dict) -> ShardedBuildPlan:
    d = dict(d)
    d["area_adj"] = tuple(tuple(int(v) for v in row) for row in d["area_adj"])
    return ShardedBuildPlan(**d)


def cached_sharded_build_plan(
    spec: MultiAreaSpec,
    seed: int,
    n_shards: int,
    *,
    mode: str = "group",
    subgroup: int = 1,
    size_multiple: int = 1,
    cache_dir: str | None = None,
    process_index: int | None = None,
    wait_s: float = _PLAN_CACHE_WAIT_S,
) -> ShardedBuildPlan:
    """:func:`sharded_build_plan`, computed once per key instead of per call.

    Resolution order: in-memory memo -> ``cache_dir`` JSON file -> compute.
    ``cache_dir`` defaults to ``$REPRO_PLAN_CACHE``; with it set in a
    multi-process run, process 0 computes and atomically publishes the
    plan while every other process polls for the file instead of repeating
    the sweep (``process_index`` defaults to :func:`jax.process_index`).
    Without a cache_dir every process computes its own -- correct, just
    duplicated -- so launchers should set one on shared storage.
    """
    import json
    import os
    import time

    key = plan_cache_key(
        spec, seed, n_shards, mode=mode, subgroup=subgroup,
        size_multiple=size_multiple)
    if key in _PLAN_MEMO:
        return _PLAN_MEMO[key]

    if cache_dir is None:
        cache_dir = os.environ.get("REPRO_PLAN_CACHE") or None
    path = (os.path.join(cache_dir, f"plan_{key}.json")
            if cache_dir else None)

    def _read() -> "ShardedBuildPlan | None":
        if path is None or not os.path.exists(path):
            return None
        with open(path) as f:
            return _plan_from_json(json.load(f))

    plan = _read()
    if plan is None:
        if process_index is None:
            process_index = jax.process_index()
        multi = jax.process_count() > 1
        if path is not None and multi and process_index != 0:
            # Another process owns the compute; wait for its publish.
            deadline = time.monotonic() + wait_s
            while plan is None and time.monotonic() < deadline:
                time.sleep(0.2)
                plan = _read()
            if plan is None:
                raise TimeoutError(
                    f"process {process_index} waited {wait_s:.0f}s for "
                    f"{path} (is process 0 running with the same "
                    "REPRO_PLAN_CACHE?)")
        else:
            plan = sharded_build_plan(
                spec, seed, n_shards, mode=mode, subgroup=subgroup,
                size_multiple=size_multiple)
            if path is not None:
                # Atomic publish: readers only ever see a complete file.
                os.makedirs(cache_dir, exist_ok=True)
                tmp = f"{path}.tmp.{os.getpid()}"
                with open(tmp, "w") as f:
                    json.dump(_plan_to_json(plan), f)
                os.replace(tmp, path)

    _PLAN_MEMO[key] = plan
    return plan


def _padk_to(x: np.ndarray, k: int, fill) -> np.ndarray:
    if x.shape[1] > k:
        raise AssertionError(
            f"shard table width {x.shape[1]} exceeds plan width {k}")
    return np.pad(x, ((0, 0), (0, k - x.shape[1])), constant_values=fill)


def build_shard_tables(
    spec: MultiAreaSpec,
    seed: int,
    shard: int,
    *,
    plan: ShardedBuildPlan,
    lane: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pass 2, inter pathway: one shard's (lane's) inbound inter slice.

    Returns ``(tgt, wout, dout)`` of shape ``[A * n_pad, plan.k_in]`` --
    bitwise-identical to ``shard_inter_tables(...)``'s slice ``[shard]``
    (or ``[shard, lane]`` under subgroup slicing) of the host-built
    network, but generated from the shard's own rows only: peak RSS is the
    shard's ~1/(S * subgroup) of the inter synapses, not the global table.
    """
    A = spec.n_areas
    n_pad, K_e = plan.n_pad, spec.k_inter
    n_rows = A * n_pad
    if K_e == 0:
        return (np.full((n_rows, 0), -1, np.int32),
                np.zeros((n_rows, 0), np.float32),
                np.ones((n_rows, 0), _delay_dtype(spec.steps_inter_max)))
    rows = shard_pathway_rows(
        plan.mode, shard, plan.n_shards, A, n_pad,
        subgroup=plan.subgroup, lane=lane)
    src, w, d = _inter_rows(spec, seed, rows, n_pad, spec.area_sizes())
    t_, w_, d_ = _invert_adjacency(src, w, d, n_rows, tgt_ids=rows)
    return (_padk_to(t_, plan.k_in, -1),
            _padk_to(w_, plan.k_in, 0.0),
            _padk_to(d_, plan.k_in, 1))


def build_group_intra_tables(
    spec: MultiAreaSpec,
    seed: int,
    areas: np.ndarray,
    *,
    plan: ShardedBuildPlan,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pass 2, intra pathway (subgroup == 1 layout): outgoing intra tables
    for the given areas, ``[len(areas), n_pad, plan.k_out_intra]`` --
    bitwise-identical to ``build_network(outgoing=...)``'s ``tgt_intra``
    rows for those areas."""
    n_pad, sizes = plan.n_pad, spec.area_sizes()
    ts, ws, ds = [], [], []
    for a in np.asarray(areas, dtype=np.int64):
        rows = np.arange(a * n_pad, (a + 1) * n_pad, dtype=np.int64)
        src, w, d = _intra_rows(spec, seed, rows, n_pad, sizes)
        t_, w_, d_ = _invert_adjacency(src, w, d, n_pad)
        ts.append(_padk_to(t_, plan.k_out_intra, -1))
        ws.append(_padk_to(w_, plan.k_out_intra, 0.0))
        ds.append(_padk_to(d_, plan.k_out_intra, 1))
    return np.stack(ts), np.stack(ws), np.stack(ds)


def build_lane_intra_tables(
    spec: MultiAreaSpec,
    seed: int,
    areas: np.ndarray,
    lane: int,
    *,
    plan: ShardedBuildPlan,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pass 2, intra pathway (subgroup > 1 layout): lane ``lane``'s cut of
    the outgoing intra tables for the given areas,
    ``[len(areas), n_pad, plan.k_lane_intra]`` -- bitwise-identical to
    ``slice_intra_tables(...)``'s ``[lane, areas]`` rows of the host-built
    network.

    The compaction is padded-width-invariant (a ``-1`` pad target is never
    inside a lane's window, and the stable compaction preserves the kept
    entries' relative order), so compacting each area's *own* inversion
    (its natural width) equals compacting the globally-padded table.
    """
    n_pad, sizes = plan.n_pad, spec.area_sizes()
    n_loc = n_pad // plan.subgroup
    lo = lane * n_loc
    k_lane = plan.k_lane_intra
    ts, ws, ds = [], [], []
    for a in np.asarray(areas, dtype=np.int64):
        rows = np.arange(a * n_pad, (a + 1) * n_pad, dtype=np.int64)
        src, w, d = _intra_rows(spec, seed, rows, n_pad, sizes)
        t_, w_, d_ = _invert_adjacency(src, w, d, n_pad)
        keep = (t_ >= lo) & (t_ < lo + n_loc)        # -1 padding never kept
        order = np.argsort(~keep, axis=1, kind="stable")
        cnt = keep.sum(axis=1)
        cols = np.arange(t_.shape[1], dtype=np.int64)[None, :]
        valid = cols < cnt[:, None]
        ts.append(_padk_to(
            np.where(valid, np.take_along_axis(t_, order, axis=1),
                     t_.dtype.type(-1))[:, :k_lane], k_lane, -1))
        ws.append(_padk_to(
            np.where(valid, np.take_along_axis(w_, order, axis=1),
                     w_.dtype.type(0))[:, :k_lane], k_lane, 0.0))
        ds.append(_padk_to(
            np.where(valid, np.take_along_axis(d_, order, axis=1),
                     d_.dtype.type(1))[:, :k_lane], k_lane, 1))
    return np.stack(ts), np.stack(ws), np.stack(ds)


def construction_cost_model(
    spec: MultiAreaSpec,
    *,
    n_shards: int,
    subgroup: int = 1,
    size_multiple: int = 1,
) -> dict:
    """Modelled host peak RSS of network construction, host-build vs sharded.

    Deterministic byte arithmetic (no allocation), mirroring what each path
    actually materialises:

    * **host build** (``build_network(outgoing=True)`` +
      ``shard_inter_tables`` + ``slice_intra_tables``): the global incoming
      tensors of both pathways, the outgoing intra inversion, the
      accumulated per-shard inbound inter slices (all S x subgroup of them
      live on the host before stacking) plus the stack copy, and the lane
      intra cuts likewise.
    * **sharded build** (plan + per-shard builders): one (shard, lane)'s
      own draws and inversion temporaries, the global counts array of the
      planning pass, and that shard's single output slice.

    Width estimates use the same deterministic bounds as the dry-run's SDS
    stand-ins (:func:`_outgoing_k_bound` / :func:`_inbound_k_bound`).
    """
    A = spec.n_areas
    n_pad = spec.padded_area_size(size_multiple)
    K_i, K_e = spec.k_intra, spec.k_inter
    sub = max(subgroup, 1)
    n_rows = A * n_pad
    by_i = 8 + np.dtype(_delay_dtype(spec.steps_intra_max)).itemsize
    by_e = 8 + np.dtype(_delay_dtype(spec.steps_inter_max)).itemsize

    k_oi = _outgoing_k_bound(K_i)
    k_ie = _inbound_k_bound(K_e, n_shards * sub)
    k_li = _inbound_k_bound(K_i, sub) if sub > 1 else k_oi

    incoming = n_rows * (K_i * by_i + K_e * by_e)
    outgoing_intra = n_rows * k_oi * by_i
    inbound_slices = n_shards * sub * n_rows * k_ie * by_e
    lane_intra = sub * n_rows * k_li * by_i if sub > 1 else 0
    # Slices accumulate, then np.stack copies them once more (x2 transient).
    host_peak = incoming + outgoing_intra + 2 * inbound_slices + 2 * lane_intra

    rows_loc = n_rows // (n_shards * sub) if spec.k_inter else 0
    # One shard's draws (src int32 + w f32 + d) + inversion temporaries
    # (int64 flat order/sort/repeat ~ 3 x 8 B per synapse) + the planning
    # pass's global counts array + the single output slice.
    shard_draws = rows_loc * K_e * (by_e + 24)
    shard_intra = n_pad * K_i * (by_i + 24)
    shard_out = n_rows * k_ie * by_e + n_pad * max(k_li, k_oi) * by_i
    counts_arr = n_rows * 8
    shard_peak = max(shard_draws, shard_intra) + shard_out + counts_arr

    return dict(
        n_shards=n_shards,
        subgroup=sub,
        build_bytes_host_modelled=int(host_peak),
        build_bytes_shard_modelled=int(shard_peak),
        host_incoming_bytes=int(incoming),
        host_inbound_slice_bytes=int(inbound_slices),
        reduction=float(host_peak) / float(max(shard_peak, 1)),
    )


def tile_gids(n_areas: int, n_pad: int, copies: int) -> jax.Array:
    """The folded batch's gid table: the single-trial ids, tiled per copy.

    ``[copies * n_areas, n_pad]`` where every copy repeats
    ``arange(n_areas * n_pad)``. Fed to the engines' ``gids`` override so
    each block of a :func:`tile_network` super-network draws the
    single-trial counter noise stream bit-for-bit -- the per-*trial*
    distinction comes from the per-trial ``seed`` SimState leaf, not the
    gid table.
    """
    one = jnp.arange(n_areas * n_pad, dtype=jnp.int32).reshape(n_areas, n_pad)
    return jnp.tile(one, (copies, 1))


def tile_network(net: Network, copies: int) -> Network:
    """``copies`` disjoint replicas of ``net`` as one block-diagonal network.

    The serving layer's folded trial batching: the area axis is tiled
    ``B = copies`` times (``[A, n_pad, ...]`` -> ``[B * A, n_pad, ...]``)
    and every *global* neuron id is offset by ``b * A * n_pad`` in copy
    ``b``, so no synapse crosses a copy boundary. Within-area indices
    (``src_intra``, ``tgt_intra``) are copy-local already and tile
    unchanged. Each block then reproduces the single-trial trajectory
    bit-for-bit: delivery weights live on the 1/256 grid (accumulation is
    associative-exact) and the per-copy scatter order is the single-trial
    scatter order.

    Sentinel conventions, load-bearing for the id offsets:

    * outgoing ``tgt_inter`` pads with ``-1`` / weight 0 -- offsets apply
      only to non-negative entries (a shifted sentinel would become a
      *valid* id in another copy);
    * incoming ``src_inter`` has no sentinels (ghost rows carry valid
      draws nullified by the alive mask / zero weights) -- offsets apply
      unconditionally.

    Sharded inbound tables don't tile (their leading axis is a device
    placement, not a network axis): tile the host-built network first,
    then re-cut with :func:`shard_inter_tables` if needed.
    """
    if copies < 1:
        raise ValueError(f"copies must be >= 1, got {copies}")
    if copies == 1:
        return net
    if net.tgt_inter_in is not None:
        raise ValueError(
            "tile_network needs the unsharded network (sharded inbound "
            "inter tables slice a device layout, not a network axis); "
            "tile first, then shard_inter_tables")
    A, n_pad = net.alive.shape
    B = copies
    block = A * n_pad
    if B * block > jnp.iinfo(jnp.int32).max:
        raise ValueError(
            f"{B} copies x {block} padded neurons overflows the int32 "
            "global-id space")

    def rep(x):
        return jnp.tile(x, (B,) + (1,) * (x.ndim - 1))

    # Per-row copy offset, broadcast against [B * A, n_pad, K] tables.
    offs = jnp.repeat(
        jnp.arange(B, dtype=jnp.int32) * jnp.int32(block), A
    )[:, None, None]

    def rep_global(x, sentinel: bool):
        t = rep(x)
        if sentinel:
            return jnp.where(t < 0, t, t + offs)
        return t + offs

    arrays = dict(
        alive=rep(net.alive),
        rate_hz=rep(net.rate_hz),
        src_intra=rep(net.src_intra),
        w_intra=rep(net.w_intra),
        delay_intra=rep(net.delay_intra),
        src_inter=(
            rep_global(net.src_inter, sentinel=False)
            if net.src_inter.size else rep(net.src_inter)
        ),
        w_inter=rep(net.w_inter),
        delay_inter=rep(net.delay_inter),
    )
    if net.tgt_intra is not None:
        arrays.update(
            tgt_intra=rep(net.tgt_intra),
            wout_intra=rep(net.wout_intra),
            dout_intra=rep(net.dout_intra),
        )
    if net.tgt_inter is not None:
        arrays.update(
            tgt_inter=rep_global(net.tgt_inter, sentinel=True),
            wout_inter=rep(net.wout_inter),
            dout_inter=rep(net.dout_inter),
        )
    area_adj = None
    if net.area_adj is not None:
        base = np.asarray(net.area_adj, dtype=bool)
        big = np.zeros((B * A, B * A), dtype=bool)
        for b in range(B):
            big[b * A:(b + 1) * A, b * A:(b + 1) * A] = base
        area_adj = tuple(tuple(int(x) for x in row) for row in big)
    return dataclasses.replace(
        net, n_areas=B * A, area_adj=area_adj, **arrays)
