"""Delayed-current ring buffer.

Each neuron owns ``ring_len`` future-input slots. A spike emitted at step
``t`` through a synapse with delay ``d`` (in steps, ``1 <= d < ring_len``)
deposits its weight into slot ``(t + d) % ring_len``; at the start of step
``t`` the engine reads -- and clears -- slot ``t % ring_len``.

This is NEST's per-neuron ring buffer, vectorised: the whole network's buffers
form one dense array ``[..., n, ring_len]`` and delivery is a scatter-add.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "read_and_clear",
    "read_and_clear_block",
    "open_window",
    "merge_window_tail",
    "deposit",
    "deposit_scatter",
]


def read_and_clear(ring: jax.Array, t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (input slot for step t, ring with that slot zeroed).

    ``ring``: [..., R]; ``t``: scalar int32 step counter.
    """
    r = ring.shape[-1]
    slot = jnp.mod(t, r)
    i_in = jax.lax.dynamic_index_in_dim(ring, slot, axis=-1, keepdims=False)
    cleared = jax.lax.dynamic_update_index_in_dim(
        ring, jnp.zeros_like(i_in), slot, axis=-1
    )
    return i_in, cleared


def read_and_clear_block(
    ring: jax.Array, t0: jax.Array, d: int
) -> tuple[jax.Array, jax.Array]:
    """Blocked window read: return (slots [t0, t0+d) as ``[..., d]``, cleared ring).

    The fused D-cycle superstep replaces ``d`` per-cycle ``read_and_clear``
    calls (each a dynamic index + a full-ring dynamic update) with ONE
    contiguous ``[..., d]`` slice + ONE update per window. Requires the ring
    to be *phase-aligned*: ``ring.shape[-1] % d == 0`` (guaranteed by
    ``MultiAreaSpec.ring_len``) and ``t0 % d == 0`` (window starts), so the
    window's slots ``(t0 + s) % R`` for ``s in [0, d)`` are contiguous.
    """
    r = ring.shape[-1]
    if r % d != 0:
        raise ValueError(f"ring_len={r} must be a multiple of the block d={d}")
    start = jnp.mod(t0, r)  # a multiple of d by the phase-alignment contract
    blk = jax.lax.dynamic_slice_in_dim(ring, start, d, axis=-1)
    cleared = jax.lax.dynamic_update_slice_in_dim(
        ring, jnp.zeros_like(blk), start, axis=-1
    )
    return blk, cleared


def open_window(
    ring: jax.Array, t0: jax.Array, d: int, w: int
) -> tuple[jax.Array, jax.Array]:
    """Open a superstep window: blocked read/clear + zero-extended live buffer.

    Returns ``(fut [..., w], cleared ring)``: columns ``[0, d)`` of ``fut``
    are the window's input slots (from :func:`read_and_clear_block`),
    ``[d, w)`` start at zero and accumulate the window's own intra deposits
    that overhang the window end (merged back via
    :func:`merge_window_tail`). ``w`` is ``Network.live_window``.
    """
    blk, cleared = read_and_clear_block(ring, t0, d)
    if w > d:
        blk = jnp.concatenate(
            [blk, jnp.zeros(blk.shape[:-1] + (w - d,), blk.dtype)], axis=-1)
    return blk, cleared


def merge_window_tail(
    ring: jax.Array, tail: jax.Array, t: jax.Array
) -> jax.Array:
    """Add window-overhang slots back into the ring.

    ``tail[..., j]`` holds contributions destined for absolute step ``t + j``
    (the part of a superstep's live window buffer that reaches beyond the
    window end). The target slots are one circular range, so instead of a
    generic scatter (serial on the CPU backend; measured ~equal here but
    pathological on wide tails) the tail is zero-padded to the ring length,
    rotated into phase, and added -- one vectorised full-ring pass per
    *window*. A branch-per-phase ``lax.switch`` touching only the tail
    columns was measured 2.4x slower than this: XLA copies the carry into
    every branch. Exact because delivery weights live on the 1/256 grid.
    """
    r = ring.shape[-1]
    w = tail.shape[-1]
    if w == 0:
        return ring
    if w > r:
        raise ValueError(f"tail width {w} exceeds ring length {r}")
    pad = [(0, 0)] * (tail.ndim - 1) + [(0, r - w)]
    return ring + jnp.roll(jnp.pad(tail, pad), jnp.mod(t, r), axis=-1)


def deposit(
    ring: jax.Array,
    vals: jax.Array,
    delays: jax.Array,
    t: jax.Array,
) -> jax.Array:
    """Scatter-add synaptic contributions into future slots.

    Args:
      ring:   [N, R] per-neuron future-input slots.
      vals:   [N, K] contribution of each synapse (w * spike).
      delays: [N, K] integer delays in steps, ``1 <= d < R``.
      t:      scalar step at which the spikes were emitted.

    Returns the updated ring. Implemented as a one-hot matmul over the slot
    axis rather than ``.at[].add`` -- on TPU this lowers to a dense
    [K x R] contraction per neuron tile (MXU/VPU friendly) instead of a serial
    scatter; the Pallas kernel in ``repro.kernels.spike_deliver`` implements
    the tiled version of exactly this contraction.
    """
    r = ring.shape[-1]
    slots = jnp.mod(t + delays.astype(jnp.int32), r)  # [N, K]
    onehot = jax.nn.one_hot(slots, r, dtype=vals.dtype)  # [N, K, R]
    return ring + jnp.einsum("nk,nkr->nr", vals, onehot)


def deposit_scatter(
    ring: jax.Array,
    vals: jax.Array,
    delays: jax.Array,
    t: jax.Array,
) -> jax.Array:
    """Scatter-add variant of :func:`deposit` (same semantics).

    Avoids materialising the ``[N, K, R]`` one-hot. Because weights live on
    an exact 1/256 grid, scatter order does not affect the result
    bit-for-bit.

    Cost model (measured, see core/delivery.py module docstring): XLA lowers
    the scatter-add to a *serial* per-update ``while`` loop on the CPU
    backend (~50 ns/synapse), while the one-hot deposit does R x more
    multiply work but fully vectorised -- so one-hot wins when K is large
    relative to the serial/SIMD throughput gap and scatter wins at small K.
    The ring is flattened so the scatter uses a single fused index column
    (``row * R + slot``) instead of a [.., 2] coordinate table; measured
    ~1.3x faster than the 2-D index form on CPU.
    """
    r = ring.shape[-1]
    n, k = vals.shape
    slots = jnp.mod(t + delays.astype(jnp.int32), r)
    flat_idx = (jnp.arange(n, dtype=jnp.int32)[:, None] * r + slots).reshape(-1)
    flat = ring.reshape(-1).at[flat_idx].add(vals.reshape(-1))
    return flat.reshape(n, r)
