"""Delayed-current ring buffer.

Each neuron owns ``ring_len`` future-input slots. A spike emitted at step
``t`` through a synapse with delay ``d`` (in steps, ``1 <= d < ring_len``)
deposits its weight into slot ``(t + d) % ring_len``; at the start of step
``t`` the engine reads -- and clears -- slot ``t % ring_len``.

This is NEST's per-neuron ring buffer, vectorised: the whole network's buffers
form one dense array ``[..., n, ring_len]`` and delivery is a scatter-add.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["read_and_clear", "deposit", "deposit_scatter"]


def read_and_clear(ring: jax.Array, t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (input slot for step t, ring with that slot zeroed).

    ``ring``: [..., R]; ``t``: scalar int32 step counter.
    """
    r = ring.shape[-1]
    slot = jnp.mod(t, r)
    i_in = jax.lax.dynamic_index_in_dim(ring, slot, axis=-1, keepdims=False)
    cleared = jax.lax.dynamic_update_index_in_dim(
        ring, jnp.zeros_like(i_in), slot, axis=-1
    )
    return i_in, cleared


def deposit(
    ring: jax.Array,
    vals: jax.Array,
    delays: jax.Array,
    t: jax.Array,
) -> jax.Array:
    """Scatter-add synaptic contributions into future slots.

    Args:
      ring:   [N, R] per-neuron future-input slots.
      vals:   [N, K] contribution of each synapse (w * spike).
      delays: [N, K] integer delays in steps, ``1 <= d < R``.
      t:      scalar step at which the spikes were emitted.

    Returns the updated ring. Implemented as a one-hot matmul over the slot
    axis rather than ``.at[].add`` -- on TPU this lowers to a dense
    [K x R] contraction per neuron tile (MXU/VPU friendly) instead of a serial
    scatter; the Pallas kernel in ``repro.kernels.spike_deliver`` implements
    the tiled version of exactly this contraction.
    """
    r = ring.shape[-1]
    slots = jnp.mod(t + delays, r)  # [N, K]
    onehot = jax.nn.one_hot(slots, r, dtype=vals.dtype)  # [N, K, R]
    return ring + jnp.einsum("nk,nkr->nr", vals, onehot)


def deposit_scatter(
    ring: jax.Array,
    vals: jax.Array,
    delays: jax.Array,
    t: jax.Array,
) -> jax.Array:
    """Scatter-add variant of :func:`deposit` (same semantics).

    Avoids materialising the ``[N, K, R]`` one-hot -- preferred when ``K`` is
    large (production-scale delivery). Because weights live on an exact 1/256
    grid, scatter order does not affect the result bit-for-bit.
    """
    r = ring.shape[-1]
    n, k = vals.shape
    slots = jnp.mod(t + delays, r)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    return ring.at[rows, slots].add(vals)
