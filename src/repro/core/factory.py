"""The unified engine factory: one public constructor, two assemblies.

``make_simulation`` is the single non-deprecated way to build an engine.
It dispatches on mesh availability -- no mesh means the single-host
reference assembly (:mod:`repro.core.engine`), a mesh means the
``shard_map``'d distributed assembly (:mod:`repro.core.dist_engine`) --
and validates the config against the chosen target in one shot
(:meth:`EngineConfig.check`), so an invalid config reports *every*
broken rule with a remedy instead of one raise per constructor replay.

The legacy entry points ``make_engine`` / ``make_dist_engine`` remain as
thin :class:`DeprecationWarning` shims over the same assemblies; both
build bit-identical engines to this factory.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.core.areas import MultiAreaSpec
from repro.core import connectivity as connectivity_lib
from repro.core.connectivity import Network
from repro.core.engine import Engine, EngineConfig, _make_engine
from repro.core import dist_engine as dist_engine_lib

__all__ = ["make_simulation"]


def make_simulation(
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(),
    *,
    net: Network | None = None,
    mesh: Mesh | None = None,
    build_seed: int = 12,
    gids: jax.Array | None = None,
    trial_leaves: bool = False,
) -> Engine:
    """Build a simulation engine for ``spec``, dispatching on ``mesh``.

    * ``mesh=None``: the single-host reference engine. ``net=None`` builds
      the connectivity host-side (``build_network``, seeded by
      ``build_seed``, with outgoing tables exactly when the event backend
      needs them).
    * ``mesh=...``: the distributed engine on that mesh. ``net=None``
      requires ``config.sharded_build`` (host-free construction); a
      host-resident ``net`` is accepted as before (callers on real
      hardware should pass ``shard_network(net, mesh, schedule)``).

    ``gids`` overrides the global-id table fed to the counter-based drive
    and the iaf phase rule -- the serving layer's folded trial batches
    pass :func:`repro.core.connectivity.tile_gids` so every copy of a
    tiled super-network draws the single-trial noise stream bit-for-bit.
    ``trial_leaves`` (distributed only) sizes the shard_map state specs
    for the optional per-trial ``seed``/``stim`` drive leaves; the
    single-host engine takes them directly via ``engine.init(seed, stim)``.

    The config is validated against the dispatch target in one shot: a
    bad config raises :class:`repro.core.engine.ConfigError` carrying the
    complete violation list, each entry with a remedy.
    """
    cfg = config
    cfg.check(distributed=mesh is not None)
    if mesh is not None:
        return dist_engine_lib._make_dist_engine(
            net, spec, mesh, cfg,
            build_seed=build_seed, gids=gids, trial_leaves=trial_leaves)
    if trial_leaves:
        raise ValueError(
            "trial_leaves sizes the distributed engine's shard_map state "
            "specs; the single-host engine takes per-trial seed/stim "
            "directly via engine.init(seed=..., stim=...)")
    if net is None:
        net = connectivity_lib.build_network(
            spec, seed=build_seed, outgoing=cfg.backend == "event")
    return _make_engine(net, spec, cfg, gids=gids)
