"""Pluggable spike-exchange layer: how spikes travel between engine shards.

The shared window core (:mod:`repro.core.schedule`) is parameterized by an
``Exchange`` object with two hooks:

* ``cycle(ring, spikes, t, net, gids, inter_now=...)`` -- the per-cycle
  short-range (intra-area) pathway; under the conventional schedule the same
  hook also runs the per-cycle long-range exchange (``inter_now=True``).
* ``window_end(ring, block, t0, net, gids, blocked=...)`` -- the
  structure-aware schedule's lumped window-end long-range pathway.

Both return ``(ring', overflow_delta, shipped_bytes_delta)``; overflow is
the count of spikes a fixed-size packet dropped (0 on dense pathways, and
*provably* 0 under the adaptive two-phase exchange below); shipped bytes is
the mesh-total wire volume the hook actually moved (f32 scalar), accumulated
into ``SimState.shipped_bytes`` so runs report measured -- not just
worst-case -- bytes per window.

Three implementations:

* :class:`LocalExchange` -- single-host identity: no collectives, delivery
  goes straight through :mod:`repro.core.delivery`. The single-host engine
  (``repro.core.make_simulation`` without a mesh) is a thin assembly over
  the shared core with this exchange.
* :class:`DenseMeshExchange` -- the mesh collectives of the original
  distributed engine: bit-packed spike vectors (``comm.gather_*``) for the
  dense backends, compacted id packets over ``all_gather`` for the event
  backend. Every device receives every fired id, whether or not any of its
  neurons has a synapse from the sender -- but since the sharded-table
  refactor each device *scatters* an arriving id only through the inbound
  edges it owns (``connectivity.shard_inter_tables``; see
  ``_inter_tables`` and :func:`inter_table_report`), not the full
  replicated outgoing table.
* :class:`RoutedExchange` -- the connectivity-routed global pathway: at
  build time the area->area adjacency (:func:`repro.core.connectivity
  .area_adjacency`) is folded to the device-group graph, and the window-end
  exchange ships fixed-size id packets only along group->group edges that
  exist, via ``ppermute`` rotation rounds over the group graph instead of a
  mesh-wide ``all_gather`` (cf. Du et al., "A Low-latency Communication
  Design for Brain Simulations"). Rounds whose offset crosses no edge are
  skipped entirely; within a round the permutation contains only existing
  edges, and each packet is compacted *per destination group* under a
  per-edge ``s_max`` bound -- spills feed the same ``SimState.overflow``
  accounting as every other packet bound.

All exchanges are bit-identical: delivery weights live on the exact 1/256
grid, so neither packet order nor scatter order can change a ULP, and the
routed edge filter is exactly the set of edges with at least one synapse.

**Adaptive two-phase exchange** (``EngineConfig.adaptive_exchange``): every
fixed-size id packet above is statically sized from a rate expectation
(``delivery.event_bounds`` / per-edge ``RouteRound.s_max``), so quiet
windows waste wire bytes and loud windows silently drop spikes into
``SimState.overflow`` -- the failure mode NEST's spike register resizes
itself to avoid (Pronold et al. 2021). Adaptive mode replaces the static
bound with two phases:

1. **counts** -- a tiny int32 collective (``comm.count_max`` /
   ``comm.gather_counts``) tells every device the window's true maximum
   packet need *before* any payload ships;
2. **payload** -- the packet is sized by the smallest rung of a
   pre-compiled power-of-two bucket ladder (``delivery.bucket_ladder``,
   dispatched via ``ops.ladder_switch`` so jit never retraces on
   data-dependent shapes) that covers the counted need. The top rung is the
   hard population cap (every neuron in scope fires once per cycle), so no
   reachable count can exceed it: ``SimState.overflow`` is provably zero.

Trajectories are bit-identical to the static path whenever the static path
itself drops nothing (same compaction order, padding scatters +0.0).

Wire-byte accounting: every exchange reports ``wire_bytes(net)`` -- static
mesh-total bytes received per window, split by pathway -- feeding
``launch/simulate.py --profile``, ``benchmarks/bench_delivery.py`` and the
:mod:`repro.core.cost_model` communication term. :func:`wire_report`
computes the dense-vs-routed comparison for a hypothetical mesh shape
without constructing devices; each entry now carries **both** the static
worst case and the adaptive two-phase model (phase-1 count bytes +
expectation-sized payload, :func:`adaptive_wire_bytes`), and live runs
accumulate the *measured* bytes in ``SimState.shipped_bytes``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm
from repro.core import delivery as delivery_lib
from repro.core.connectivity import Network
from repro.core.schedule import CONVENTIONAL, STRUCTURE_AWARE
from repro.kernels import ops as kops

__all__ = [
    "EXCHANGES",
    "Exchange",
    "InflightWindow",
    "LocalExchange",
    "DenseMeshExchange",
    "RoutedExchange",
    "Routing",
    "build_routing",
    "adaptive_wire_bytes",
    "inter_table_report",
    "priced_inter_table_report",
    "wire_report",
]

EXCHANGES = ("local", "dense", "routed")

_I32_BYTES = 4
# Receive-table bytes per synapse entry at the production delay dtypes:
# tgt int32 + w f32 + delay int8 (matches Network.bytes_per_synapse for
# every spec whose step cutoffs fit int8; reports use the network's own
# accounting so exotic int32-delay specs stay honest).
_SYN_BYTES = 9


# ---------------------------------------------------------------------------
# Group routing tables (the connectivity-derived structure of RoutedExchange)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RouteRound:
    """One ppermute rotation round of the routed global pathway."""

    offset: int                           # destination group = (g + offset) % G
    pairs: tuple[tuple[int, int], ...]    # existing edges at this offset
    s_max: int                            # per-edge packet bound (ids/cycle)


@dataclasses.dataclass(frozen=True)
class Routing:
    """Per-destination-group routing tables over the area adjacency.

    ``proj[a, h]`` -- does source area ``a`` project into any area of device
    group ``h`` (groups own ``A / n_groups`` consecutive areas, row-major
    over the mesh's area axes, matching the engines' placement).
    ``group_adj[g, h]`` -- the folded group graph. ``rounds`` holds only the
    rotation offsets that cross at least one edge; a dense graph needs all
    ``G`` offsets, a sparse one skips most.
    """

    n_groups: int
    proj: np.ndarray        # [A, G] bool
    group_adj: np.ndarray   # [G, G] bool
    rounds: tuple[RouteRound, ...]

    @property
    def n_edges(self) -> int:
        return int(self.group_adj.sum())

    @property
    def n_wire_rounds(self) -> int:
        """Rounds that actually move bytes (offset 0 is group-local)."""
        return sum(1 for r in self.rounds if r.offset != 0)


def build_routing(
    adj: np.ndarray,
    n_groups: int,
    *,
    exp_area_spikes: float,
    headroom: float,
    floor: int,
    intra_tier: int | None = None,
) -> Routing:
    """Fold the [A, A] area adjacency onto ``n_groups`` device groups.

    ``exp_area_spikes`` is the expected spikes per area per cycle; the
    per-edge packet bound scales with the number of source areas actually
    projecting along the edge (``headroom x expectation + slack``, the same
    sizing rule as :func:`repro.core.delivery.event_bounds`), so sparse
    edges get small packets and absent edges get none.

    ``intra_tier`` is the number of consecutive groups sharing the fast
    interconnect tier (groups per pod on the (pod, data) group grid; group
    index is row-major, so one pod's groups are contiguous). When set, the
    rotation rounds are *hierarchically ordered*: the group-local offset 0
    first, then every offset whose existing edges all stay inside a tier,
    then the pod-crossing ones -- so on a multi-pod mesh most rounds
    complete on the fast tier before the first slow-tier crossing, instead
    of interleaving the two. Ordering only (each round ships the same
    packets either way; delivery is scatter-order-exact on the 1/256
    grid), so trajectories are bit-identical to the flat ring order.
    """
    adj = np.asarray(adj, dtype=bool)
    a = adj.shape[0]
    if a % n_groups != 0:
        raise ValueError(f"n_areas={a} not divisible by n_groups={n_groups}")
    a_loc = a // n_groups
    proj = adj.reshape(a, n_groups, a_loc).any(axis=2)          # [A, G]
    group_adj = proj.reshape(n_groups, a_loc, n_groups).any(axis=1)
    # Source areas contributing to each edge, for the per-edge bound.
    n_src = proj.reshape(n_groups, a_loc, n_groups).sum(axis=1)  # [G, G]
    slack = 4 * max(floor, 1)
    rounds = []
    for k in range(n_groups):
        pairs = tuple(
            (g, (g + k) % n_groups)
            for g in range(n_groups)
            if group_adj[g, (g + k) % n_groups]
        )
        if not pairs:
            continue
        s_max = max(
            int(headroom * exp_area_spikes * n_src[g, h]) + slack
            for g, h in pairs
        )
        rounds.append(RouteRound(offset=k, pairs=pairs, s_max=s_max))
    if intra_tier is not None and 0 < intra_tier < n_groups:
        def tier(rnd: RouteRound) -> int:
            if rnd.offset == 0:
                return 0   # group-local, no wire at all
            if all(g // intra_tier == h // intra_tier for g, h in rnd.pairs):
                return 1   # every edge stays on the fast tier
            return 2       # at least one pod-crossing edge
        rounds.sort(key=lambda r: (tier(r), r.offset))
    return Routing(
        n_groups=n_groups, proj=proj, group_adj=group_adj,
        rounds=tuple(rounds),
    )


# ---------------------------------------------------------------------------
# Exchange implementations
# ---------------------------------------------------------------------------


class InflightWindow(NamedTuple):
    """The two-window in-flight state of the overlapped exchange pipeline.

    ``wire`` is the *received* window-end payload of window ``w`` -- every
    collective has already run by the time an InflightWindow exists, so
    finishing it (the receive scatter into the ring) is collective-free and
    can happen at the top of window ``w+1``'s program, overlapping the
    payload transfer with ``w+1``'s compute on hardware with async
    collectives. ``t0`` is the window's start step (the scatter's time
    base). An *empty* inflight (``Exchange.init_inflight``) scatters
    nothing bitwise: id wires carry only the fill id (dropped by the
    receive maps), dense wires carry zeros (+0.0 adds are bit-exact on the
    1/256 grid, and rings never hold -0.0).
    """

    wire: jax.Array
    t0: jax.Array


class Exchange:
    """Interface + shared bookkeeping; see the module docstring.

    ``cycle`` and ``window_end`` return ``(ring', overflow_delta,
    shipped_bytes_delta)``: overflow counts spikes a fixed-size packet
    dropped (always 0 under the adaptive two-phase exchange), shipped bytes
    is the mesh-total wire volume the hook moved this call (f32 scalar; 0
    on the single-host identity), accumulated by the shared window core
    into ``SimState.shipped_bytes``.

    **Overlapped pipeline split** (``EngineConfig.overlap_exchange``):
    ``window_end`` = ``start_window_end`` then ``finish_window_end``. The
    causality of the structure-aware schedule pins where the cut can go:
    window ``w``'s deposits land at slots ``[t0 + D, ...)`` and the
    earliest of them is exactly the first slot window ``w+1`` reads -- so
    the receive *scatter* cannot be deferred past ``w+1``'s ring reads, but
    everything before it can be issued early. ``start`` therefore does the
    assembly and ALL collectives (the adaptive phase-1 counts -- final at
    the end of ``w``'s block -- plus the payload gathers/ppermutes) and all
    overflow/shipped accounting, returning an :class:`InflightWindow`;
    ``finish`` is the collective-free receive scatter, run at the top of
    the next window's program (or by ``Engine.drain`` at a pipeline
    boundary). Split == sequential bitwise: same packets, same scatter
    values, scatter order is exact on the 1/256 grid.
    """

    name = "abstract"
    adaptive = False

    def cycle(self, ring, spikes, t, net, gids, *, inter_now: bool):
        raise NotImplementedError

    def window_end(self, ring, block, t0, net, gids, *, blocked: bool):
        raise NotImplementedError

    def start_window_end(self, block, t0, net, gids, *, blocked: bool):
        """Assemble + ship window ``[t0, t0+D)``'s global pathway; returns
        ``(InflightWindow, overflow_delta, shipped_bytes_delta)``."""
        raise NotImplementedError

    def finish_window_end(self, ring, inflight, net, gids, *, blocked: bool):
        """Collective-free receive scatter of an in-flight window's payload
        into the ring; returns the updated ring."""
        raise NotImplementedError

    def init_inflight(self, net: Network) -> InflightWindow:
        """An empty (scatters-nothing) in-flight window, globally shaped
        (what a pipeline starts from and resets to after a drain)."""
        raise NotImplementedError

    def inflight_pspecs(self) -> InflightWindow:
        """PartitionSpecs of the in-flight state for ``shard_map`` threading
        (distributed exchanges only)."""
        raise NotImplementedError

    def wire_bytes(self, net: Network) -> dict:
        raise NotImplementedError


class LocalExchange(Exchange):
    """Single-host identity exchange: delivery without any wire.

    Reproduces the original ``make_engine`` semantics exactly, including the
    event backend's per-area / whole-network packet bounds and their
    overflow accounting.
    """

    name = "local"

    def __init__(self, net: Network, cfg):
        self.backend = cfg.backend
        self.adaptive = cfg.adaptive_exchange
        self.s_max_area, self.s_max_all = delivery_lib.event_bounds(
            net, headroom=cfg.s_max_headroom, floor=cfg.s_max_floor,
            burst_factor=cfg.s_max_burst)
        # Adaptive bucket ladders: no wire on a single host, but the event
        # path's packet bound still caps the scatter -- the ladder sizes it
        # to the cycle's true count instead, with the hard population cap
        # (every neuron fires) on top, so overflow is impossible.
        a, n_pad = net.alive.shape
        self.ladder_area = delivery_lib.bucket_ladder(cfg.s_max_floor, n_pad)
        self.ladder_all = delivery_lib.bucket_ladder(
            cfg.s_max_floor, a * n_pad)

    def _overflow(self, spikes, net, inter_now: bool):
        """Spikes dropped by the event path's static packet bounds."""
        if self.backend != "event" or self.adaptive:
            return jnp.int32(0)
        per_area = spikes.sum(axis=-1, dtype=jnp.int32)   # [A]
        over = jnp.int32(0)
        if net.k_intra > 0:
            over = jnp.maximum(per_area - self.s_max_area, 0).sum()
        if inter_now and net.k_inter > 0:
            over = over + jnp.maximum(per_area.sum() - self.s_max_all, 0)
        return over

    def cycle(self, ring, spikes, t, net, gids, *, inter_now: bool):
        del gids
        sf = spikes.astype(jnp.float32)
        if self.backend == "event" and self.adaptive:
            per_area = spikes.sum(axis=-1, dtype=jnp.int32)
            ring = kops.ladder_switch(
                self.ladder_area, per_area.max(),
                lambda b, r: delivery_lib.deliver_intra(
                    r, sf, net, t, backend=self.backend, s_max=b),
                ring)
            if inter_now:
                ring = kops.ladder_switch(
                    self.ladder_all, per_area.sum(),
                    lambda b, r: delivery_lib.deliver_inter(
                        r, sf.reshape(-1), net, t,
                        backend=self.backend, s_max=b),
                    ring)
            return ring, jnp.int32(0), jnp.float32(0)
        ring = delivery_lib.deliver_intra(
            ring, sf, net, t, backend=self.backend, s_max=self.s_max_area)
        if inter_now:
            ring = delivery_lib.deliver_inter(
                ring, sf.reshape(-1), net, t,
                backend=self.backend, s_max=self.s_max_all)
        return ring, self._overflow(spikes, net, inter_now), jnp.float32(0)

    def window_end(self, ring, block, t0, net, gids, *, blocked: bool):
        del gids
        zero = jnp.float32(0)
        if net.k_inter == 0:
            return ring, jnp.int32(0), zero
        d_win = block.shape[0]
        flat = block.reshape(d_win, -1).astype(jnp.float32)
        adaptive = self.backend == "event" and self.adaptive
        if blocked:
            if adaptive:
                counts = block.reshape(d_win, -1).sum(
                    axis=-1, dtype=jnp.int32)
                ring = kops.ladder_switch(
                    self.ladder_all, counts.max(),
                    lambda b, r: delivery_lib.deliver_inter_block(
                        r, flat, net, t0, backend=self.backend, s_max=b),
                    ring)
                return ring, jnp.int32(0), zero
            ring = delivery_lib.deliver_inter_block(
                ring, flat, net, t0, backend=self.backend,
                s_max=self.s_max_all)
            over = jnp.int32(0)
            if self.backend == "event":
                counts = block.reshape(d_win, -1).sum(
                    axis=-1, dtype=jnp.int32)
                over = jnp.maximum(counts - self.s_max_all, 0).sum()
            return ring, over, zero

        def window_loop(s_max, ring):
            def deliver_s(s, carry):
                ring, over = carry
                ring = delivery_lib.deliver_inter(
                    ring, flat[s], net, t0 + s,
                    backend=self.backend, s_max=s_max)
                if self.backend == "event" and not adaptive:
                    over = over + jnp.maximum(
                        block[s].sum(dtype=jnp.int32) - s_max, 0)
                return ring, over

            return jax.lax.fori_loop(
                0, d_win, deliver_s, (ring, jnp.int32(0)))

        if adaptive:
            counts = block.reshape(d_win, -1).sum(axis=-1, dtype=jnp.int32)
            ring, over = kops.ladder_switch(
                self.ladder_all, counts.max(), window_loop, ring)
        else:
            ring, over = window_loop(self.s_max_all, ring)
        return ring, over, zero

    # -- overlapped pipeline split ------------------------------------------

    def start_window_end(self, block, t0, net, gids, *, blocked: bool):
        del gids, blocked
        t0 = jnp.asarray(t0, jnp.int32)
        d_win = block.shape[0]
        flat = block.reshape(d_win, -1).astype(jnp.float32)
        if net.k_inter == 0:
            return (InflightWindow(wire=flat[:, :0], t0=t0),
                    jnp.int32(0), jnp.float32(0))
        over = jnp.int32(0)
        if self.backend == "event" and not self.adaptive:
            # Same per-cycle spill count the sequential hook accumulates
            # (blocked and legacy paths agree on it).
            counts = block.reshape(d_win, -1).sum(axis=-1, dtype=jnp.int32)
            over = jnp.maximum(counts - self.s_max_all, 0).sum()
        return InflightWindow(wire=flat, t0=t0), over, jnp.float32(0)

    def finish_window_end(self, ring, inflight, net, gids, *, blocked: bool):
        del gids
        if net.k_inter == 0 or inflight.wire.shape[-1] == 0:
            return ring
        flat, t0 = inflight.wire, inflight.t0
        d_win = flat.shape[0]
        adaptive = self.backend == "event" and self.adaptive
        counts = flat.sum(axis=-1).astype(jnp.int32)
        if blocked:
            if adaptive:
                return kops.ladder_switch(
                    self.ladder_all, counts.max(),
                    lambda b, r: delivery_lib.deliver_inter_block(
                        r, flat, net, t0, backend=self.backend, s_max=b),
                    ring)
            return delivery_lib.deliver_inter_block(
                ring, flat, net, t0, backend=self.backend,
                s_max=self.s_max_all)

        def window_loop(s_max, ring):
            def deliver_s(s, ring):
                return delivery_lib.deliver_inter(
                    ring, flat[s], net, t0 + s,
                    backend=self.backend, s_max=s_max)

            return jax.lax.fori_loop(0, d_win, deliver_s, ring)

        if adaptive:
            return kops.ladder_switch(
                self.ladder_all, counts.max(), window_loop, ring)
        return window_loop(self.s_max_all, ring)

    def init_inflight(self, net: Network) -> InflightWindow:
        d_win = max(net.delay_ratio, 1)
        a, n_pad = net.alive.shape
        width = a * n_pad if net.k_inter > 0 else 0
        return InflightWindow(
            wire=jnp.zeros((d_win, width), jnp.float32), t0=jnp.int32(0))

    def wire_bytes(self, net: Network) -> dict:
        return dict(exchange=self.name, local_bytes=0, global_bytes=0,
                    total_bytes=0, adaptive=self.adaptive)


class DenseMeshExchange(Exchange):
    """The mesh-wide collectives (the pre-routing distributed design).

    Structure-aware placement: the per-cycle local pathway completes each
    area over the intra-area subgroup (``model`` axis); the window-end global
    pathway all-gathers the lumped ``[D, ...]`` block (bit-packed vectors for
    the dense backends, compacted id packets for the event backend) over the
    *whole* mesh -- every device receives every fired id. Conventional
    placement: one mesh-wide exchange per cycle feeds both pathways.
    """

    name = "dense"

    def __init__(self, net: Network, cfg, mesh):
        self.backend = cfg.backend
        self.schedule = cfg.schedule
        self.mesh = mesh
        self.area_axes = tuple(mesh.axis_names[:-1])
        self.subgroup = mesh.axis_names[-1]
        self.all_axes = tuple(mesh.axis_names)
        self.n_dev = mesh.size
        self.gsz = mesh.shape[self.subgroup]
        self.n_groups = self.n_dev // self.gsz
        self.headroom = cfg.s_max_headroom
        self.floor = cfg.s_max_floor
        self.adaptive = cfg.adaptive_exchange
        # Static event-packet bounds: per-device shares of the single-host
        # bounds, floored so tiny shards keep headroom. _mesh_bounds is the
        # single source of truth, shared with the static wire accounting so
        # the byte counts always price the bounds the window bodies ship.
        if self.backend == "event":
            self.s_max_loc, self.s_max_dev = _mesh_bounds(
                net, n_groups=self.n_groups, gsz=self.gsz,
                headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
        else:
            self.s_max_loc = self.s_max_dev = 0
        # Adaptive bucket ladders: capped by the hard population bound of
        # each packet's scope (a neuron fires at most once per cycle), so
        # the top rung can never drop a spike. The per-(area, lane) local
        # packet holds at most this device's n_loc neurons of one area; the
        # per-device window packet at most its whole shard.
        A, n_pad = net.alive.shape
        if self.schedule == CONVENTIONAL:
            n_loc = n_pad // self.n_dev if n_pad % self.n_dev == 0 else n_pad
            self.ladder_loc = None
            self.ladder_dev = delivery_lib.bucket_ladder(
                cfg.s_max_floor, A * n_loc)
        else:
            a_loc, n_loc = A // self.n_groups, n_pad // self.gsz
            self.ladder_loc = delivery_lib.bucket_ladder(
                cfg.s_max_floor, n_loc)
            self.ladder_dev = delivery_lib.bucket_ladder(
                cfg.s_max_floor, a_loc * n_loc)
        # Static per-hook shipped-byte constants, derived from the same
        # accounting the Engine reports (dense_wire_bytes), so measured
        # bytes == modelled bytes wherever packets are statically sized.
        wb = dense_wire_bytes(
            net, backend=self.backend, schedule=self.schedule,
            n_groups=self.n_groups, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor)
        d_win = max(net.delay_ratio, 1)
        if self.schedule == CONVENTIONAL:
            self._cycle_wire = wb["global_bytes"] / d_win
            self._window_wire = 0.0
        else:
            self._cycle_wire = wb["local_bytes"] / d_win
            self._window_wire = float(wb["global_bytes"])

    # -- shard-index helpers (valid only inside shard_map) ------------------

    def _axis_offset(self, axes: Sequence[str], block: int):
        """This device's row offset for a dim sharded over ``axes``."""
        idx = jnp.int32(0)
        for ax in axes:
            idx = idx * self.mesh.shape[ax] + jax.lax.axis_index(ax)
        return idx * block

    def _group_index(self):
        """Flattened (row-major) index of this device's area group."""
        g = jnp.int32(0)
        for ax in self.area_axes:
            g = g * self.mesh.shape[ax] + jax.lax.axis_index(ax)
        return g

    def _global_to_local(self, a_loc: int, n_loc: int, net: Network):
        """Global target id -> local ring row (-1 if another device owns it)."""
        n_pad = net.n_pad
        aoff = self._axis_offset(self.area_axes, a_loc)
        noff = self._axis_offset((self.subgroup,), n_loc)

        def to_local(g):
            al = g // n_pad - aoff
            il = g % n_pad - noff
            keep = (al >= 0) & (al < a_loc) & (il >= 0) & (il < n_loc)
            return jnp.where(keep, al * n_loc + il, -1)

        return to_local

    def _inter_tables(self, net: Network):
        """This device's inter receive tables ``(tgt, w, d) [n_rows, K]``.

        With sharded inbound tables (``connectivity.shard_inter_tables``,
        the default distributed assembly) the shard_map view's leading
        shard axis is local size 1 -- ``[0]`` selects this device's own
        inbound slice, so the receive scatter touches only the ~1/S of
        edges this device owns. Subgroup-sliced tables carry a second
        sharded lane axis (``[S, gsz, rows, K]``, local view ``[1, 1,
        rows, K]``) -- ``[0, 0]`` selects this device's own ~1/(S * gsz)
        slice. The legacy replicated reshape is kept for
        ``EngineConfig.shard_inter_tables=False`` (the equivalence suite's
        bit-identity reference).
        """
        if net.tgt_inter_in is not None:
            if net.tgt_inter_in.ndim == 4:
                return (net.tgt_inter_in[0, 0], net.wout_inter_in[0, 0],
                        net.dout_inter_in[0, 0])
            return (net.tgt_inter_in[0], net.wout_inter_in[0],
                    net.dout_inter_in[0])
        n_rows = net.n_areas * net.n_pad
        k_out = net.tgt_inter.shape[-1]
        return (net.tgt_inter.reshape(n_rows, k_out),
                net.wout_inter.reshape(n_rows, k_out),
                net.dout_inter.reshape(n_rows, k_out))

    def _intra_tables(self, net: Network):
        """This device's outgoing intra tables ``(tgt, w, d) [A, n, K]``.

        Subgroup-sliced tables (``connectivity.slice_intra_tables``) carry
        a leading lane axis sharded over the subgroup (``[gsz, A, n_pad,
        K_lane]``, local view ``[1, A_loc, n_pad, K_lane]``) -- ``[0]``
        selects this lane's own target-window slice, so the local-pathway
        scatter touches only the ~1/gsz of intra edges landing in its own
        neuron window instead of a lane-replicated full table. The 3-D
        passthrough keeps the legacy replicated layout (single-host
        engines, the conventional cut, ``subgroup_inter_tables=False``).
        """
        if net.tgt_intra.ndim == 4:
            return net.tgt_intra[0], net.wout_intra[0], net.dout_intra[0]
        return net.tgt_intra, net.wout_intra, net.dout_intra

    # -- hooks --------------------------------------------------------------

    def cycle(self, ring, spikes, t, net, gids, *, inter_now: bool):
        if self.schedule == CONVENTIONAL:
            return self._cycle_conventional(ring, spikes, t, net, gids)
        assert not inter_now, "structure-aware lumps the global pathway"
        n_loc = spikes.shape[-1]
        a_loc = spikes.shape[0]
        s8 = spikes.astype(jnp.int8)
        over = jnp.int32(0)
        shipped = jnp.float32(self._cycle_wire)
        if self.backend == "event" and net.k_intra > 0:
            # Local pathway, sparse wire: compact fired neurons into
            # per-area id packets *before* the subgroup exchange.
            noff = jax.lax.axis_index(self.subgroup) * n_loc
            ids = noff + jnp.arange(n_loc, dtype=jnp.int32)

            # Scatter straight into this device's neuron window of each
            # area: within-area target -> local row, -1 if not ours.
            def to_local(i):
                il = i - noff
                keep = (il >= 0) & (il < n_loc)
                return jnp.where(keep, il, -1)

            def local_pathway(s_max, ring):
                packets, counts = jax.vmap(
                    lambda f: delivery_lib.compact_fired(
                        f, ids, s_max=s_max, invalid=net.n_pad)
                )(spikes)
                wire = jax.lax.all_gather(
                    packets, self.subgroup, axis=1, tiled=True)
                ring = jax.vmap(
                    lambda r, idl, tg, w, d: kops.event_deliver_ids(
                        r, idl, tg, w, d, t, tgt_map=to_local)
                )(ring, wire, *self._intra_tables(net))
                return ring, counts

            if self.adaptive:
                # Phase 1: the mesh-max per-(area, lane) count selects one
                # bucket for every device (branch uniformity); phase 2
                # ships rung-sized packets. The top rung is n_loc (this
                # lane's whole neuron window), so nothing can drop.
                need = comm.count_max(
                    spikes.sum(axis=-1, dtype=jnp.int32).max(),
                    self.all_axes)
                ring, _ = kops.ladder_switch(
                    self.ladder_loc, need, local_pathway, ring)
                rung = kops.ladder_rung(self.ladder_loc, need)
                shipped = (
                    jnp.float32(self.n_dev * a_loc * (self.gsz - 1)
                                * _I32_BYTES) * rung.astype(jnp.float32)
                    + comm.count_wire_bytes(1, self.n_dev))
            else:
                ring, counts = local_pathway(self.s_max_loc, ring)
                over = jax.lax.psum(
                    jnp.maximum(counts - self.s_max_loc, 0).sum(),
                    self.all_axes)
        elif self.backend != "event":
            # Local pathway, dense wire: complete this device's areas over
            # the subgroup, then deliver via the shared dispatch.
            area_spikes = comm.gather_area(s8, subgroup_axis=self.subgroup)
            ring = delivery_lib.deliver_intra(
                ring, area_spikes.astype(jnp.float32), net, t,
                backend=self.backend)
        if net.k_intra == 0:
            shipped = jnp.float32(0)
        return ring, over, shipped

    def _cycle_conventional(self, ring, spikes, t, net, gids):
        """One mesh-wide exchange feeds both pathways (round-robin layout)."""
        A, n_pad = net.n_areas, net.n_pad
        n_loc = spikes.shape[-1]
        r_len = ring.shape[-1]
        s8 = spikes.astype(jnp.int8)
        over = jnp.int32(0)
        shipped = jnp.float32(self._cycle_wire)
        if self.backend == "event":
            noff = self._axis_offset(self.all_axes, n_loc)

            # Both scatters go straight into this device's neuron window
            # (rows [noff, noff + n_loc) of every area) -- no full
            # [A, n_pad, R] buffer.
            def win_local(i):
                il = i - noff
                keep = (il >= 0) & (il < n_loc)
                return jnp.where(keep, il, -1)

            def exchange_cycle(s_max, ring):
                packet, count = delivery_lib.compact_fired(
                    spikes, gids, s_max=s_max, invalid=A * n_pad)
                wire = jax.lax.all_gather(
                    packet, self.all_axes, axis=0, tiled=True)  # [n_dev*s]
                if net.k_intra > 0:
                    # Short-range: per-area within-area ids from the list.
                    areas = jnp.arange(A, dtype=jnp.int32)
                    ids_a = jnp.where(
                        wire[None, :] // n_pad == areas[:, None],
                        wire[None, :] % n_pad, n_pad)       # [A, S]
                    ring = jax.vmap(
                        lambda r, idl, tg, w, d: kops.event_deliver_ids(
                            r, idl, tg, w, d, t, tgt_map=win_local)
                    )(ring, ids_a, *self._intra_tables(net))
                # Long-range: global target id -> (area row, local window).
                if net.k_inter > 0:
                    tgt_f, w_f, d_f = self._inter_tables(net)

                    def glob_local(g):
                        il = g % n_pad - noff
                        keep = (il >= 0) & (il < n_loc)
                        return jnp.where(keep, (g // n_pad) * n_loc + il, -1)

                    ring = kops.event_deliver_ids(
                        ring.reshape(A * n_loc, r_len), wire, tgt_f, w_f,
                        d_f, t, tgt_map=glob_local).reshape(A, n_loc, r_len)
                return ring, count

            if self.adaptive:
                # Phase 1: mesh-max fired count this cycle; phase 2: one
                # rung-sized packet per device. Top rung = the device's
                # whole shard (A * n_loc), so no count can exceed it.
                need = comm.count_max(
                    spikes.sum(dtype=jnp.int32), self.all_axes)
                ring, _ = kops.ladder_switch(
                    self.ladder_dev, need, exchange_cycle, ring)
                rung = kops.ladder_rung(self.ladder_dev, need)
                shipped = (
                    jnp.float32(self.n_dev * (self.n_dev - 1) * _I32_BYTES)
                    * rung.astype(jnp.float32)
                    + comm.count_wire_bytes(1, self.n_dev))
            else:
                ring, count = exchange_cycle(self.s_max_dev, ring)
                over = jax.lax.psum(
                    jnp.maximum(count - self.s_max_dev, 0), self.all_axes)
        else:
            # One global all_gather per cycle: every device needs the full
            # vector because its neurons' sources are scattered everywhere.
            full = comm.gather_full(s8, self.all_axes)
            full_f = full.astype(jnp.float32)  # [A, n_pad]
            ring = delivery_lib.deliver_intra(
                ring, full_f, net, t, backend=self.backend)
            ring = delivery_lib.deliver_inter(
                ring, full_f.reshape(-1), net, t, backend=self.backend)
        return ring, over, shipped

    def window_end(self, ring, block, t0, net, gids, *, blocked: bool):
        if net.k_inter == 0:
            return ring, jnp.int32(0), jnp.float32(0)
        a_loc, n_loc, r_len = ring.shape
        A, n_pad = net.n_areas, net.n_pad
        d_win = block.shape[0]
        shipped = jnp.float32(self._window_wire)
        if self.backend == "event":
            tgt_f, w_f, d_f = self._inter_tables(net)
            to_local = self._global_to_local(a_loc, n_loc, net)

            def exchange_window(s_max, ring):
                # Sparse wire: one (id, step) packet for the whole window.
                packets, counts = delivery_lib.compact_fired_block(
                    block, gids, s_max=s_max, invalid=A * n_pad)
                wire = jax.lax.all_gather(
                    packets, self.all_axes, axis=1, tiled=True)
                ring_flat = ring.reshape(a_loc * n_loc, r_len)
                if blocked:
                    # Single-pass blocked receive: all D packets at once.
                    ring_flat = kops.event_deliver_block(
                        ring_flat, wire, tgt_f, w_f, d_f, t0,
                        tgt_map=to_local)
                else:
                    def deliver_s(s, rf):
                        return kops.event_deliver_ids(
                            rf, wire[s], tgt_f, w_f, d_f, t0 + s,
                            tgt_map=to_local)

                    ring_flat = jax.lax.fori_loop(
                        0, d_win, deliver_s, ring_flat)
                return ring_flat.reshape(a_loc, n_loc, r_len), counts

            if self.adaptive:
                # Phase 1: the window's mesh-max per-cycle fired count (one
                # scalar pmax); phase 2: all D cycles ship rung-sized
                # packets. Top rung = the whole device shard -> zero drop.
                need = comm.count_max(
                    block.reshape(d_win, -1).sum(
                        axis=-1, dtype=jnp.int32).max(),
                    self.all_axes)
                ring, _ = kops.ladder_switch(
                    self.ladder_dev, need, exchange_window, ring)
                rung = kops.ladder_rung(self.ladder_dev, need)
                shipped = (
                    jnp.float32(self.n_dev * d_win * (self.n_dev - 1)
                                * _I32_BYTES) * rung.astype(jnp.float32)
                    + comm.count_wire_bytes(1, self.n_dev))
                return ring, jnp.int32(0), shipped
            ring, counts = exchange_window(self.s_max_dev, ring)
            over = jax.lax.psum(
                jnp.maximum(counts - self.s_max_dev, 0).sum(), self.all_axes)
            return ring, over, shipped

        gblock = comm.gather_global(
            block.astype(jnp.int8), area_axes=self.area_axes,
            subgroup_axis=self.subgroup)          # [D, A, n_pad] int8
        gflat = gblock.astype(jnp.float32).reshape(d_win, A * n_pad)
        if blocked:
            ring = delivery_lib.deliver_inter_block(
                ring, gflat, net, t0, backend=self.backend)
            return ring, jnp.int32(0), shipped

        def deliver_s(s, ring):
            return delivery_lib.deliver_inter(
                ring, gflat[s], net, t0 + s, backend=self.backend)

        ring = jax.lax.fori_loop(0, d_win, deliver_s, ring)
        return ring, jnp.int32(0), shipped

    # -- overlapped pipeline split ------------------------------------------

    def start_window_end(self, block, t0, net, gids, *, blocked: bool):
        del blocked
        t0 = jnp.asarray(t0, jnp.int32)
        d_win = block.shape[0]
        if net.k_inter == 0:
            return (InflightWindow(jnp.zeros((d_win, 0), jnp.int32), t0),
                    jnp.int32(0), jnp.float32(0))
        A, n_pad = net.n_areas, net.n_pad
        invalid = A * n_pad
        shipped = jnp.float32(self._window_wire)
        if self.backend == "event":
            if self.adaptive:
                # Phase 1 (the counts are final at the end of this window's
                # block) + the payload all_gather, both issued here; the pad
                # to the ladder cap keeps every bucket branch on one static
                # in-flight shape, extra slots carrying the fill id.
                cap = self.ladder_dev[-1]
                need = comm.count_max(
                    block.reshape(d_win, -1).sum(
                        axis=-1, dtype=jnp.int32).max(),
                    self.all_axes)

                def assemble(b):
                    packets, _ = delivery_lib.compact_fired_block(
                        block, gids, s_max=b, invalid=invalid)
                    gw = jax.lax.all_gather(
                        packets, self.all_axes, axis=1, tiled=True)
                    gw = gw.reshape(d_win, self.n_dev, b)
                    gw = jnp.pad(gw, ((0, 0), (0, 0), (0, cap - b)),
                                 constant_values=invalid)
                    return gw.reshape(d_win, self.n_dev * cap)

                wire = kops.ladder_switch(self.ladder_dev, need, assemble)
                rung = kops.ladder_rung(self.ladder_dev, need)
                shipped = (
                    jnp.float32(self.n_dev * d_win * (self.n_dev - 1)
                                * _I32_BYTES) * rung.astype(jnp.float32)
                    + comm.count_wire_bytes(1, self.n_dev))
                return InflightWindow(wire, t0), jnp.int32(0), shipped
            packets, counts = delivery_lib.compact_fired_block(
                block, gids, s_max=self.s_max_dev, invalid=invalid)
            wire = jax.lax.all_gather(
                packets, self.all_axes, axis=1, tiled=True)
            over = jax.lax.psum(
                jnp.maximum(counts - self.s_max_dev, 0).sum(), self.all_axes)
            return InflightWindow(wire, t0), over, shipped
        gblock = comm.gather_global(
            block.astype(jnp.int8), area_axes=self.area_axes,
            subgroup_axis=self.subgroup)          # [D, A, n_pad] int8
        return InflightWindow(gblock, t0), jnp.int32(0), shipped

    def finish_window_end(self, ring, inflight, net, gids, *, blocked: bool):
        del gids
        if net.k_inter == 0 or inflight.wire.shape[1] == 0:
            return ring
        a_loc, n_loc, r_len = ring.shape
        A, n_pad = net.n_areas, net.n_pad
        wire, t0 = inflight.wire, inflight.t0
        d_win = wire.shape[0]
        if self.backend == "event":
            tgt_f, w_f, d_f = self._inter_tables(net)
            to_local = self._global_to_local(a_loc, n_loc, net)
            ring_flat = ring.reshape(a_loc * n_loc, r_len)
            if blocked:
                ring_flat = kops.event_deliver_block(
                    ring_flat, wire, tgt_f, w_f, d_f, t0, tgt_map=to_local)
            else:
                def deliver_s(s, rf):
                    return kops.event_deliver_ids(
                        rf, wire[s], tgt_f, w_f, d_f, t0 + s,
                        tgt_map=to_local)

                ring_flat = jax.lax.fori_loop(0, d_win, deliver_s, ring_flat)
            return ring_flat.reshape(a_loc, n_loc, r_len)
        gflat = wire.astype(jnp.float32).reshape(d_win, A * n_pad)
        if blocked:
            return delivery_lib.deliver_inter_block(
                ring, gflat, net, t0, backend=self.backend)

        def deliver_s(s, ring):
            return delivery_lib.deliver_inter(
                ring, gflat[s], net, t0 + s, backend=self.backend)

        return jax.lax.fori_loop(0, d_win, deliver_s, ring)

    def init_inflight(self, net: Network) -> InflightWindow:
        d_win = max(net.delay_ratio, 1)
        A, n_pad = net.n_areas, net.n_pad
        if net.k_inter == 0:
            wire = jnp.zeros((d_win, 0), jnp.int32)
        elif self.backend == "event":
            cap = self.ladder_dev[-1] if self.adaptive else self.s_max_dev
            wire = jnp.full((d_win, self.n_dev * cap), A * n_pad, jnp.int32)
        else:
            wire = jnp.zeros((d_win, A, n_pad), jnp.int8)
        return InflightWindow(wire=wire, t0=jnp.int32(0))

    def inflight_pspecs(self) -> InflightWindow:
        from jax.sharding import PartitionSpec as P

        # The dense wire is the result of a whole-mesh gather: identical on
        # every device, so the in-flight state is replicated.
        return InflightWindow(wire=P(), t0=P())

    # -- static wire accounting ---------------------------------------------

    def wire_bytes(self, net: Network) -> dict:
        rep = dense_wire_bytes(
            net, backend=self.backend, schedule=self.schedule,
            n_groups=self.n_groups, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor)
        rep["adaptive"] = adaptive_wire_bytes(
            net, backend=self.backend, schedule=self.schedule,
            n_groups=self.n_groups, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor)
        rep["adaptive_on"] = self.adaptive
        return rep


class RoutedExchange(DenseMeshExchange):
    """Connectivity-routed global pathway (see the module docstring).

    The local pathway is inherited from :class:`DenseMeshExchange` -- the
    intra-area subgroup exchange already mirrors network structure. The
    window-end global pathway replaces the mesh-wide ``all_gather`` with
    ppermute rotation rounds over the group graph: each group's window
    packet is masked and re-compacted *per destination group* (only ids
    whose source area projects along the edge, bound ``RouteRound.s_max``),
    shipped only along edges that exist, and scattered through this
    device's inter receive tables on arrival (the sharded inbound slice by
    default, see ``_inter_tables``). Requires
    ``build_network(outgoing=True)`` for the inter tables, under every
    delivery backend (the routed wire format is id packets).
    """

    name = "routed"

    def __init__(self, net: Network, cfg, mesh, adjacency: np.ndarray):
        super().__init__(net, cfg, mesh)
        if cfg.schedule != STRUCTURE_AWARE:
            raise ValueError(
                "RoutedExchange routes the structure-aware window's lumped "
                "global pathway; the conventional schedule has none")
        if (net.k_inter > 0 and net.tgt_inter is None
                and net.tgt_inter_in is None):
            raise ValueError(
                "RoutedExchange ships id packets and scatters through the "
                "outgoing tables: build_network(outgoing=True) required")
        # The routed global pathway ships device packets regardless of the
        # delivery backend, so the bound must exist for the dense ones too
        # (the parent already set it for 'event').
        if self.backend != "event":
            _, self.s_max_dev = _mesh_bounds(
                net, n_groups=self.n_groups, gsz=self.gsz,
                headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
        exp_area = delivery_lib.expected_area_spikes(net)
        # Hierarchical round order on a multi-pod mesh: the leading area
        # axis is the pod tier, so groups-per-pod consecutive groups share
        # the fast tier and their offsets are scheduled first.
        intra_tier = (
            self.n_groups // mesh.shape[self.area_axes[0]]
            if len(self.area_axes) > 1 else None
        )
        self.routing = build_routing(
            adjacency, self.n_groups, exp_area_spikes=exp_area,
            headroom=cfg.s_max_headroom, floor=cfg.s_max_floor,
            intra_tier=intra_tier)
        # Baked constants: area -> destination-group projection (row A
        # absorbs the packet fill id) and the group graph for the
        # receive-validity mask.
        self._proj_const = np.concatenate(
            [self.routing.proj, np.zeros((1, self.n_groups), bool)], axis=0)
        # Adaptive per-round machinery: the edge-packet ladder tops out at
        # the whole source group's population (areas/group x n_pad -- also
        # exactly the assembled group packet's id capacity), and each
        # round's static [G, areas/group] mask selects, from the phase-1
        # per-area count table, the areas feeding that round's edges -- so
        # every device derives the round's *exact* packet need.
        A, n_pad = net.alive.shape
        a_grp = A // self.n_groups
        self.ladder_edge = delivery_lib.bucket_ladder(
            cfg.s_max_floor, a_grp * n_pad)
        proj_r = self.routing.proj.reshape(self.n_groups, a_grp,
                                           self.n_groups)
        self._round_masks = {
            rnd.offset: np.stack([
                proj_r[g, :, (g + rnd.offset) % self.n_groups]
                for g in range(self.n_groups)
            ]).astype(np.int32)                      # [G, areas/group]
            for rnd in self.routing.rounds
        }
        # The routed global pathway's static shipped-byte constant replaces
        # the dense parent's (same accounting routed_wire_bytes reports).
        self._window_wire = float(routed_wire_bytes(
            net, self.routing, backend=self.backend, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor)["global_bytes"])

    def window_end(self, ring, block, t0, net, gids, *, blocked: bool):
        # The routed receive is always the single-pass blocked scatter; a
        # window of per-cycle scatters would be bit-identical (grid-exact
        # weights), so ``blocked`` has nothing to select.
        del blocked
        if net.k_inter == 0 or not self.routing.rounds:
            return ring, jnp.int32(0), jnp.float32(0)
        if self.adaptive:
            return self._window_end_adaptive(ring, block, t0, net, gids)
        a_loc, n_loc, r_len = ring.shape
        A, n_pad = net.n_areas, net.n_pad
        G = self.routing.n_groups
        invalid = A * n_pad

        # 1. Assemble the *group* packet on the fast tier: compact this
        # device's fired ids, complete over the intra-area subgroup.
        packets, counts = delivery_lib.compact_fired_block(
            block, gids, s_max=self.s_max_dev, invalid=invalid)
        over = jax.lax.psum(
            jnp.maximum(counts - self.s_max_dev, 0).sum(), self.all_axes)
        gwire = jax.lax.all_gather(
            packets, self.subgroup, axis=1, tiled=True)      # [D, gsz*s_dev]

        my_g = self._group_index()
        lane0 = jax.lax.axis_index(self.subgroup) == 0
        src_area = jnp.where(gwire < invalid, gwire // n_pad, A)
        proj = jnp.asarray(self._proj_const)                 # [A+1, G]
        gadj = jnp.asarray(self.routing.group_adj)           # [G, G]

        # 2. One rotation round per *existing* offset of the group graph;
        # every received packet keeps its [D, s] row=cycle layout, so the
        # rounds concatenate along the id axis into ONE blocked scatter.
        received = []
        for rnd in self.routing.rounds:
            dst_g = jnp.mod(my_g + rnd.offset, G)
            keep = proj[src_area, dst_g]                     # [D, L]
            pkt, cnt = kops.compact_ids_block(
                keep, gwire, size=rnd.s_max, fill_id=invalid)
            # Per-edge spill: every subgroup lane computes the same count,
            # so only lane 0 contributes to the psum.
            spill = jnp.maximum(cnt - rnd.s_max, 0).sum()
            over = over + jax.lax.psum(
                jnp.where(lane0, spill, 0), self.all_axes)
            if rnd.offset:
                axis = (self.area_axes if len(self.area_axes) > 1
                        else self.area_axes[0])
                pkt = jax.lax.ppermute(pkt, axis, rnd.pairs)
                # Groups with no inbound edge at this offset received zeros
                # from ppermute (id 0 is a real neuron): mask them invalid.
                ok = gadj[jnp.mod(my_g - rnd.offset, G), my_g]
                pkt = jnp.where(ok, pkt, invalid)
            received.append(pkt)

        tgt_f, w_f, d_f = self._inter_tables(net)
        to_local = self._global_to_local(a_loc, n_loc, net)
        ring_flat = kops.event_deliver_block(
            ring.reshape(a_loc * n_loc, r_len),
            jnp.concatenate(received, axis=1),
            tgt_f, w_f, d_f, t0, tgt_map=to_local)
        return (ring_flat.reshape(a_loc, n_loc, r_len), over,
                jnp.float32(self._window_wire))

    def _window_end_adaptive(self, ring, block, t0, net, gids):
        """The two-phase routed window: exact counts, then right-sized
        packets.

        Phase 1 ships the global ``[D, A]`` per-area spike-count table
        (``comm.gather_counts``) plus one scalar pmax -- from the table
        every device derives, identically, the *exact* packet need of the
        group assembly and of every rotation round's edges, so all bucket
        choices are branch-uniform and no packet can drop a spike (the
        ladders top out at the group population). Phase 2 assembles the
        group packet at the device bucket and re-compacts each round at its
        own edge bucket; each round scatters immediately (per-round
        ``event_deliver_block`` -- bit-identical to the static path's
        concatenated single scatter, grid-exact weights).
        """
        a_loc, n_loc, r_len = ring.shape
        A, n_pad = net.n_areas, net.n_pad
        G = self.routing.n_groups
        invalid = A * n_pad
        d_win = block.shape[0]
        gsz = self.gsz
        cap_dev = self.ladder_dev[-1]

        # -- phase 1: counts ------------------------------------------------
        counts_local = block.sum(axis=-1, dtype=jnp.int32)   # [D, A_loc]
        counts_all = comm.gather_counts(
            counts_local, area_axes=self.area_axes,
            subgroup_axis=self.subgroup)                     # [D, A]
        dev_need = comm.count_max(
            counts_local.sum(axis=-1).max(), self.all_axes)
        shipped = jnp.float32(
            comm.count_wire_bytes(d_win * A + 1, self.n_dev))

        # -- phase 2a: assemble the group packet at the device bucket -------
        def assemble(b):
            packets, _ = delivery_lib.compact_fired_block(
                block, gids, s_max=b, invalid=invalid)       # [D, b]
            gw = jax.lax.all_gather(
                packets, self.subgroup, axis=1, tiled=True)  # [D, gsz*b]
            # Pad each lane's slot out to the ladder cap so every bucket
            # branch returns the same [D, gsz*cap] shape (extra slots carry
            # the fill id, absorbed by the receive scatter).
            gw = gw.reshape(d_win, gsz, b)
            gw = jnp.pad(gw, ((0, 0), (0, 0), (0, cap_dev - b)),
                         constant_values=invalid)
            return gw.reshape(d_win, gsz * cap_dev)

        gwire = kops.ladder_switch(self.ladder_dev, dev_need, assemble)
        rung_dev = kops.ladder_rung(self.ladder_dev, dev_need)
        shipped = shipped + (
            jnp.float32(self.n_dev * (gsz - 1) * d_win * _I32_BYTES)
            * rung_dev.astype(jnp.float32))

        my_g = self._group_index()
        src_area = jnp.where(gwire < invalid, gwire // n_pad, A)
        proj = jnp.asarray(self._proj_const)                 # [A+1, G]
        gadj = jnp.asarray(self.routing.group_adj)           # [G, G]
        tgt_f, w_f, d_f = self._inter_tables(net)
        to_local = self._global_to_local(a_loc, n_loc, net)
        cg = counts_all.reshape(d_win, G, A // G)

        # -- phase 2b: one bucketed round per existing offset ---------------
        for rnd in self.routing.rounds:
            mask = jnp.asarray(self._round_masks[rnd.offset])  # [G, A/G]
            # Exact per-edge need: spikes of the areas projecting along
            # each edge at this offset, maxed over cycles and edges.
            need_r = (cg * mask[None]).sum(axis=-1).max()
            dst_g = jnp.mod(my_g + rnd.offset, G)
            keep = proj[src_area, dst_g]                     # [D, L]

            def round_fn(b, ring, rnd=rnd, keep=keep):
                pkt, _ = kops.compact_ids_block(
                    keep, gwire, size=b, fill_id=invalid)
                if rnd.offset:
                    axis = (self.area_axes if len(self.area_axes) > 1
                            else self.area_axes[0])
                    pkt = jax.lax.ppermute(pkt, axis, rnd.pairs)
                    ok = gadj[jnp.mod(my_g - rnd.offset, G), my_g]
                    pkt = jnp.where(ok, pkt, invalid)
                rf = kops.event_deliver_block(
                    ring.reshape(a_loc * n_loc, r_len), pkt,
                    tgt_f, w_f, d_f, t0, tgt_map=to_local)
                return rf.reshape(a_loc, n_loc, r_len)

            ring = kops.ladder_switch(
                self.ladder_edge, need_r, round_fn, ring)
            if rnd.offset:
                rung = kops.ladder_rung(self.ladder_edge, need_r)
                shipped = shipped + (
                    jnp.float32(len(rnd.pairs) * gsz * d_win * _I32_BYTES)
                    * rung.astype(jnp.float32))
        return ring, jnp.int32(0), shipped

    # -- overlapped pipeline split ------------------------------------------

    def start_window_end(self, block, t0, net, gids, *, blocked: bool):
        # All rotation rounds (collectives) run here; the received packets
        # keep their [D, s] row=cycle layout and concatenate along the id
        # axis into ONE in-flight wire, scattered by finish_window_end. The
        # leading size-1 axis is this group's slot of the global in-flight
        # state (the routed wire differs per group, unlike the dense one).
        del blocked
        t0 = jnp.asarray(t0, jnp.int32)
        d_win = block.shape[0]
        if net.k_inter == 0 or not self.routing.rounds:
            return (InflightWindow(jnp.zeros((1, d_win, 0), jnp.int32), t0),
                    jnp.int32(0), jnp.float32(0))
        if self.adaptive:
            return self._start_adaptive(block, t0, net, gids)
        A, n_pad = net.n_areas, net.n_pad
        G = self.routing.n_groups
        invalid = A * n_pad

        packets, counts = delivery_lib.compact_fired_block(
            block, gids, s_max=self.s_max_dev, invalid=invalid)
        over = jax.lax.psum(
            jnp.maximum(counts - self.s_max_dev, 0).sum(), self.all_axes)
        gwire = jax.lax.all_gather(
            packets, self.subgroup, axis=1, tiled=True)      # [D, gsz*s_dev]

        my_g = self._group_index()
        lane0 = jax.lax.axis_index(self.subgroup) == 0
        src_area = jnp.where(gwire < invalid, gwire // n_pad, A)
        proj = jnp.asarray(self._proj_const)                 # [A+1, G]
        gadj = jnp.asarray(self.routing.group_adj)           # [G, G]

        received = []
        for rnd in self.routing.rounds:
            dst_g = jnp.mod(my_g + rnd.offset, G)
            keep = proj[src_area, dst_g]                     # [D, L]
            pkt, cnt = kops.compact_ids_block(
                keep, gwire, size=rnd.s_max, fill_id=invalid)
            spill = jnp.maximum(cnt - rnd.s_max, 0).sum()
            over = over + jax.lax.psum(
                jnp.where(lane0, spill, 0), self.all_axes)
            if rnd.offset:
                axis = (self.area_axes if len(self.area_axes) > 1
                        else self.area_axes[0])
                pkt = jax.lax.ppermute(pkt, axis, rnd.pairs)
                ok = gadj[jnp.mod(my_g - rnd.offset, G), my_g]
                pkt = jnp.where(ok, pkt, invalid)
            received.append(pkt)
        wire = jnp.concatenate(received, axis=1)[None]       # [1, D, L]
        return (InflightWindow(wire, t0), over,
                jnp.float32(self._window_wire))

    def _start_adaptive(self, block, t0, net, gids):
        """Two-phase start: phase 1 + every bucketed round, no scatter.

        Identical collectives to ``_window_end_adaptive`` (the wire ships
        rung-sized packets), but each round's packet is padded out to the
        edge-ladder cap *after* the ppermute so all bucket branches share
        one static in-flight shape; the extra slots carry the fill id,
        which the deferred receive scatter absorbs bitwise.
        """
        A, n_pad = net.n_areas, net.n_pad
        G = self.routing.n_groups
        invalid = A * n_pad
        d_win = block.shape[0]
        gsz = self.gsz
        cap_dev = self.ladder_dev[-1]
        cap_edge = self.ladder_edge[-1]

        # -- phase 1: counts ------------------------------------------------
        counts_local = block.sum(axis=-1, dtype=jnp.int32)   # [D, A_loc]
        counts_all = comm.gather_counts(
            counts_local, area_axes=self.area_axes,
            subgroup_axis=self.subgroup)                     # [D, A]
        dev_need = comm.count_max(
            counts_local.sum(axis=-1).max(), self.all_axes)
        shipped = jnp.float32(
            comm.count_wire_bytes(d_win * A + 1, self.n_dev))

        # -- phase 2a: assemble the group packet at the device bucket -------
        def assemble(b):
            packets, _ = delivery_lib.compact_fired_block(
                block, gids, s_max=b, invalid=invalid)       # [D, b]
            gw = jax.lax.all_gather(
                packets, self.subgroup, axis=1, tiled=True)  # [D, gsz*b]
            gw = gw.reshape(d_win, gsz, b)
            gw = jnp.pad(gw, ((0, 0), (0, 0), (0, cap_dev - b)),
                         constant_values=invalid)
            return gw.reshape(d_win, gsz * cap_dev)

        gwire = kops.ladder_switch(self.ladder_dev, dev_need, assemble)
        rung_dev = kops.ladder_rung(self.ladder_dev, dev_need)
        shipped = shipped + (
            jnp.float32(self.n_dev * (gsz - 1) * d_win * _I32_BYTES)
            * rung_dev.astype(jnp.float32))

        my_g = self._group_index()
        src_area = jnp.where(gwire < invalid, gwire // n_pad, A)
        proj = jnp.asarray(self._proj_const)                 # [A+1, G]
        gadj = jnp.asarray(self.routing.group_adj)           # [G, G]
        cg = counts_all.reshape(d_win, G, A // G)

        # -- phase 2b: one bucketed round per existing offset ---------------
        received = []
        for rnd in self.routing.rounds:
            mask = jnp.asarray(self._round_masks[rnd.offset])  # [G, A/G]
            need_r = (cg * mask[None]).sum(axis=-1).max()
            dst_g = jnp.mod(my_g + rnd.offset, G)
            keep = proj[src_area, dst_g]                     # [D, L]

            def round_fn(b, rnd=rnd, keep=keep):
                pkt, _ = kops.compact_ids_block(
                    keep, gwire, size=b, fill_id=invalid)
                if rnd.offset:
                    axis = (self.area_axes if len(self.area_axes) > 1
                            else self.area_axes[0])
                    pkt = jax.lax.ppermute(pkt, axis, rnd.pairs)
                    ok = gadj[jnp.mod(my_g - rnd.offset, G), my_g]
                    pkt = jnp.where(ok, pkt, invalid)
                return jnp.pad(pkt, ((0, 0), (0, cap_edge - b)),
                               constant_values=invalid)

            received.append(
                kops.ladder_switch(self.ladder_edge, need_r, round_fn))
            if rnd.offset:
                rung = kops.ladder_rung(self.ladder_edge, need_r)
                shipped = shipped + (
                    jnp.float32(len(rnd.pairs) * gsz * d_win * _I32_BYTES)
                    * rung.astype(jnp.float32))
        wire = jnp.concatenate(received, axis=1)[None]       # [1, D, L]
        return InflightWindow(wire, t0), jnp.int32(0), shipped

    def finish_window_end(self, ring, inflight, net, gids, *, blocked: bool):
        # Collective-free: one blocked scatter of the concatenated rounds
        # (scatter-order independence makes it bit-identical to the
        # sequential path's per-round scatters; fill ids scatter nothing).
        del blocked, gids
        if net.k_inter == 0 or inflight.wire.shape[-1] == 0:
            return ring
        a_loc, n_loc, r_len = ring.shape
        tgt_f, w_f, d_f = self._inter_tables(net)
        to_local = self._global_to_local(a_loc, n_loc, net)
        ring_flat = kops.event_deliver_block(
            ring.reshape(a_loc * n_loc, r_len), inflight.wire[0],
            tgt_f, w_f, d_f, inflight.t0, tgt_map=to_local)
        return ring_flat.reshape(a_loc, n_loc, r_len)

    def init_inflight(self, net: Network) -> InflightWindow:
        d_win = max(net.delay_ratio, 1)
        if net.k_inter == 0 or not self.routing.rounds:
            width = 0
        elif self.adaptive:
            width = len(self.routing.rounds) * self.ladder_edge[-1]
        else:
            width = sum(rnd.s_max for rnd in self.routing.rounds)
        wire = jnp.full((self.n_groups, d_win, width),
                        net.n_areas * net.n_pad, jnp.int32)
        return InflightWindow(wire=wire, t0=jnp.int32(0))

    def inflight_pspecs(self) -> InflightWindow:
        from jax.sharding import PartitionSpec as P

        # The routed wire differs per device group: the global in-flight
        # state carries a leading group axis, sharded over the area axes
        # (local slice [1, D, L]); it is replicated over the subgroup axis.
        return InflightWindow(wire=P(self.area_axes, None, None), t0=P())

    def wire_bytes(self, net: Network) -> dict:
        rep = routed_wire_bytes(
            net, self.routing, backend=self.backend, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor)
        rep["adaptive"] = adaptive_wire_bytes(
            net, backend=self.backend, schedule=STRUCTURE_AWARE,
            n_groups=self.n_groups, gsz=self.gsz,
            headroom=self.headroom, floor=self.floor, routing=self.routing)
        rep["adaptive_on"] = self.adaptive
        return rep


# ---------------------------------------------------------------------------
# Static wire accounting (mesh-total bytes received per window)
# ---------------------------------------------------------------------------


def _mesh_bounds(net: Network, *, n_groups, gsz, headroom, floor):
    s_max_area, s_max_all = delivery_lib.event_bounds(
        net, headroom=headroom, floor=floor)
    s_max_loc = max(floor, -(-s_max_area // gsz))
    s_max_dev = max(floor, -(-s_max_all // (n_groups * gsz)))
    return s_max_loc, s_max_dev


def dense_wire_bytes(
    net: Network, *, backend: str, schedule: str,
    n_groups: int, gsz: int, headroom: float = 8.0, floor: int = 16,
) -> dict:
    """Mesh-total received bytes per window of :class:`DenseMeshExchange`."""
    n_dev = n_groups * gsz
    d_win = net.delay_ratio
    A, n_pad = net.n_areas, net.n_pad
    s_max_loc, s_max_dev = _mesh_bounds(
        net, n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    if schedule == CONVENTIONAL:
        n_loc = n_pad // n_dev
        if backend == "event":
            glob = n_dev * d_win * (n_dev - 1) * s_max_dev * _I32_BYTES
        else:
            glob = n_dev * d_win * A * (n_dev - 1) * -(-n_loc // 8)
        return dict(exchange="dense", schedule=schedule, backend=backend,
                    local_bytes=0, global_bytes=glob, total_bytes=glob)
    a_loc, n_loc = A // n_groups, n_pad // gsz
    per = -(-n_loc // 8)  # packed bytes per local spike-vector shard
    if net.k_intra == 0:
        local = 0
    elif backend == "event":
        local = n_dev * d_win * a_loc * (gsz - 1) * s_max_loc * _I32_BYTES
    else:
        local = n_dev * d_win * a_loc * (gsz - 1) * per
    if net.k_inter == 0:
        glob = 0
    elif backend == "event":
        glob = n_dev * d_win * (n_dev - 1) * s_max_dev * _I32_BYTES
    else:
        # gather_global: subgroup stage, then the area-axes stages.
        glob = n_dev * d_win * a_loc * per * (
            (gsz - 1) + (n_groups - 1) * gsz)
    return dict(exchange="dense", schedule=schedule, backend=backend,
                local_bytes=local, global_bytes=glob,
                total_bytes=local + glob)


def routed_wire_bytes(
    net: Network, routing: Routing, *, backend: str,
    gsz: int, headroom: float = 8.0, floor: int = 16,
) -> dict:
    """Mesh-total received bytes per window of :class:`RoutedExchange`.

    The local pathway is the dense structure-aware one; the global pathway is
    the subgroup assembly plus one ``[D, s_max]`` id packet per existing edge
    per subgroup lane -- offsets with no edge ship nothing at all.
    """
    n_groups = routing.n_groups
    n_dev = n_groups * gsz
    d_win = net.delay_ratio
    base = dense_wire_bytes(
        net, backend=backend, schedule=STRUCTURE_AWARE,
        n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    _, s_max_dev = _mesh_bounds(
        net, n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    if net.k_inter == 0:
        glob = 0
    else:
        assembly = n_dev * (gsz - 1) * d_win * s_max_dev * _I32_BYTES
        edges = sum(
            len(r.pairs) * gsz * d_win * r.s_max * _I32_BYTES
            for r in routing.rounds if r.offset != 0
        )
        glob = assembly + edges
    return dict(exchange="routed", schedule=STRUCTURE_AWARE, backend=backend,
                local_bytes=base["local_bytes"], global_bytes=glob,
                total_bytes=base["local_bytes"] + glob,
                rounds=routing.n_wire_rounds,
                dense_rounds=max(n_groups - 1, 0),
                edges=routing.n_edges)


def adaptive_wire_bytes(
    net: Network,
    *,
    backend: str,
    schedule: str = STRUCTURE_AWARE,
    n_groups: int,
    gsz: int,
    headroom: float = 8.0,
    floor: int = 16,
    routing: Routing | None = None,
) -> dict:
    """The adaptive two-phase exchange's byte model (pure shape arithmetic).

    Prices, per window: ``counts_bytes`` (the phase-1 count collectives),
    ``payload_bytes_expected`` (phase-2 packets sized by the rung an
    expectation-sized window lands on, :func:`repro.core.delivery
    .expected_bucket` -- the *typical*-window bytes; live runs report the
    actually-measured value in ``SimState.shipped_bytes``), and
    ``payload_bytes_worst`` (every ladder at its hard-cap top rung -- the
    bound that makes overflow impossible). ``saved_bytes`` is the
    expectation-window saving vs the static-bound path; ``applies=False``
    marks pathways with no id packets to size (the dense exchange's
    bit-packed backends), where the numbers simply restate the static case.
    Mirrors the runtime constants of the exchange hooks term for term, so
    modelled and measured bytes agree whenever counts sit on the modelled
    rung.
    """
    n_dev = n_groups * gsz
    d_win = net.delay_ratio
    A, n_pad = net.n_areas, net.n_pad
    exp_area = delivery_lib.expected_area_spikes(net)
    if routing is not None:
        static = routed_wire_bytes(
            net, routing, backend=backend, gsz=gsz,
            headroom=headroom, floor=floor)
    else:
        static = dense_wire_bytes(
            net, backend=backend, schedule=schedule, n_groups=n_groups,
            gsz=gsz, headroom=headroom, floor=floor)
    out = dict(
        exchange=static["exchange"], backend=backend, applies=False,
        static_total_bytes=static["total_bytes"], counts_bytes=0,
        payload_bytes_expected=static["total_bytes"],
        payload_bytes_worst=static["total_bytes"],
        total_bytes_expected=static["total_bytes"],
        saved_bytes=0, buckets={},
    )
    if routing is None and backend != "event":
        return out  # bit-packed dense wire: nothing to size adaptively
    out["applies"] = True
    buckets: dict = {}
    counts = 0
    payload_exp = 0
    payload_worst = 0
    if schedule == CONVENTIONAL:
        n_loc = n_pad // n_dev
        ladder = delivery_lib.bucket_ladder(floor, A * n_loc)
        b = delivery_lib.expected_bucket(ladder, exp_area * A / n_dev)
        buckets["device"] = b
        counts = d_win * comm.count_wire_bytes(1, n_dev)
        payload_exp = n_dev * d_win * (n_dev - 1) * b * _I32_BYTES
        payload_worst = n_dev * d_win * (n_dev - 1) * ladder[-1] * _I32_BYTES
    else:
        a_loc, n_loc = A // n_groups, n_pad // gsz
        if net.k_intra > 0 and backend == "event":
            ladder_loc = delivery_lib.bucket_ladder(floor, n_loc)
            bl = delivery_lib.expected_bucket(ladder_loc, exp_area / gsz)
            buckets["local"] = bl
            counts += d_win * comm.count_wire_bytes(1, n_dev)
            payload_exp += (n_dev * d_win * a_loc * (gsz - 1)
                            * bl * _I32_BYTES)
            payload_worst += (n_dev * d_win * a_loc * (gsz - 1)
                              * ladder_loc[-1] * _I32_BYTES)
        else:
            # The dense local pathway stays bit-packed (not adaptively
            # sized); restate its static bytes so totals remain comparable.
            payload_exp += static["local_bytes"]
            payload_worst += static["local_bytes"]
        if net.k_inter > 0:
            ladder_dev = delivery_lib.bucket_ladder(floor, a_loc * n_loc)
            if routing is None:
                bd = delivery_lib.expected_bucket(
                    ladder_dev, exp_area * A / n_dev)
                buckets["device"] = bd
                counts += comm.count_wire_bytes(1, n_dev)
                payload_exp += (n_dev * d_win * (n_dev - 1)
                                * bd * _I32_BYTES)
                payload_worst += (n_dev * d_win * (n_dev - 1)
                                  * ladder_dev[-1] * _I32_BYTES)
            else:
                G = routing.n_groups
                bd = delivery_lib.expected_bucket(
                    ladder_dev, exp_area * A / n_dev)
                buckets["assembly"] = bd
                counts += comm.count_wire_bytes(d_win * A + 1, n_dev)
                payload_exp += (n_dev * (gsz - 1) * d_win * bd * _I32_BYTES)
                payload_worst += (n_dev * (gsz - 1) * d_win
                                  * ladder_dev[-1] * _I32_BYTES)
                ladder_edge = delivery_lib.bucket_ladder(
                    floor, a_loc * n_pad)
                proj_r = routing.proj.reshape(G, A // G, G)
                round_buckets = {}
                for rnd in routing.rounds:
                    if rnd.offset == 0:
                        continue
                    n_src = max(int(proj_r[g, :, h].sum())
                                for g, h in rnd.pairs)
                    br = delivery_lib.expected_bucket(
                        ladder_edge, exp_area * n_src)
                    round_buckets[rnd.offset] = br
                    payload_exp += (len(rnd.pairs) * gsz * d_win
                                    * br * _I32_BYTES)
                    payload_worst += (len(rnd.pairs) * gsz * d_win
                                      * ladder_edge[-1] * _I32_BYTES)
                buckets["rounds"] = round_buckets
    out.update(
        counts_bytes=counts,
        payload_bytes_expected=payload_exp,
        payload_bytes_worst=payload_worst,
        total_bytes_expected=counts + payload_exp,
        saved_bytes=static["total_bytes"] - (counts + payload_exp),
        buckets=buckets,
    )
    return out


def inter_table_report(
    net: Network,
    *,
    n_groups: int,
    gsz: int,
    schedule: str = STRUCTURE_AWARE,
    headroom: float = 8.0,
    floor: int = 16,
    routing: Routing | None = None,
    subgroup: int = 1,
) -> dict:
    """Per-device inter receive-table bytes and receive-side scatter work,
    replicated vs sharded -- the static accounting of the sharded-table
    tentpole (pure shape arithmetic, no devices).

    ``table_bytes.replicated`` prices the legacy layout (every device holds
    the full ``[A * n_pad, K_out]`` outgoing tables,
    ``Network.bytes_per_synapse()`` B/synapse); ``table_bytes.sharded``
    prices the inbound slice one device keeps after
    :func:`repro.core.connectivity.shard_inter_tables` (one shard of the
    ``[S, A * n_pad, K_in]`` stack, or one ``[S, gsz, A * n_pad, K_in]``
    lane of the subgroup-sliced layout -- detected from the table rank, or
    requested via ``subgroup`` for the width-bound fallback). Widths come
    from the network's own tables when it carries them and fall back to the
    deterministic ``network_sds`` bounds otherwise, so the report matches
    what the dry-run lowers. ``receive`` counts synapse touches per device
    per window of the event receive scatter (ids scattered x table width):
    the id volume is unchanged by sharding -- the win is the ~S x narrower
    table each id fans out over. Feeds ``launch/dryrun.py``,
    ``benchmarks/bench_delivery.py`` and ``cost_model.receive_time_s``.
    """
    from repro.core import connectivity as connectivity_lib

    n_dev = n_groups * gsz
    d_win = net.delay_ratio
    rows = net.n_areas * net.n_pad
    n_shards = n_groups if schedule == STRUCTURE_AWARE else n_dev
    k_e = net.k_inter
    syn_b = net.bytes_per_synapse()
    if net.tgt_inter is not None:
        k_rep = net.tgt_inter.shape[-1]
    else:
        k_rep = connectivity_lib._outgoing_k_bound(k_e)
    if net.tgt_inter_in is not None:
        k_sh = net.tgt_inter_in.shape[-1]
        # [S, rows, K] -> S shards; [S, gsz, rows, K] -> S * gsz slices.
        n_shards = int(np.prod(net.tgt_inter_in.shape[:-2]))
    else:
        n_shards = n_shards * max(subgroup, 1)
        k_sh = connectivity_lib._inbound_k_bound(k_e, n_shards)
    bytes_rep = rows * k_rep * syn_b
    bytes_sh = rows * k_sh * syn_b
    _, s_max_dev = _mesh_bounds(
        net, n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    # Ids scattered per device per window by each global pathway.
    ids = {"dense": d_win * n_dev * s_max_dev}
    if routing is not None:
        ids["routed"] = d_win * sum(r.s_max for r in routing.rounds)
    receive = {
        name: dict(
            ids_per_window=n,
            syn_touches_replicated=n * k_rep,
            syn_touches_sharded=n * k_sh,
        )
        for name, n in ids.items()
    }
    return dict(
        rows=rows,
        n_shards=n_shards,
        k_out_replicated=k_rep,
        k_in_sharded=k_sh,
        table_bytes=dict(
            replicated=bytes_rep,
            sharded=bytes_sh,
            reduction=bytes_rep / bytes_sh if bytes_sh else float("inf"),
        ),
        receive=receive,
    )


def priced_inter_table_report(
    net: Network,
    *,
    n_groups: int,
    gsz: int,
    schedule: str = STRUCTURE_AWARE,
    headroom: float = 8.0,
    floor: int = 16,
    routing: Routing | None = None,
    subgroup: int = 1,
) -> dict:
    """:func:`inter_table_report` with *both* table layouts priced from one
    network.

    A network normally carries one layout (replicated before
    ``shard_inter_tables`` / inbound after); the missing side would fall
    back to the deterministic width bound, whose per-shard slack
    misprices small configs. This instantiates the sharded slices from a
    replicated-only network (or their SDS bound for stand-ins) and
    re-attaches the replicated leaves, so every caller of the
    replicated-vs-sharded comparison (``benchmarks/bench_delivery.py``,
    ``launch/simulate.py --profile``, ``launch/dryrun.py``) prices the
    same thing the same way.
    """
    if (net.k_inter > 0 and net.tgt_inter is not None
            and net.tgt_inter_in is None):
        from repro.core import connectivity as connectivity_lib

        n_shards = n_groups if schedule == STRUCTURE_AWARE else n_groups * gsz
        mode = "group" if schedule == STRUCTURE_AWARE else "window"
        sharded = connectivity_lib.shard_inter_tables(
            net, n_shards, mode=mode,
            subgroup=subgroup if mode == "group" else 1)
        net = dataclasses.replace(
            sharded, tgt_inter=net.tgt_inter, wout_inter=net.wout_inter,
            dout_inter=net.dout_inter)
    return inter_table_report(
        net, n_groups=n_groups, gsz=gsz, schedule=schedule,
        headroom=headroom, floor=floor, routing=routing, subgroup=subgroup)


def wire_report(
    net: Network,
    adjacency: np.ndarray,
    *,
    backend: str,
    n_groups: int,
    gsz: int,
    headroom: float = 8.0,
    floor: int = 16,
) -> dict:
    """Dense-vs-routed wire volume for a hypothetical ``n_groups x gsz``
    mesh -- pure static accounting, no devices required. Feeds
    ``benchmarks/bench_delivery.py`` and ``simulate.py --profile``.

    Each entry carries *both* sizings: the top-level fields are the static
    worst case (fixed ``s_max`` packets -- what a non-adaptive run always
    ships), and ``["adaptive"]`` is the two-phase model
    (:func:`adaptive_wire_bytes`: phase-1 count bytes + expectation-sized
    payload + hard-cap worst case), so dry-run and benchmark rows stay
    honest when ``EngineConfig.adaptive_exchange`` is on. Live runs report
    the measured value in ``SimState.shipped_bytes``.
    """
    exp_area = delivery_lib.expected_area_spikes(net)
    routing = build_routing(
        adjacency, n_groups, exp_area_spikes=exp_area,
        headroom=headroom, floor=floor)
    dense = dense_wire_bytes(
        net, backend=backend, schedule=STRUCTURE_AWARE,
        n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    dense["adaptive"] = adaptive_wire_bytes(
        net, backend=backend, schedule=STRUCTURE_AWARE,
        n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor)
    routed = routed_wire_bytes(
        net, routing, backend=backend, gsz=gsz,
        headroom=headroom, floor=floor)
    routed["adaptive"] = adaptive_wire_bytes(
        net, backend=backend, schedule=STRUCTURE_AWARE,
        n_groups=n_groups, gsz=gsz, headroom=headroom, floor=floor,
        routing=routing)
    return dict(dense=dense, routed=routed)
