"""End-to-end wall-clock cost model (reproduces Figs. 1b, 4, 7a, 8, 9).

This container has one CPU, not a 128-node cluster, so the paper's scaling
figures are reproduced through a calibrated performance model -- exactly the
kind of semi-empirical model the paper calls for in its Discussion ("it is
time for ... more advanced performance modeling"). The model composes:

  per-cycle, per-process compute time
      t_cycle = t_deliver + t_update + t_collocate           (paper eq. 18)
  + a collective-communication model  t_coll = alpha(M) + bytes/beta   (Fig. 4)
  + the order-statistics synchronization model of §2.2 (sync_model)
  + the cache model of §2.3 (delivery_model) feeding t_deliver.

Calibration constants are fitted to the published SuperMUC-NG numbers (RTF
9.4 -> 22.7 conventional and 8.5 -> 15.7 structure-aware across M = 16..128,
Fig. 7a) and are documented inline. The same machinery with TPU constants
(dispatch ~1 us, ICI ~50 GB/s/link) feeds the §Roofline collective term.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import delivery_model, sync_model

__all__ = [
    "CollectiveModel",
    "SUPERMUC_MPI",
    "JURECA_MPI",
    "TPU_ICI",
    "MachineModel",
    "SUPERMUC",
    "JURECA",
    "WorkloadModel",
    "PhaseBreakdown",
    "receive_time_s",
    "exchange_time_s",
    "simulate_rtf",
]


@dataclasses.dataclass(frozen=True)
class CollectiveModel:
    """t(one collective call) = alpha(M) + total_bytes / beta.

    ``alpha`` captures per-call dispatch/latency (and its growth with
    participant count -- OpenMPI algorithm switches appear as jumps, Fig. 4);
    ``beta`` is the effective aggregate bandwidth.
    """

    alpha_us_by_log2m: tuple[float, ...]  # alpha for M = 2^i
    beta_gbps: float

    def alpha_us(self, m: int) -> float:
        i = min(max(int(round(math.log2(max(m, 1)))), 0),
                len(self.alpha_us_by_log2m) - 1)
        return self.alpha_us_by_log2m[i]

    def call_time_s(self, m: int, total_bytes: float) -> float:
        return self.alpha_us(m) * 1e-6 + total_bytes / (self.beta_gbps * 1e9)


# Calibrated to Fig. 4 (MPI_Alltoall on SuperMUC-NG, OpenMPI): latency-
# dominated at the paper's spike-buffer sizes; jumps at 64/128 ranks.
SUPERMUC_MPI = CollectiveModel(
    alpha_us_by_log2m=(5, 8, 12, 18, 26, 40, 65, 120),  # M=1..128
    beta_gbps=10.0,
)
# JURECA-DC: InfiniBand HDR100, slightly lower latency, higher bandwidth.
JURECA_MPI = CollectiveModel(
    alpha_us_by_log2m=(4, 6, 9, 14, 20, 32, 50, 90),
    beta_gbps=12.5,
)
# TPU ICI (v5e-class): ~1 us dispatch, ~50 GB/s per link; used by roofline.
TPU_ICI = CollectiveModel(
    alpha_us_by_log2m=(1, 1, 1, 1.5, 2, 2.5, 3, 4, 5, 6),
    beta_gbps=50.0,
)


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Per-node compute constants + the interconnect model."""

    name: str
    t_m: int                   # hardware threads per node (T_M)
    c_update_ns: float         # neuron state update, per neuron (LIF)
    c_update_iaf_ns: float     # ignore-and-fire update, per neuron
    c_syn_seq_ns: float        # delivery, per synapse, sequential (cached)
    c_syn_irr_ns: float        # delivery, per synapse, irregular (first touch)
    c_collocate_ns: float      # per emitted spike
    mpi: CollectiveModel = SUPERMUC_MPI
    # Relative per-process jitter of cycle times (body of Fig. 7b, CV ~ 0.04
    # after removing systematic process offsets) + serial correlation.
    cycle_cv: float = 0.028
    ar1_rho: float = 0.6
    minor_mode_weight: float = 0.02
    minor_mode_rel_shift: float = 0.185
    minor_mode_dwell: float = 5.0


# Calibration notes (SuperMUC-NG, T_M = 48): constants are *per-thread*
# nanoseconds; update and deliver parallelise over the T_M OpenMP threads,
# collocate runs on the master thread only (paper §2.4.3). With N_M = 130k,
# K_N = 6000, rate 2.5 Hz, dt 0.1 ms this puts the mean conventional cycle
# time at ~1.6 ms for M = 128 (Fig. 7b: 1.62 ms) with update ~ 0.5 ms and
# deliver ~ 1.0 ms, and reproduces RTF 9.4 -> 22.7 (conv) / 8.5 -> 15.7
# (struct) across M = 16..128 (Fig. 7a) to within ~15 %.
SUPERMUC = MachineModel(
    name="SuperMUC-NG",
    t_m=48,
    c_update_ns=300.0,
    c_update_iaf_ns=190.0,
    c_syn_seq_ns=55.0,
    c_syn_irr_ns=370.0,
    c_collocate_ns=900.0,
    mpi=SUPERMUC_MPI,
)
JURECA = MachineModel(
    name="JURECA-DC",
    t_m=128,
    c_update_ns=260.0,
    c_update_iaf_ns=170.0,
    c_syn_seq_ns=50.0,
    c_syn_irr_ns=330.0,
    c_collocate_ns=900.0,
    mpi=JURECA_MPI,
    # More cores absorb imbalance better (paper §2.4.3: V2's +68% spikes cost
    # +24% cycle time on SuperMUC-NG but only +7% on JURECA-DC).
    cycle_cv=0.022,
)


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """Per-process workload of a multi-area simulation (weak-scaling cell)."""

    n_m: int = 130_000        # neurons per process (mean area size)
    k_n: int = 6000           # synapses per neuron
    k_intra_frac: float = 0.5
    rate_hz: float = 2.5
    dt_ms: float = 0.1
    d: int = 10               # delay ratio D
    neuron_model: str = "iaf"  # 'iaf' (MAM-benchmark) or 'lif' (MAM)
    area_size_cv: float = 0.0  # Fig. 8a heterogeneity
    rate_cv: float = 0.0       # Fig. 8b heterogeneity
    bytes_per_spike: float = 4.0

    def spikes_per_proc_cycle(self) -> float:
        return self.n_m * self.rate_hz * self.dt_ms * 1e-3


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    """Real-time factors per phase (wall time / model time), Fig. 7a style."""

    update: float
    deliver: float
    collocate: float
    communicate: float  # pure data exchange
    synchronize: float

    @property
    def total(self) -> float:
        return (self.update + self.deliver + self.collocate
                + self.communicate + self.synchronize)

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self) | {"total": self.total}


def _phase_means(
    wl: WorkloadModel, hw: MachineModel, m: int, schedule: str
) -> tuple[float, float, float]:
    """Expected per-cycle (update, deliver, collocate) seconds per process."""
    c_upd = hw.c_update_iaf_ns if wl.neuron_model == "iaf" else hw.c_update_ns
    # Update parallelises over the T_M threads.
    t_update = wl.n_m * c_upd * 1e-9 / hw.t_m

    # Delivery: per process and cycle, the emitted spikes network-wide fan out
    # to K_N synapses each; 1/M of those synapse events land locally, i.e.
    # exactly spikes_per_proc_cycle * K_N events. The per-event cost blends
    # sequential and irregular access with the §2.3 fractions; threads share
    # the work.
    n = wl.n_m * m
    syn_events = wl.spikes_per_proc_cycle() * wl.k_n
    if schedule == "conventional":
        f_irr = delivery_model.f_irr_conventional(n, wl.k_n, m, hw.t_m)
    else:
        f_irr = delivery_model.f_irr_structure_aware(
            n, wl.k_n, m, hw.t_m,
            k_intra=wl.k_n * wl.k_intra_frac,
            k_inter=wl.k_n * (1 - wl.k_intra_frac),
        )
    per_syn = (f_irr * hw.c_syn_irr_ns + (1 - f_irr) * hw.c_syn_seq_ns) * 1e-9
    t_deliver = syn_events * per_syn / hw.t_m

    # Collocation runs on the master thread only (paper §2.4.3).
    t_collocate = wl.spikes_per_proc_cycle() * hw.c_collocate_ns * 1e-9
    return t_update, t_deliver, t_collocate


def receive_time_s(syn_touches: float, hw: MachineModel) -> float:
    """Receive-side scatter seconds for ``syn_touches`` synapse-table
    touches (per device, per window).

    The event receive path's work is ids_scattered x receive-table width --
    the counter :func:`repro.core.exchange.inter_table_report` reports for
    the replicated vs sharded table layouts (the sharded layout divides the
    width by ~the shard count, the NEST every-rank-scans-everything fix of
    arXiv:2109.11358). Each touch is one sequential table read + ring
    accumulate, priced at the cache-model's sequential per-synapse cost and
    parallelised over the ``T_M`` threads -- the same constants the deliver
    phase of :func:`simulate_rtf` uses, so before/after receive times are
    comparable with the phase breakdowns.
    """
    return syn_touches * hw.c_syn_seq_ns * 1e-9 / hw.t_m


def exchange_time_s(
    counts_bytes: float,
    payload_bytes: float,
    m: int,
    mpi: CollectiveModel = SUPERMUC_MPI,
) -> float:
    """Wall seconds of one adaptive two-phase exchange.

    Two dependent collective calls: phase 1 moves the tiny count packet
    (latency-dominated -- ``alpha(M)`` plus a few int32 words), phase 2 the
    right-sized payload. The two phases cannot overlap (the payload size is
    a function of the counts), so the times add: the adaptive exchange buys
    its byte savings at the price of one extra ``alpha(M)`` dispatch per
    window -- worth it exactly when ``saved_bytes / beta > alpha(M)``,
    which at brain-scale static bounds (8x-expectation headroom) it is (cf.
    Du et al. 2022: count-first exchanges amortize at scale). Byte inputs
    come from ``exchange.adaptive_wire_bytes`` (modelled) or
    ``SimState.shipped_bytes`` (measured); pass ``counts_bytes=0`` to price
    the static single-phase exchange with the same constants.
    """
    t = mpi.call_time_s(m, payload_bytes)
    if counts_bytes > 0:
        t += mpi.call_time_s(m, counts_bytes)
    return t


def simulate_rtf(
    wl: WorkloadModel,
    hw: MachineModel,
    m: int,
    schedule: str,
    *,
    t_model_s: float = 1.0,
    seed: int = 0,
    bytes_per_window: float | None = None,
) -> PhaseBreakdown:
    """Monte-Carlo the full schedule and return per-phase real-time factors.

    Mirrors the paper's instrumentation: per-phase times are averaged over
    processes; synchronization is the mean waiting time at the barrier before
    the collective; communicate is the pure data exchange (Fig. 1b).

    ``bytes_per_window`` overrides the analytic spike-buffer estimate with a
    measured mesh-total wire volume -- the static counters the exchange
    layer reports (``repro.core.exchange``, ``Engine.wire_bytes``), so the
    model can price the dense vs connectivity-routed global pathway from
    the same numbers the engines ship.
    """
    rng = np.random.default_rng(seed)
    s = int(round(t_model_s / (wl.dt_ms * 1e-3)))
    d = wl.d if schedule == "structure_aware" else 1
    s -= s % max(wl.d, 1)

    t_upd, t_dlv, t_col = _phase_means(wl, hw, m, schedule)
    mu = t_upd + t_dlv + t_col

    # Systematic per-process offsets from heterogeneity: area size scales all
    # compute phases; rate scales delivery/collocation only.
    size_f = np.maximum(1 + wl.area_size_cv * rng.standard_normal(m), 0.1)
    rate_f = np.maximum(1 + wl.rate_cv * rng.standard_normal(m), 0.1)
    proc_mu = t_upd * size_f + t_dlv * size_f * rate_f + t_col * rate_f

    model = sync_model.CycleTimeModel(
        mu=1.0,  # placeholder; we inject proc_mu directly below
        sigma=hw.cycle_cv,
        rho=hw.ar1_rho,
        minor_mode_shift=hw.minor_mode_rel_shift,
        minor_mode_weight=hw.minor_mode_weight,
        minor_mode_dwell=hw.minor_mode_dwell,
    )
    # Relative jitter matrix around 1.0 (shared across schedules comparisons
    # when the same seed is used -- common random numbers).
    jitter = model.sample(m, s, rng) / 1.0
    cycle_t = proc_mu[:, None] * jitter  # [M, S]

    # Lump into communication windows of length d.
    lumped = cycle_t.reshape(m, s // d, d).sum(axis=2)  # [M, S/d]
    wall_compute_wait = lumped.max(axis=0).sum()
    mean_compute = cycle_t.sum(axis=1).mean()
    t_sync = wall_compute_wait - mean_compute

    # Data exchange: spikes from d cycles, all processes' buffers -- unless
    # the caller supplies the exchange layer's measured wire volume.
    if bytes_per_window is None:
        spikes_per_window = wl.spikes_per_proc_cycle() * d
        bytes_per_window = spikes_per_window * wl.bytes_per_spike * m
    n_windows = s // d
    t_comm = n_windows * hw.mpi.call_time_s(m, bytes_per_window)
    # The structure-aware local exchange is a buffer swap -- negligible, but
    # modelled as one dispatch per cycle on the local tier.
    if schedule == "structure_aware":
        t_comm += (s - n_windows) * 0.2e-6

    return PhaseBreakdown(
        update=float(t_upd * s / t_model_s) * float(np.mean(size_f)),
        deliver=float(t_dlv * s / t_model_s) * float(np.mean(size_f * rate_f)),
        collocate=float(t_col * s / t_model_s),
        communicate=float(t_comm / t_model_s),
        synchronize=float(t_sync / t_model_s),
    )
