"""Backend-selectable spike delivery: the shared per-cycle hot path.

The paper identifies the *deliver* phase as the dominant per-cycle compute
cost and its irregular memory access as the thing a cache-aware rewrite must
fix (§2.3, §3). Both engines (``engine.py`` single-host, ``dist_engine.py``
sharded) route their intra-/inter-area delivery through this module, selected
by ``EngineConfig.delivery_backend``:

* ``"onehot"``  -- gather + one-hot-einsum deposit. Reference semantics; the
  per-cycle ``[N, K, R]`` one-hot is a dense MXU contraction but materialises
  the full ring axis for every synapse.
* ``"scatter"`` -- gather + ``.at[].add`` deposit. No ``[N, K, R]`` tensor.
  NOTE the measured CPU crossover vs ``onehot`` (BENCH_delivery.json):
  XLA lowers the scatter-add to a *serial* while-loop over all N*K updates
  (~50 ns/synapse on the reference container -- confirmed in compiled HLO),
  whereas the one-hot einsum does R x more multiplies fully vectorised. At
  the quickstart shape (K=64, R=110) the dense einsum therefore wins
  (~1.5x); at MAM-like small K (K=6) the scatter wins (~1.3x). The deposit
  uses flattened single-column indices (see
  :func:`repro.core.ring_buffer.deposit_scatter`), the fastest scatter
  layout measured; on TPU the same op maps to the native scatter unit and
  the crossover moves -- re-measure there before switching defaults.
* ``"pallas"``  -- the tiled, *delay-resolved* kernel
  (:func:`repro.kernels.ops.spike_deliver`): contributions are reduced over K
  once per slot of the per-pathway delay window ``[steps_lo, steps_lo +
  r_span)`` carried on :class:`~repro.core.connectivity.Network`, then rolled
  into the ring with :func:`~repro.kernels.ops.apply_contrib`. The narrow
  windows are exactly what the paper's short/long pathway split (§4.1.2)
  buys.
* ``"event"``   -- compact the fired neurons and scatter their *outgoing*
  synapses (:func:`~repro.kernels.ops.event_deliver`). At brain-scale rates
  (~0.025 % of neurons fire per 0.1 ms cycle) this replaces the dense
  O(N * K) sweep with an O(s_max * K_out) scatter. Requires
  ``build_network(outgoing=True)``.

All four are bit-identical on the reference network: delivery weights live on
the exact 1/256 grid, so f32 ring accumulation is associative-exact and
neither scatter order nor slot-reduction order can change a ULP.

:func:`compact_fired` implements the wire format of the distributed event
path: fired neurons are compacted into fixed-size id packets *before* the
exchange (NEST's spike-id wire format, the one the paper contrasts with
dense vectors). The receive side scatters the ids through each device's
inter receive tables straight into its ring shard
(``ops.event_deliver_ids`` with a global->local ``tgt_map``); since the
sharded-table refactor those are the per-shard *inbound* slices of
``connectivity.shard_inter_tables`` -- each device scatters only the edges
it owns. ``s_max`` caps the packet; the engines surface the spill in
``SimState.overflow``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ring_buffer
from repro.core.connectivity import Network
from repro.kernels import ops as kops

__all__ = [
    "BACKENDS",
    "expected_area_spikes",
    "event_bounds",
    "bucket_ladder",
    "expected_bucket",
    "deliver_intra",
    "deliver_inter",
    "deliver_inter_block",
    "compact_fired",
    "compact_fired_block",
]

BACKENDS = ("onehot", "scatter", "pallas", "event")

# deliver_inter_block folds the window's cycle axis into the synapse axis;
# for the one-hot backend that materialises an [N, D*K, R] tensor. Above
# this element count (1 GiB f32) the blocked call deposits per cycle
# instead -- production-scale MAM shards would otherwise need ~190 GiB of
# temp per device (measured by launch/dryrun, see EXPERIMENTS.md).
ONEHOT_FOLD_LIMIT = 2**28


def expected_area_spikes(net: Network) -> float:
    """Expected spikes per (padded) area per cycle -- the packet-sizing rule.

    Uses the per-area target rate, which for ignore-and-fire is the exact
    emission rate; ShapeDtypeStruct stand-ins (dry-run lowering) carry no
    rate data and fall back to the 2.5 Hz MAM ground state. Single source of
    truth for :func:`event_bounds` and the routed exchange's per-edge bounds
    (``repro.core.exchange``), so the wire accounting always prices the
    bounds the engines actually ship.
    """
    mean_rate = (
        float(jnp.asarray(net.rate_hz).mean())
        if hasattr(net.rate_hz, "mean") else 2.5
    )
    return net.alive.shape[1] * mean_rate * net.dt_ms * 1e-3


def event_bounds(
    net: Network, *, headroom: float, floor: int, burst_factor: int = 1
) -> tuple[int, int]:
    """Static event-buffer bounds ``(s_max_area, s_max_all)``.

    ``s_max = headroom x expected spikes/cycle + floor`` (cf. NEST's dynamic
    spike-register resizing; sizing is static here, the engines surface
    overruns via ``SimState.overflow``). The expectation is
    :func:`expected_area_spikes`. The event path's cost is s_max-bound, so
    ``floor`` is the knob that trades burst tolerance against wasted
    scatter width.

    ``burst_factor`` multiplies only the whole-network bound's constant
    burst slack (the ``4 x floor`` term). The proportional part of
    ``s_max_all`` scales with the area count, but the slack does not -- so
    a network holding ``B`` independent copies (``launch.serve``'s folded
    trial batch) would run strictly tighter per-copy headroom than its
    ``B`` sequential references. Passing ``burst_factor=B`` restores
    parity without touching the per-area bound (widening that instead
    costs ~``B x`` scatter width in *every* area).
    """
    a = net.alive.shape[0]
    exp_area = expected_area_spikes(net)
    s_max_area = int(headroom * exp_area) + max(floor, 1)
    s_max_all = (int(headroom * exp_area * a)
                 + 4 * max(floor, 1) * max(int(burst_factor), 1))
    return s_max_area, s_max_all


def bucket_ladder(floor: int, cap: int) -> tuple[int, ...]:
    """The adaptive exchange's pre-compiled packet-size ladder.

    Powers-of-two rungs ``floor, 2*floor, 4*floor, ...`` topped by ``cap``
    exactly -- ``cap`` is the *hard* population bound (every neuron in scope
    fires once per cycle; refractoriness forbids more), so a packet sized by
    the top rung can never drop a spike. The adaptive two-phase exchange
    compiles one branch per rung (:func:`repro.kernels.ops.ladder_switch`)
    and phase-1 counts choose the smallest rung that covers the window
    (:func:`repro.kernels.ops.bucket_index`) -- quiet windows ship
    ``floor``-sized packets, the worst case ships ``cap``, and
    ``SimState.overflow`` is provably zero in between. Contrast
    :func:`event_bounds`, the *static* sizing rule the adaptive mode
    replaces: its headroom-scaled expectation can sit below a burst, which
    is exactly the overflow failure mode (cf. NEST's dynamic spike-register
    resizing, arXiv:2109.11358).
    """
    floor = max(int(floor), 1)
    cap = max(int(cap), floor)
    rungs = []
    b = floor
    while b < cap:
        rungs.append(b)
        b *= 2
    rungs.append(cap)
    return tuple(rungs)


def expected_bucket(ladder: tuple[int, ...], expected_count: float) -> int:
    """The rung a typical window lands on: smallest rung >= the expectation.

    The *modelled* counterpart of the runtime bucket choice, used by the
    static wire accounting (``exchange.adaptive_wire_bytes``) to price the
    payload bytes of an expectation-sized window without running devices --
    actual runs report measured bytes in ``SimState.shipped_bytes``.
    """
    need = int(-(-expected_count // 1)) if expected_count > 0 else 1
    for b in ladder:
        if b >= need:
            return b
    return ladder[-1]


def _deposit(ring, vals, delays, t, *, onehot: bool):
    a, n, r = ring.shape
    k = vals.shape[-1]
    fn = ring_buffer.deposit if onehot else ring_buffer.deposit_scatter
    out = fn(ring.reshape(a * n, r), vals.reshape(a * n, k),
             delays.reshape(a * n, k), t)
    return out.reshape(a, n, r)


def deliver_intra(
    ring: jax.Array,         # [A, n, R] target rows (may be a device-local view)
    area_spikes: jax.Array,  # [A, n_src] f32 complete per-area spike vectors
    net: Network,            # tables with matching row view: src_intra [A, n, K]
    t: jax.Array,
    *,
    backend: str,
    s_max: int | None = None,
) -> jax.Array:
    """One cycle of intra-area (short-range pathway) delivery."""
    a, n, r = ring.shape
    if net.src_intra.shape[-1] == 0:
        return ring
    if backend == "event":
        # Single-host layout only (ring covers the full area); the sharded
        # event path compacts before the exchange -- see the engines.
        return jax.vmap(
            lambda rg, sp, tg, w, d: kops.event_deliver(
                rg, sp > 0, tg, w, d, t, s_max=s_max)
        )(ring, area_spikes, net.tgt_intra, net.wout_intra, net.dout_intra)
    if backend == "pallas":
        k = net.src_intra.shape[-1]
        n_src = area_spikes.shape[-1]
        # Lift per-area source indices into one flat id space so the whole
        # network is a single kernel launch (grid over [A * n] row tiles).
        offs = jnp.arange(a, dtype=jnp.int32) * n_src
        src_g = (net.src_intra + offs[:, None, None]).reshape(a * n, k)
        contrib = kops.spike_deliver(
            area_spikes.reshape(-1), src_g,
            net.w_intra.reshape(a * n, k), net.delay_intra.reshape(a * n, k),
            steps_lo=net.steps_lo_intra, r_span=net.r_span_intra,
        )
        flat = kops.apply_contrib(
            ring.reshape(a * n, r), contrib, t, net.steps_lo_intra)
        return flat.reshape(a, n, r)
    vals = net.w_intra * jax.vmap(lambda s, i: s[i])(area_spikes, net.src_intra)
    return _deposit(ring, vals, net.delay_intra, t,
                    onehot=(backend == "onehot"))


def deliver_inter(
    ring: jax.Array,         # [A, n, R] target rows (may be a device-local view)
    flat_spikes: jax.Array,  # [N_global] f32 global spike vector for one cycle
    net: Network,            # src_inter [A, n, K] holding *global* source ids
    t: jax.Array,
    *,
    backend: str,
    s_max: int | None = None,
) -> jax.Array:
    """One cycle of inter-area (long-range pathway) delivery."""
    a, n, r = ring.shape
    k = net.src_inter.shape[-1]
    if k == 0:
        return ring
    if backend == "event":
        k_out = net.tgt_inter.shape[-1]
        flat = kops.event_deliver(
            ring.reshape(a * n, r),
            flat_spikes > 0,
            net.tgt_inter.reshape(a * n, k_out),
            net.wout_inter.reshape(a * n, k_out),
            net.dout_inter.reshape(a * n, k_out),
            t, s_max=s_max,
        )
        return flat.reshape(a, n, r)
    if backend == "pallas":
        contrib = kops.spike_deliver(
            flat_spikes, net.src_inter.reshape(a * n, k),
            net.w_inter.reshape(a * n, k), net.delay_inter.reshape(a * n, k),
            steps_lo=net.steps_lo_inter, r_span=net.r_span_inter,
        )
        flat = kops.apply_contrib(
            ring.reshape(a * n, r), contrib, t, net.steps_lo_inter)
        return flat.reshape(a, n, r)
    vals = net.w_inter * flat_spikes[net.src_inter]
    return _deposit(ring, vals, net.delay_inter, t,
                    onehot=(backend == "onehot"))


def deliver_inter_block(
    ring: jax.Array,     # [A, n, R] target rows (may be a device-local view)
    block: jax.Array,    # [D, N_global] f32 global spike vectors, one per cycle
    net: Network,        # src_inter [A, n, K] holding *global* source ids
    t0: jax.Array,       # window start (cycle s of the block was emitted at t0+s)
    *,
    backend: str,
    s_max: int | None = None,
) -> jax.Array:
    """One lumped window of inter-area delivery in a **single pass**.

    The structure-aware schedule's window-end exchange used to replay
    ``deliver_inter`` D times in a sequential ``fori_loop``; this entry point
    delivers the whole ``[D, N]`` spike block at once. Per backend:

    * ``event``  -- compact each cycle of the block into an id packet
      (``compact_fired_block``: an ``(id, step)`` packet of bound
      ``D * s_max``) and scatter all of them through the outgoing tables in
      one :func:`repro.kernels.ops.event_deliver_block` pass.
    * ``pallas`` -- D delay-resolved kernel launches whose ``[N, r_span]``
      contributions are shift-summed into one ``[N, D-1+r_span]`` window,
      rolled into the ring with a single ``apply_contrib``.
    * ``onehot``/``scatter`` -- fold the window's cycle axis into the synapse
      axis (``[N, D*K]`` values with delays offset by the cycle index) and
      deposit once.

    Cycle ``s`` of the block behaves exactly like ``deliver_inter(..., t0+s)``;
    a window of per-cycle calls and one blocked call are bit-identical
    (1/256-grid weights make deposit order irrelevant).
    """
    a, n, r = ring.shape
    k = net.src_inter.shape[-1]
    d_win = block.shape[0]
    if k == 0:
        return ring
    if backend == "event":
        k_out = net.tgt_inter.shape[-1]
        n_src = a * n
        # Positions ARE global ids on the complete network view, so the
        # compaction reduces to a sized nonzero per cycle.
        fired = jax.vmap(
            lambda sp: kops.sized_nonzero(sp > 0, size=s_max, fill=n_src)
        )(block)                                           # [D, s_max]
        flat = kops.event_deliver_block(
            ring.reshape(a * n, r), fired,
            net.tgt_inter.reshape(a * n, k_out),
            net.wout_inter.reshape(a * n, k_out),
            net.dout_inter.reshape(a * n, k_out),
            t0,
        )
        return flat.reshape(a, n, r)
    if backend == "pallas":
        span = net.r_span_inter
        wide = None
        for s in range(d_win):
            contrib = kops.spike_deliver(
                block[s], net.src_inter.reshape(a * n, k),
                net.w_inter.reshape(a * n, k),
                net.delay_inter.reshape(a * n, k),
                steps_lo=net.steps_lo_inter, r_span=span,
            )
            shifted = jnp.pad(contrib, ((0, 0), (s, d_win - 1 - s)))
            wide = shifted if wide is None else wide + shifted
        flat = kops.apply_contrib(
            ring.reshape(a * n, r), wide, t0, net.steps_lo_inter)
        return flat.reshape(a, n, r)
    # Dense deposits: cycle s with delay d targets slot (t0 + s + d) % R, so
    # folding s into the delay turns the window into one [N, D*K] deposit.
    # The one-hot deposit materialises [N, D*K, R]; beyond ~2^28 elements
    # (1 GiB f32 -- production-scale MAM shards hit ~50G) that folding
    # trades a catastrophic temp blow-up for a op-count win, so fall back
    # to per-cycle deposits inside the block. Static shapes, static choice,
    # bit-identical either way (1/256-grid exactness).
    if backend == "onehot" and a * n * d_win * k * r > ONEHOT_FOLD_LIMIT:
        for s in range(d_win):
            vals = net.w_inter * block[s][net.src_inter]
            ring = _deposit(ring, vals, net.delay_inter, t0 + s, onehot=True)
        return ring
    vals = net.w_inter[None] * block[:, net.src_inter]     # [D, A, n, K]
    delays = net.delay_inter[None] + jnp.arange(
        d_win, dtype=jnp.int32)[:, None, None, None]       # [D, A, n, K]
    vals = jnp.moveaxis(vals, 0, 2).reshape(a, n, d_win * k)
    delays = jnp.moveaxis(delays, 0, 2).reshape(a, n, d_win * k)
    return _deposit(ring, vals, delays, t0, onehot=(backend == "onehot"))


# ---------------------------------------------------------------------------
# Sparse id packets: the distributed event path's wire format.
# ---------------------------------------------------------------------------


def compact_fired(
    fired: jax.Array,   # [...] bool
    ids: jax.Array,     # [...] int32 payload per neuron (e.g. global ids)
    *,
    s_max: int,
    invalid: int,
) -> tuple[jax.Array, jax.Array]:
    """Compact fired neurons into a fixed-size id packet.

    Returns ``(packet [s_max] int32, count scalar int32)``. The packet holds
    ``ids`` of the first ``s_max`` fired neurons, padded with ``invalid``
    (choose it >= the receiving table's row count so
    :func:`repro.kernels.ops.event_deliver_ids` absorbs it). ``count`` is the
    *true* number of fired neurons; ``count > s_max`` means the packet
    dropped spikes -- the engines accumulate that spill into
    ``SimState.overflow`` instead of failing silently.

    The ``D == 1`` special case of :func:`repro.kernels.ops
    .compact_ids_block` -- one compaction primitive serves every packet
    (local pathway, lumped window, routed edges).
    """
    packet, count = kops.compact_ids_block(
        fired.reshape(1, -1), ids.reshape(1, -1),
        size=s_max, fill_id=invalid)
    return packet[0], count[0]


def compact_fired_block(
    fired: jax.Array,   # [D, ...] bool -- one window of spike rasters
    ids: jax.Array,     # [...] int32 payload per neuron (e.g. global ids)
    *,
    s_max: int,
    invalid: int,
) -> tuple[jax.Array, jax.Array]:
    """Compact a whole window into one ``(id, step)`` packet.

    Returns ``(packets [D, s_max] int32, counts [D] int32)`` -- the blocked
    wire format of the lumped exchange: the step of each id is implicit in
    its row, and the bound is ``D * s_max``. Packing is per cycle (each row is
    :func:`compact_fired` of that cycle), so the spill accounting -- and,
    under overflow, the *dropped spikes themselves* -- are identical to D
    per-cycle packings; the engines accumulate ``max(counts - s_max, 0)``
    into ``SimState.overflow`` either way.
    """
    d_win = fired.shape[0]
    return kops.compact_ids_block(
        fired.reshape(d_win, -1), ids.reshape(-1),
        size=s_max, fill_id=invalid)
