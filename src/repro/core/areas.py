"""Multi-area network specifications.

The paper studies two models:

* **MAM** -- the multi-area model of macaque visual cortex (Schmidt et al. 2018):
  32 areas, heterogeneous sizes (CV ~= 0.2 around a mean of ~130k neurons),
  ~6000 synapses per neuron of which ~1800 are long-range (inter-area),
  integrate-and-fire dynamics, ground state at ~2.5 spikes/s.

* **MAM-benchmark** -- a deliberately homogeneous variant: equal area sizes,
  equal intra/inter in-degrees (K_intra = K_inter ~= 3000), *ignore-and-fire*
  neurons that spike at a fixed interval/phase independent of input, so the
  workload is constant under scaling.

Both are described here by :class:`MultiAreaSpec`, which carries everything the
connectivity builder, the engines, the partitioner and the analytic models need.
All delays are expressed on the simulation grid ``dt_ms`` (= the overall minimum
delay ``d_min`` of the paper). The delay ratio ``D = d_min_inter / d_min``
(paper eq. (1)) controls the structure-aware communication interval.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "AreaSpec",
    "MultiAreaSpec",
    "mam_benchmark_spec",
    "mam_spec",
    "ring_area_adjacency",
    "tile_spec",
    "MAM_AREA_NAMES",
]


# The 32 vision-related areas of macaque cortex used by the MAM
# (Schmidt, Bakker, Hilgetag, Diesmann & van Albada 2018).
MAM_AREA_NAMES: tuple[str, ...] = (
    "V1", "V2", "VP", "V3", "V3A", "MT", "V4t", "V4", "VOT", "MSTd",
    "PIP", "PO", "DP", "MIP", "MDP", "VIP", "LIP", "PITv", "PITd", "MSTl",
    "CITv", "CITd", "FEF", "TF", "AITv", "FST", "7a", "STPp", "STPa", "46",
    "AITd", "TH",
)


@dataclasses.dataclass(frozen=True)
class AreaSpec:
    """One cortical area.

    Attributes:
      name: area label (e.g. ``"V1"``).
      n_neurons: number of (live) neurons in the area.
      rate_hz: target/drive spike rate for the area's neurons. For the
        ignore-and-fire model this is the exact emission rate; for the LIF
        model it parameterises the external Poisson drive.
    """

    name: str
    n_neurons: int
    rate_hz: float = 2.5

    def __post_init__(self) -> None:
        if self.n_neurons <= 0:
            raise ValueError(f"area {self.name!r}: n_neurons must be > 0")
        if self.rate_hz < 0:
            raise ValueError(f"area {self.name!r}: rate_hz must be >= 0")


@dataclasses.dataclass(frozen=True)
class MultiAreaSpec:
    """Full multi-area network specification.

    Delay conventions (paper §2.1): the simulation step is ``dt_ms`` which
    equals the overall minimum delay ``d_min``. Intra-area delays live on
    ``[dt_ms, delay_intra_max_ms]``; inter-area delays are cut off below at
    ``d_min_inter_ms`` (the paper imposes the same cutoff on the MAM) and live
    on ``[d_min_inter_ms, delay_inter_max_ms]``. ``D`` is the integer ratio
    ``d_min_inter / d_min`` of eq. (1).
    """

    areas: tuple[AreaSpec, ...]
    # -- temporal structure -------------------------------------------------
    dt_ms: float = 0.1
    d_min_inter_ms: float = 1.0
    delay_intra_mean_ms: float = 1.25
    delay_intra_std_ms: float = 0.625
    delay_inter_mean_ms: float = 5.0
    delay_inter_std_ms: float = 2.5
    delay_intra_max_ms: float = 3.0
    delay_inter_max_ms: float = 10.0
    # -- connectivity -------------------------------------------------------
    k_intra: int = 3000
    k_inter: int = 3000
    # Optional area->area adjacency mask: ``area_adjacency[src][tgt]`` truthy
    # iff source area ``src`` is allowed to project into target area ``tgt``.
    # ``None`` means all-to-all (every other area), the MAM default. A sparse
    # mask restricts the inter-area source draws in ``build_network`` -- the
    # connectivity-routed global pathway (``core/exchange.RoutedExchange``)
    # then ships spike packets only along edges that exist. Stored as nested
    # tuples so the spec stays hashable/frozen; see
    # :func:`ring_area_adjacency` for a canonical sparse example.
    area_adjacency: tuple[tuple[int, ...], ...] | None = None
    exc_fraction: float = 0.8
    # Weights are drawn on a 1/256 grid (exactly representable in f32) so that
    # ring-buffer accumulation is associative-exact and the conventional and
    # structure-aware schedules produce bit-identical spike trains. Units: pA
    # current impulses into an iaf_psc_exp with C_m = 250 pF (NEST defaults);
    # w_exc ~= 88 pA is the canonical 0.15 mV PSP.
    w_exc: float = 88.0
    g: float = 4.0  # inhibition dominance: w_inh = -g * w_exc
    # -- external drive (LIF only) -------------------------------------------
    ext_rate_hz: float = 2000.0  # rate of the external Poisson drive per neuron
    # Calibrated so the ground state sits at ~2.5 spikes/s (fluctuation-driven
    # regime just below threshold), matching the MAM ground state.
    w_ext: float = 282.0

    def __post_init__(self) -> None:
        if not self.areas:
            raise ValueError("MultiAreaSpec needs at least one area")
        if self.dt_ms <= 0:
            raise ValueError("dt_ms must be > 0")
        ratio = self.d_min_inter_ms / self.dt_ms
        if abs(ratio - round(ratio)) > 1e-9:
            raise ValueError(
                "d_min_inter_ms must be an integer multiple of dt_ms "
                f"(got ratio {ratio})"
            )
        if round(ratio) < 1:
            raise ValueError("d_min_inter_ms must be >= dt_ms")
        if self.delay_inter_max_ms < self.d_min_inter_ms:
            raise ValueError("delay_inter_max_ms must be >= d_min_inter_ms")
        if self.delay_intra_max_ms < self.dt_ms:
            raise ValueError("delay_intra_max_ms must be >= dt_ms")
        if self.k_intra < 0 or self.k_inter < 0:
            raise ValueError("in-degrees must be >= 0")
        if len(self.areas) == 1 and self.k_inter > 0:
            raise ValueError("single-area network cannot have inter-area synapses")
        if self.area_adjacency is not None:
            a = len(self.areas)
            adj = np.asarray(self.area_adjacency, dtype=bool)
            if adj.shape != (a, a):
                raise ValueError(
                    f"area_adjacency must be [{a}, {a}], got {adj.shape}"
                )
            if self.k_inter > 0:
                valid = adj & ~np.eye(a, dtype=bool)
                if not valid.any(axis=0).all():
                    raise ValueError(
                        "area_adjacency must give every target area at least "
                        "one non-self source area when k_inter > 0"
                    )

    # -- derived quantities ---------------------------------------------------

    @property
    def n_areas(self) -> int:
        return len(self.areas)

    @property
    def delay_ratio(self) -> int:
        """``D`` of paper eq. (1): d_min_inter / d_min."""
        return int(round(self.d_min_inter_ms / self.dt_ms))

    @property
    def n_total(self) -> int:
        """Total number of live neurons."""
        return sum(a.n_neurons for a in self.areas)

    @property
    def n_max_area(self) -> int:
        """Largest area size (before padding)."""
        return max(a.n_neurons for a in self.areas)

    def padded_area_size(self, multiple: int = 1) -> int:
        """Padded per-area neuron count ``N_max``.

        All areas are padded to the size of the largest area (the paper's
        'ghost neuron' construction, §4.1.1), rounded up to ``multiple`` so
        device sharding and VMEM tiling divide evenly.
        """
        n = self.n_max_area
        return ((n + multiple - 1) // multiple) * multiple

    @property
    def steps_intra_max(self) -> int:
        return int(round(self.delay_intra_max_ms / self.dt_ms))

    @property
    def steps_inter_min(self) -> int:
        return self.delay_ratio

    @property
    def steps_inter_max(self) -> int:
        return int(round(self.delay_inter_max_ms / self.dt_ms))

    @property
    def ring_len(self) -> int:
        """Ring-buffer length: one slot per step up to the maximum delay.

        A spike emitted at step ``t`` with delay ``d`` lands in slot
        ``(t + d) % ring_len``; the slot for step ``t`` is read (and cleared)
        at the start of step ``t``, so ``max_delay + 1`` slots suffice. The
        length is rounded up to a multiple of the delay ratio ``D`` so that
        window starts (``t0 ≡ 0 mod D``) always land on a slot-block boundary
        -- the engines' fused D-cycle superstep reads and clears one
        contiguous ``[.., D]`` block per window instead of one slot per cycle
        (see ``repro.core.ring_buffer.read_and_clear_block``).
        """
        base = max(self.steps_intra_max, self.steps_inter_max) + 1
        d = self.delay_ratio
        return ((base + d - 1) // d) * d

    @property
    def k_total(self) -> int:
        return self.k_intra + self.k_inter

    def area_sizes(self) -> np.ndarray:
        return np.asarray([a.n_neurons for a in self.areas], dtype=np.int32)

    def area_rates(self) -> np.ndarray:
        return np.asarray([a.rate_hz for a in self.areas], dtype=np.float32)

    def steps_for(self, t_model_ms: float) -> int:
        """Number of simulation cycles covering ``t_model_ms`` of model time."""
        s = t_model_ms / self.dt_ms
        if abs(s - round(s)) > 1e-9:
            raise ValueError("t_model_ms must be a multiple of dt_ms")
        return int(round(s))

    def adjacency_matrix(self) -> np.ndarray:
        """The [A, A] bool source->target adjacency this spec allows.

        ``None`` (the default) means all-to-all minus the diagonal; inter-area
        self-projections never exist (intra-area synapses are the separate
        short-range tier).
        """
        a = self.n_areas
        if self.area_adjacency is None:
            adj = ~np.eye(a, dtype=bool)
        else:
            adj = np.asarray(self.area_adjacency, dtype=bool) & ~np.eye(
                a, dtype=bool)
        if self.k_inter == 0:
            adj = np.zeros((a, a), dtype=bool)
        return adj


def ring_area_adjacency(
    n_areas: int, width: int = 1
) -> tuple[tuple[int, ...], ...]:
    """A deliberately sparse area graph: a directed ring of degree ``width``.

    ``adj[src][tgt]`` is 1 iff ``(tgt - src) mod A`` is in ``[1, width]`` --
    each area projects only to its next ``width`` neighbours, so a
    connectivity-routed exchange genuinely skips most group->group edges
    (the all-to-all MAM default makes every edge exist). Used by the
    exchange equivalence/wire-volume suites.
    """
    if not 1 <= width < n_areas:
        raise ValueError(f"width must be in [1, {n_areas - 1}]")
    return tuple(
        tuple(1 if ((t - s) % n_areas) in range(1, width + 1) else 0
              for t in range(n_areas))
        for s in range(n_areas)
    )


def mam_benchmark_spec(
    n_areas: int = 4,
    n_per_area: int = 200,
    k_intra: int = 16,
    k_inter: int = 16,
    rate_hz: float = 2.5,
    *,
    dt_ms: float = 0.1,
    d_min_inter_ms: float = 1.0,
    area_size_cv: float = 0.0,
    rate_cv: float = 0.0,
    seed: int = 12,
    area_adjacency: tuple[tuple[int, ...], ...] | None = None,
) -> MultiAreaSpec:
    """The homogeneous MAM-benchmark (paper §4.2), arbitrarily scalable.

    The paper's production setting is ``n_areas = M``, ``n_per_area ~= 130_000``,
    ``k_intra = k_inter ~= 3000``; the defaults here are laptop-scale and are
    overridden by configs/benchmarks. ``area_size_cv`` and ``rate_cv`` enable
    the controlled heterogeneity sweeps of Fig. 8: sizes/rates are drawn from
    normal distributions with fixed means (as in the paper).
    """
    rng = np.random.default_rng(seed)
    sizes = np.full(n_areas, n_per_area, dtype=np.int64)
    if area_size_cv > 0:
        draw = rng.normal(n_per_area, area_size_cv * n_per_area, size=n_areas)
        sizes = np.maximum(8, np.round(draw)).astype(np.int64)
    rates = np.full(n_areas, rate_hz, dtype=np.float64)
    if rate_cv > 0:
        draw = rng.normal(rate_hz, rate_cv * rate_hz, size=n_areas)
        rates = np.maximum(0.1, draw)
    areas = tuple(
        AreaSpec(name=f"A{i:02d}", n_neurons=int(sizes[i]), rate_hz=float(rates[i]))
        for i in range(n_areas)
    )
    # Benchmark delay statistics from the paper: intra ~ N(1.25, 0.625) ms,
    # inter ~ N(5, 2.5) ms, cut off below at dt and d_min_inter respectively.
    return MultiAreaSpec(
        areas=areas,
        dt_ms=dt_ms,
        d_min_inter_ms=d_min_inter_ms,
        k_intra=k_intra if n_areas > 1 else k_intra + k_inter,
        k_inter=k_inter if n_areas > 1 else 0,
        area_adjacency=area_adjacency,
    )


# Relative area sizes for the 32-area MAM. Derived from the published model's
# property that neuron densities vary across areas with CV ~= 0.2 around a mean
# of ~130k per 1 mm^2 patch; V1 is the largest area. The exact per-area neuron
# counts of Schmidt et al. (2018) require the experimental datasets which are
# not redistributable here; these deterministic relative sizes reproduce the
# published mean/CV/rank structure used by the performance study.
_MAM_REL_SIZES: tuple[float, ...] = (
    1.53, 1.48, 1.13, 1.11, 0.93, 0.88, 1.04, 1.24, 0.96, 0.85,
    0.95, 0.89, 0.98, 0.82, 0.80, 0.92, 1.01, 1.02, 0.97, 0.83,
    0.94, 0.96, 1.07, 1.18, 0.91, 0.86, 1.09, 1.12, 0.87, 1.15,
    0.90, 0.79,
)

# Per-area ground-state firing rates (spikes/s). The MAM ground state has a
# network mean of ~2.5 Hz with V2 ~68% above the mean (paper §2.4.3).
_MAM_REL_RATES: tuple[float, ...] = (
    1.10, 1.68, 1.05, 0.95, 0.90, 1.22, 0.86, 1.15, 0.82, 0.95,
    0.88, 0.78, 1.02, 0.72, 0.70, 1.08, 1.18, 0.92, 0.90, 0.85,
    0.96, 0.98, 1.25, 0.88, 0.80, 0.84, 1.12, 1.06, 0.78, 1.30,
    0.82, 0.68,
)


def mam_spec(
    *,
    scale: float = 1.0,
    mean_area_size: int = 130_000,
    mean_rate_hz: float = 2.5,
    k_intra: int = 4200,
    k_inter: int = 1800,
    d_min_inter_ms: float = 1.0,
    size_multiple: int = 8,
) -> MultiAreaSpec:
    """The 32-area multi-area model of macaque visual cortex (performance view).

    ``scale`` shrinks neuron counts and in-degrees together for laptop-scale
    validation (scale=1 is the production model: ~4.2M neurons, ~6000 synapses
    per neuron of which ~1800 are inter-area).
    """
    if not (0 < scale <= 1.0):
        raise ValueError("scale must be in (0, 1]")
    sizes = [
        max(size_multiple, int(round(r * mean_area_size * scale)))
        for r in _MAM_REL_SIZES
    ]
    rates = [mean_rate_hz * r for r in _MAM_REL_RATES]
    areas = tuple(
        AreaSpec(name=MAM_AREA_NAMES[i], n_neurons=sizes[i], rate_hz=rates[i])
        for i in range(32)
    )
    ki = max(1, int(round(k_intra * scale)))
    ke = max(1, int(round(k_inter * scale)))
    return MultiAreaSpec(
        areas=areas,
        d_min_inter_ms=d_min_inter_ms,
        k_intra=ki,
        k_inter=ke,
    )


def tile_spec(spec: MultiAreaSpec, copies: int) -> MultiAreaSpec:
    """``copies`` independent replicas of ``spec`` as one block-diagonal spec.

    The serving layer's *folded* trial batching (launch/serve.py) runs B
    independent trials as ONE super-network of ``B * A`` areas whose
    area-adjacency is block-diagonal -- no synapse ever crosses a copy
    boundary, so each block's trajectory is exactly the single-trial
    trajectory (same weights, same delays, same drive stream when each
    block is fed the single-trial gid table). Unlike a vmapped batch the
    folded network runs the *single-trial* code shape -- flat scatters, no
    batched-sort slow paths -- which is where its throughput comes from on
    hosts without a spare device axis.

    All temporal/connectivity parameters are shared (they are per-synapse
    rules, not per-network state); only ``areas`` and ``area_adjacency``
    grow.
    """
    if copies < 1:
        raise ValueError(f"copies must be >= 1, got {copies}")
    if copies == 1:
        return spec
    a = spec.n_areas
    base = spec.adjacency_matrix() if spec.k_inter > 0 else None
    if base is not None:
        big = np.zeros((copies * a, copies * a), dtype=bool)
        for b in range(copies):
            big[b * a:(b + 1) * a, b * a:(b + 1) * a] = base
        adjacency = tuple(tuple(int(x) for x in row) for row in big)
    else:
        adjacency = None
    return dataclasses.replace(
        spec, areas=spec.areas * copies, area_adjacency=adjacency)
