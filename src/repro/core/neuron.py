"""Neuron models: ``iaf_psc_exp`` LIF and the paper's *ignore-and-fire*.

Both models expose the same functional interface so the engines are
model-agnostic:

    state  = init(alive_shape)                      # pytree of arrays
    state', spikes = update(state, I_in, t, ...)    # one dt step

* ``iaf_psc_exp``: leaky integrate-and-fire with exponential post-synaptic
  currents, integrated with *exact propagators* (Rotter & Diesmann 1999;
  NEST's default discretisation). The external Poisson drive is folded in
  deterministically from ``(seed, t)`` so any two schedules of the same
  network see bit-identical drive.

* ``ignore_and_fire`` (paper §4.2): receives and emits spikes like an LIF but
  ignores input -- it fires on a fixed per-neuron interval/phase. Its update
  cost is independent of activity, which makes the MAM-benchmark workload
  constant under scaling.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LIFParams",
    "LIFState",
    "lif_init",
    "lif_update",
    "IafState",
    "iaf_interval",
    "ignore_and_fire_init",
    "ignore_and_fire_update",
    "poisson_drive",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LIFParams:
    """iaf_psc_exp parameters (NEST defaults unless noted) + precomputed
    propagators for step ``dt_ms``."""

    tau_m_ms: float = dataclasses.field(metadata=dict(static=True), default=10.0)
    tau_syn_ms: float = dataclasses.field(metadata=dict(static=True), default=0.5)
    c_m_pf: float = dataclasses.field(metadata=dict(static=True), default=250.0)
    t_ref_ms: float = dataclasses.field(metadata=dict(static=True), default=2.0)
    v_th_mv: float = dataclasses.field(metadata=dict(static=True), default=15.0)
    v_reset_mv: float = dataclasses.field(metadata=dict(static=True), default=0.0)
    dt_ms: float = dataclasses.field(metadata=dict(static=True), default=0.1)

    @property
    def p22(self) -> float:
        """V decay over one step: exp(-dt/tau_m)."""
        return float(np.exp(-self.dt_ms / self.tau_m_ms))

    @property
    def p11(self) -> float:
        """Synaptic-current decay: exp(-dt/tau_syn)."""
        return float(np.exp(-self.dt_ms / self.tau_syn_ms))

    @property
    def p21(self) -> float:
        """Exact current->voltage propagator over one step."""
        tm, ts, dt, cm = self.tau_m_ms, self.tau_syn_ms, self.dt_ms, self.c_m_pf
        if abs(tm - ts) < 1e-12:
            return float(dt / cm * np.exp(-dt / tm))
        return float(
            (tm * ts) / (cm * (tm - ts)) * (np.exp(-dt / tm) - np.exp(-dt / ts))
        )

    @property
    def t_ref_steps(self) -> int:
        return int(round(self.t_ref_ms / self.dt_ms))


class LIFState(NamedTuple):
    v: jax.Array        # membrane potential [...,]
    i_syn: jax.Array    # synaptic current  [...,]
    refrac: jax.Array   # remaining refractory steps, int32


def lif_init(shape: tuple[int, ...], dtype=jnp.float32) -> LIFState:
    return LIFState(
        v=jnp.zeros(shape, dtype),
        i_syn=jnp.zeros(shape, dtype),
        refrac=jnp.zeros(shape, jnp.int32),
    )


def _splitmix32(x: jax.Array) -> jax.Array:
    """A well-mixed 32-bit finaliser (splitmix/murmur3 family)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x + jnp.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * jnp.uint32(0x21F0AAAD)
    x = (x ^ (x >> 15)) * jnp.uint32(0x735A2D97)
    return x ^ (x >> 15)


def counter_uniform(seed, t: jax.Array, gids: jax.Array) -> jax.Array:
    """Shard-invariant uniform(0,1) as a pure function of (seed, t, gid).

    Counter-based: each neuron's draw depends only on its *global* id and the
    absolute step, so any partitioning of the network (round-robin,
    structure-aware, single device, 512 devices) sees bit-identical noise.

    ``seed`` may be a Python int (the classic engine-wide seed) or an array
    broadcastable against ``gids`` -- the serving layer's per-trial seeds
    ride through as a per-neuron uint32 leaf, and a broadcast scalar is
    bit-identical to the int path.
    """
    h = _splitmix32(
        _splitmix32(
            _splitmix32(jnp.asarray(seed, jnp.uint32)) + gids.astype(jnp.uint32)
        )
        + jnp.asarray(t, jnp.uint32)
    )
    return h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)


def poisson_drive(
    seed,
    t: jax.Array,
    gids: jax.Array,
    rate_hz: jax.Array,
    dt_ms: float,
    w_ext: float,
) -> jax.Array:
    """Deterministic external Poisson drive current for step ``t``.

    Each neuron receives a Bernoulli(dt * rate) impulse of weight ``w_ext``
    (``rate_hz`` is the *effective* drive rate, already summing the external
    in-degree). Keyed on (seed, t, gid) -- see :func:`counter_uniform` -- so
    conventional and structure-aware schedules, and any device sharding, see
    identical realisations.
    """
    p = rate_hz * (dt_ms * 1e-3)
    u = counter_uniform(seed, t, gids)
    return (u < p).astype(jnp.float32) * w_ext


def lif_update(
    state: LIFState,
    i_in: jax.Array,
    alive: jax.Array,
    params: LIFParams,
) -> tuple[LIFState, jax.Array]:
    """One exact-propagator step. ``i_in`` is this step's ring-buffer slot
    (synaptic impulses, incl. external drive). Returns (state', spikes bool)."""
    p11, p21, p22 = params.p11, params.p21, params.p22

    refractory = state.refrac > 0
    # Synaptic current integrates impulses regardless of refractoriness.
    i_new = state.i_syn * p11 + i_in
    v_prop = state.v * p22 + state.i_syn * p21
    v_new = jnp.where(refractory, params.v_reset_mv, v_prop)

    spikes = (v_new >= params.v_th_mv) & alive & ~refractory
    v_out = jnp.where(spikes, params.v_reset_mv, v_new)
    refrac_out = jnp.where(
        spikes,
        jnp.int32(params.t_ref_steps),
        jnp.maximum(state.refrac - 1, 0),
    )
    return LIFState(v=v_out, i_syn=i_new, refrac=refrac_out), spikes


class IafState(NamedTuple):
    countdown: jax.Array  # steps until next spike, int32 (<0: never fires)


def iaf_interval(rate_hz: jax.Array, dt_ms: float) -> jax.Array:
    """Per-neuron firing interval in steps (single source of truth).

    ``round(1 / (rate * dt))`` clamped to >= 1; rate 0 maps to a
    never-fires sentinel. Shared by init, update and the fused superstep
    kernel (kernels/cycle.py) so the emission rule cannot drift between the
    unfused and fused engines.
    """
    return jnp.where(
        rate_hz > 0,
        jnp.maximum(jnp.round(1000.0 / (rate_hz * dt_ms)).astype(jnp.int32), 1),
        jnp.int32(jnp.iinfo(jnp.int32).max // 2),
    )


def ignore_and_fire_init(
    alive: jax.Array,
    rate_hz: jax.Array,
    dt_ms: float,
    gids: jax.Array | None = None,
) -> IafState:
    """Per-neuron interval = round(1 / (rate * dt)); phase = gid % interval.

    Phases are spread deterministically by *global* neuron id so population
    activity is stationary (the paper's benchmark has constant aggregate rate)
    and any sharding reproduces the same spike trains.
    """
    interval = iaf_interval(rate_hz, dt_ms)
    if gids is None:
        gids = jnp.arange(alive.size, dtype=jnp.int32).reshape(alive.shape)
    phase = gids % interval
    countdown = jnp.where(alive, phase, jnp.int32(jnp.iinfo(jnp.int32).max // 2))
    return IafState(countdown=countdown)


def ignore_and_fire_update(
    state: IafState,
    i_in: jax.Array,
    alive: jax.Array,
    rate_hz: jax.Array,
    dt_ms: float,
) -> tuple[IafState, jax.Array]:
    """Fire when the countdown hits zero; input ``i_in`` is delivered (the
    delivery cost exists) but ignored by the dynamics, as in the paper."""
    del i_in  # received but ignored -- that's the point of ignore-and-fire
    spikes = (state.countdown == 0) & alive
    interval = iaf_interval(rate_hz, dt_ms)
    countdown = jnp.where(spikes, interval - 1, state.countdown - 1)
    return IafState(countdown=countdown), spikes
