"""Deterministic fault injection for preemption-tolerant simulation.

A brain-scale run is hours long on thousands of devices; the extreme form of
the paper's straggler problem is a *preempted or dead node*, and the
communication layer's own failure modes (overflow, transient I/O loss --
cf. Du et al., "A Low-latency Communication Design for Brain Simulations")
should be conditions to degrade through, not crash on. This module makes
those conditions reproducible on a laptop:

* **compute jitter** -- per-device, per-cycle compute times drawn from
  :class:`repro.core.sync_model.CycleTimeModel` (the paper's §2.2 generative
  model), lumped over the D-cycle window and *slept* for on the host: the
  run's wall clock becomes ``max`` over simulated devices, exactly the
  order-statistics regime the sync model predicts. Samples are keyed by
  ``(seed, window)`` so a resumed run sees the same straggler sequence as an
  uninterrupted one.
* **transient checkpoint-write failures** -- the first ``k`` saves raise
  ``OSError``, exercising :class:`repro.checkpoint.manager.AsyncWriter`'s
  bounded-retry/backoff path end to end.
* **simulated preemption** -- a SIGTERM-style :class:`Preempted` raised at a
  chosen window boundary; the windowed run loop
  (:func:`repro.core.schedule.run_windows`) writes a final checkpoint and
  re-raises, so kill-at-window-k / resume flows are a single flag.

Everything here is host-side and deterministic; nothing is traced into the
jitted window body.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import numpy as np

from repro.core import sync_model

__all__ = [
    "FaultConfig",
    "FaultInjector",
    "Preempted",
    "format_fault_specs",
    "parse_fault_specs",
    "predicted_window_comm_jitter_s",
    "predicted_window_jitter_s",
]


class Preempted(RuntimeError):
    """Simulated SIGTERM: raised at a window boundary by the fault harness.

    ``window`` is the 1-based count of completed windows (== the checkpoint
    step id written at that boundary, if checkpointing is on).
    """

    def __init__(self, window: int, checkpoint_path: str | None = None):
        self.window = window
        self.checkpoint_path = checkpoint_path
        where = f" (checkpoint: {checkpoint_path})" if checkpoint_path else ""
        super().__init__(
            f"simulated preemption after window {window}{where}")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Declarative fault plan, carried on ``EngineConfig.faults``.

    All zeros/negatives = that fault disabled; the default instance injects
    nothing. Jitter times are in milliseconds (the sync model's natural
    unit); ``jitter_devices=0`` means "use the real device count" (resolved
    by the injector).
    """

    # Per-device compute jitter (sync_model.CycleTimeModel body + AR(1)).
    jitter_mu_ms: float = 0.0
    jitter_sigma_ms: float = 0.0
    jitter_rho: float = 0.0
    jitter_devices: int = 0
    # Per-window *exchange* (communication) jitter: one N(mu, sigma^2) draw
    # per simulated device per window, maxed over devices. The overlapped
    # schedule hides this behind the next window's compute (wall tracks
    # max(compute, comm)); the sequential schedule pays the sum.
    comm_mu_ms: float = 0.0
    comm_sigma_ms: float = 0.0
    # Transient checkpoint-write failures: the first k saves raise OSError.
    ckpt_write_failures: int = 0
    # Simulated preemption after this many *completed* windows (1-based;
    # <= 0 disables). Counted in absolute windows (resume-aware): a run
    # resumed at window 10 with preempt_after_window=12 dies 2 windows in.
    preempt_after_window: int = 0
    seed: int = 0

    @property
    def jitter_enabled(self) -> bool:
        return self.jitter_mu_ms > 0 or self.jitter_sigma_ms > 0

    @property
    def comm_enabled(self) -> bool:
        return self.comm_mu_ms > 0 or self.comm_sigma_ms > 0

    @property
    def any_enabled(self) -> bool:
        return (self.jitter_enabled or self.comm_enabled
                or self.ckpt_write_failures > 0
                or self.preempt_after_window > 0)

    def cycle_time_model(self) -> sync_model.CycleTimeModel:
        return sync_model.CycleTimeModel(
            mu=self.jitter_mu_ms * 1e-3,
            sigma=self.jitter_sigma_ms * 1e-3,
            rho=self.jitter_rho,
        )


def predicted_window_jitter_s(
    model: sync_model.CycleTimeModel, n_devices: int, d: int
) -> float:
    """Analytic E[window straggler time]: paper eqs. (6)+(8) per window.

    Lumping D cycles turns per-device window time into N(D mu, D sigma^2);
    the expected maximum over M devices is ``D mu + sqrt(D) sigma xi_M``
    (Blom). :meth:`FaultInjector.window_jitter_s` draws from the same model,
    so measured window times under injected jitter must converge to this --
    the validation the resilience tests pin.
    """
    return d * model.mu + math.sqrt(d) * model.sigma * sync_model.blom_xi(
        n_devices)


def predicted_window_comm_jitter_s(
    comm_mu_s: float, comm_sigma_s: float, n_devices: int
) -> float:
    """Analytic E[window exchange straggler]: max over M of N(mu, sigma^2).

    The exchange happens once per window (not per cycle), so the lumping
    factor is 1; the expected maximum over the M participating devices is
    ``mu + sigma xi_M`` (Blom), same order-statistics form as the compute
    prediction.
    """
    return comm_mu_s + comm_sigma_s * sync_model.blom_xi(n_devices)


class FaultInjector:
    """Runtime arm of a :class:`FaultConfig` for one run (or one resume leg).

    Stateless across windows except the transient-write counter; jitter is a
    pure function of ``(seed, window)`` so interrupted and uninterrupted runs
    sleep through identical straggler sequences.
    """

    def __init__(self, cfg: FaultConfig, *, n_devices: int, delay_ratio: int):
        self.cfg = cfg
        self.n_devices = cfg.jitter_devices or n_devices
        self.delay_ratio = delay_ratio
        self.model = cfg.cycle_time_model()
        self.injected_sleep_s = 0.0
        self.windows_slept = 0
        self._ckpt_fails_left = cfg.ckpt_write_failures
        self.ckpt_failures_injected = 0

    # -- compute jitter ----------------------------------------------------

    def window_jitter_s(self, window: int) -> float:
        """Straggler time for one window: max over simulated devices of the
        D-cycle lumped draw from the cycle-time model."""
        if not self.cfg.jitter_enabled:
            return 0.0
        rng = np.random.default_rng((self.cfg.seed, int(window)))
        t = self.model.sample(self.n_devices, self.delay_ratio, rng)
        return float(t.sum(axis=1).max())

    def window_comm_jitter_s(self, window: int) -> float:
        """Exchange straggler time for one window: max over simulated devices
        of one N(comm_mu, comm_sigma^2) draw. Keyed by ``(seed, window)``
        with a salt so the comm draw is independent of the compute draw --
        both are pure functions of the window index, so interrupted,
        resumed, sequential and pipelined runs all see the *same* realized
        straggler sequence (what makes the max-vs-sum assertions exact)."""
        if not self.cfg.comm_enabled:
            return 0.0
        rng = np.random.default_rng((self.cfg.seed, int(window), 0x0C))
        t = (self.cfg.comm_mu_ms
             + self.cfg.comm_sigma_ms * rng.standard_normal(self.n_devices))
        return max(float(t.max()) * 1e-3, 0.0)

    def inject(self, seconds: float) -> float:
        """Sleep ``seconds`` on the host and account for it; returns it."""
        if seconds > 0:
            time.sleep(seconds)
            self.injected_sleep_s += seconds
            self.windows_slept += 1
        return seconds

    def sleep(self, window: int) -> float:
        """Inject the window's compute straggler time as a host sleep."""
        return self.inject(self.window_jitter_s(window))

    def predicted_jitter_s(self) -> float:
        """The sync model's per-window prediction for this injector's shape."""
        return predicted_window_jitter_s(
            self.model, self.n_devices, self.delay_ratio)

    def predicted_comm_s(self) -> float:
        """Per-window exchange-straggler prediction (0 when comm disabled)."""
        if not self.cfg.comm_enabled:
            return 0.0
        return predicted_window_comm_jitter_s(
            self.cfg.comm_mu_ms * 1e-3, self.cfg.comm_sigma_ms * 1e-3,
            self.n_devices)

    def predicted_sequential_s(self) -> float:
        """Per-window injected wall under the sequential schedule: the SUM of
        the compute and exchange straggler times (both on the critical
        path)."""
        return self.predicted_jitter_s() + self.predicted_comm_s()

    def predicted_overlap_s(self) -> float:
        """Per-window injected wall under the pipelined schedule: E[max] of
        the compute and exchange stragglers (Clark), the paper's
        max(compute, comm) claim in closed form. The straggler *spread*
        (std of the max over M devices) is approximated by the per-device
        sigma -- an upper bound that only matters when the two means are
        close."""
        m1 = self.predicted_jitter_s()
        s1 = math.sqrt(self.delay_ratio) * self.model.sigma
        m2 = self.predicted_comm_s()
        s2 = self.cfg.comm_sigma_ms * 1e-3
        return sync_model.expected_max_normals(m1, s1, m2, s2)

    # -- preemption --------------------------------------------------------

    def preempt_now(self, windows_done: int) -> bool:
        """True when the SIGTERM-style stop fires (after `windows_done`)."""
        return (self.cfg.preempt_after_window > 0
                and windows_done >= self.cfg.preempt_after_window)

    # -- transient checkpoint-write failures -------------------------------

    def wrap_save(self, save_fn: Callable[..., str]) -> Callable[..., str]:
        """A ``save_fn`` whose first k calls raise OSError, then delegate.

        Handed to ``AsyncWriter(save_fn=...)`` so the writer's bounded
        retry/backoff path runs against a deterministic failure budget.
        """

        def flaky_save(directory, step, tree, *, extra=None):
            if self._ckpt_fails_left > 0:
                self._ckpt_fails_left -= 1
                self.ckpt_failures_injected += 1
                raise OSError(
                    f"injected transient checkpoint-write failure "
                    f"({self.ckpt_failures_injected}"
                    f"/{self.cfg.ckpt_write_failures})")
            return save_fn(directory, step, tree, extra=extra)

        return flaky_save


def _pop_number(kv: dict, key: str, default, spec: str, conv):
    """Pop ``key`` from ``kv`` and convert with ``conv``, with context on a
    bad numeric literal (a raw ``float('x')`` error names neither the option
    nor the spec -- exactly the silent-misconfiguration trap this grammar
    exists to close)."""
    raw = kv.pop(key, None)
    if raw is None:
        return default
    try:
        return conv(raw)
    except ValueError:
        raise ValueError(
            f"bad value {raw!r} for option {key!r} in fault spec {spec!r} "
            f"(expected {conv.__name__})") from None


def parse_fault_specs(specs: list[str] | None, *, seed: int = 0) -> FaultConfig:
    """Parse ``--inject-fault`` CLI specs into one :class:`FaultConfig`.

    Grammar (repeatable, later specs merge over earlier ones)::

        jitter:mu_ms=1.6,sigma_ms=0.3[,comm_mu_ms=..][,comm_sigma_ms=..]
              [,rho=0.5][,devices=8]
        ckpt-io:fails=2
        preempt:window=12

    Round-trips with :func:`format_fault_specs`; every malformed input --
    unknown kind, unknown or missing option, bad numeric literal, or a
    ``jitter:`` spec that sets nothing -- raises ``ValueError`` naming the
    offending spec.
    """
    cfg = FaultConfig(seed=seed)
    for spec in specs or ():
        kind, _, body = spec.partition(":")
        kv = {}
        for part in filter(None, body.split(",")):
            k, eq, v = part.partition("=")
            if not eq:
                raise ValueError(f"bad fault option {part!r} in {spec!r}")
            kv[k] = v
        try:
            if kind == "jitter":
                if not kv:
                    raise ValueError(
                        f"fault spec {spec!r} sets no options (a bare "
                        f"'jitter' would silently disable the harness); "
                        f"expected e.g. jitter:mu_ms=1.6,sigma_ms=0.3")
                cfg = dataclasses.replace(
                    cfg,
                    jitter_mu_ms=_pop_number(
                        kv, "mu_ms", cfg.jitter_mu_ms, spec, float),
                    jitter_sigma_ms=_pop_number(
                        kv, "sigma_ms", cfg.jitter_sigma_ms, spec, float),
                    comm_mu_ms=_pop_number(
                        kv, "comm_mu_ms", cfg.comm_mu_ms, spec, float),
                    comm_sigma_ms=_pop_number(
                        kv, "comm_sigma_ms", cfg.comm_sigma_ms, spec, float),
                    jitter_rho=_pop_number(
                        kv, "rho", cfg.jitter_rho, spec, float),
                    jitter_devices=_pop_number(
                        kv, "devices", cfg.jitter_devices, spec, int),
                )
            elif kind == "ckpt-io":
                if "fails" not in kv:
                    raise KeyError("fails")
                cfg = dataclasses.replace(cfg, ckpt_write_failures=_pop_number(
                    kv, "fails", 0, spec, int))
            elif kind == "preempt":
                if "window" not in kv:
                    raise KeyError("window")
                cfg = dataclasses.replace(cfg, preempt_after_window=_pop_number(
                    kv, "window", 0, spec, int))
            else:
                raise ValueError(
                    f"unknown fault kind {kind!r} (expected jitter | "
                    f"ckpt-io | preempt)")
        except KeyError as e:
            raise ValueError(f"fault spec {spec!r} missing option {e}") from e
        if kv:
            raise ValueError(
                f"unknown option(s) {sorted(kv)} for fault kind {kind!r}")
    return cfg


def format_fault_specs(cfg: FaultConfig) -> list[str]:
    """Inverse of :func:`parse_fault_specs` (modulo ``seed``, which is a CLI
    flag, not part of the spec grammar): emits one spec per enabled fault
    such that ``parse_fault_specs(format_fault_specs(cfg), seed=cfg.seed)
    == cfg``. Used to echo the active fault plan (resume hints, logs) in a
    form that can be pasted straight back onto ``--inject-fault``."""
    specs: list[str] = []
    jitter_opts = []
    base = FaultConfig()
    for opt, field in (("mu_ms", "jitter_mu_ms"),
                       ("sigma_ms", "jitter_sigma_ms"),
                       ("comm_mu_ms", "comm_mu_ms"),
                       ("comm_sigma_ms", "comm_sigma_ms"),
                       ("rho", "jitter_rho"),
                       ("devices", "jitter_devices")):
        val = getattr(cfg, field)
        if val != getattr(base, field):
            jitter_opts.append(f"{opt}={val!r}")
    if jitter_opts:
        specs.append("jitter:" + ",".join(jitter_opts))
    if cfg.ckpt_write_failures > 0:
        specs.append(f"ckpt-io:fails={cfg.ckpt_write_failures}")
    if cfg.preempt_after_window > 0:
        specs.append(f"preempt:window={cfg.preempt_after_window}")
    return specs
