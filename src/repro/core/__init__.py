"""Core library: the paper's structure-aware simulation strategy in JAX."""

from repro.core.areas import (
    AreaSpec,
    MultiAreaSpec,
    mam_benchmark_spec,
    mam_spec,
    ring_area_adjacency,
    tile_spec,
)
from repro.core.connectivity import (
    Network,
    build_network,
    shard_inter_tables,
    tile_gids,
    tile_network,
)
from repro.core.delivery import BACKENDS as DELIVERY_BACKENDS
from repro.core.exchange import EXCHANGES
from repro.core.engine import (
    ConfigError,
    ConfigViolation,
    Engine,
    EngineConfig,
    SimState,
    make_engine,
)
from repro.core.factory import make_simulation
from repro.core.schedule import SimCheckpointer, run_windows
from repro.core.dist_engine import (
    make_dist_engine,
    network_pspecs,
    shard_network,
    state_pspecs,
)
from repro.core.partition import (
    RoundRobinPlacement,
    StructureAwarePlacement,
    elastic_reshard_plan,
    round_robin_placement,
    structure_aware_placement,
)

__all__ = [
    "AreaSpec",
    "MultiAreaSpec",
    "mam_benchmark_spec",
    "mam_spec",
    "ring_area_adjacency",
    "tile_spec",
    "Network",
    "build_network",
    "shard_inter_tables",
    "tile_gids",
    "tile_network",
    "DELIVERY_BACKENDS",
    "EXCHANGES",
    "ConfigError",
    "ConfigViolation",
    "Engine",
    "EngineConfig",
    "SimState",
    "SimCheckpointer",
    "run_windows",
    "make_simulation",
    "make_engine",
    "make_dist_engine",
    "network_pspecs",
    "state_pspecs",
    "shard_network",
    "RoundRobinPlacement",
    "StructureAwarePlacement",
    "round_robin_placement",
    "structure_aware_placement",
    "elastic_reshard_plan",
]
