"""Dense spike-exchange primitives (bit-packed gathers over the mesh tiers).

The paper's §4.1.2 introduces *separate communication pathways* for short- and
long-range spikes. On a TPU mesh ``(pod, data, model)``:

* the **local pathway** runs every cycle but only over the ``model`` axis --
  the subgroup of devices hosting one area (the paper's proposed ``MPI_Group``
  generalisation). On hardware these are nearest-neighbour ICI hops.
* the **global pathway** runs every D-th cycle and carries the lumped
  ``[D, ...]`` spike block (larger, rarer messages -- the sublinear
  collective-cost regime of Fig. 4).

This module provides the *dense wire format* for both: bit-packed spike
vectors assembled with tiled ``all_gather`` (``gather_area`` /
``gather_global`` / ``gather_full``). It is one of the wire formats behind
the pluggable exchange layer (:mod:`repro.core.exchange`): the
``DenseMeshExchange`` uses these gathers for the dense delivery backends and
compacted id packets for the event backend; the connectivity-``routed``
exchange replaces the global gather entirely with ppermute packet rounds
over the area-adjacency group graph, so fired ids only travel along edges
that exist.

Spikes travel as one *bit* per neuron per cycle on the dense wire (a neuron
fires at most once per 0.1 ms step because of refractoriness), which both
matches NEST's byte-level spike compression spirit and keeps collective
bytes honest for the roofline.

All functions below are written for use *inside* ``shard_map``.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "pack_bits",
    "unpack_bits",
    "gather_area",
    "gather_global",
    "exchange_bytes",
    "count_max",
    "gather_counts",
    "count_wire_bytes",
]


def pack_bits(x: jax.Array) -> jax.Array:
    """[..., n] 0/1 int8 -> [..., ceil(n/8)] uint8 (wire format).

    A neuron fires at most once per 0.1 ms cycle, so a spike vector is one
    *bit* per neuron -- packing cuts collective bytes 8x vs int8. (NEST sends
    sparse id packets; at brain-scale rates an id list would be smaller
    still, but bit-vectors keep XLA shapes static and unpack on the VPU.)
    """
    n = x.shape[-1]
    pad = (-n) % 8
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    b = x.reshape(x.shape[:-1] + ((n + pad) // 8, 8)).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (b * weights).sum(axis=-1, dtype=jnp.uint8)


def unpack_bits(p: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: [..., n/8] uint8 -> [..., n] int8."""
    bits = (p[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & jnp.uint8(1)
    out = bits.reshape(p.shape[:-1] + (p.shape[-1] * 8,))
    return out[..., :n].astype(jnp.int8)


def gather_area(
    spikes_local: jax.Array,
    *,
    subgroup_axis: str = "model",
    packed: bool = True,
) -> jax.Array:
    """Local pathway: assemble the full per-area spike vector.

    ``spikes_local``: [A_loc, n_loc] int8 -- this device's shard of its areas'
    neurons. Returns [A_loc, n_pad]: the areas' complete spike vectors,
    gathered over the intra-area subgroup only (bit-packed on the wire).
    """
    if not packed:
        return jax.lax.all_gather(
            spikes_local, subgroup_axis, axis=1, tiled=True)
    n_loc = spikes_local.shape[-1]
    per = (n_loc + 7) // 8
    wire = pack_bits(spikes_local)
    wire = jax.lax.all_gather(wire, subgroup_axis, axis=1, tiled=True)
    # unpack per shard, then flatten shards back into the neuron axis
    n_shards = wire.shape[1] // per
    wire = wire.reshape(wire.shape[0], n_shards, per)
    out = unpack_bits(wire, n_loc)
    return out.reshape(out.shape[0], n_shards * n_loc)


def gather_global(
    block_local: jax.Array,
    *,
    area_axes: Sequence[str] = ("pod", "data"),
    subgroup_axis: str = "model",
    packed: bool = True,
) -> jax.Array:
    """Global pathway: assemble the lumped spike block of the whole network.

    ``block_local``: [D, A_loc, n_loc] int8 (D cycles of local spikes).
    Returns [D, A, n_pad] in global area order. Two stages: first complete
    each area over the subgroup axis (fast tier), then concatenate areas over
    the area axes (slow tier). Area order is (pod-major, data-minor) matching
    ``partition.StructureAwarePlacement``. Bit-packed on the wire (8x fewer
    collective bytes; spikes are one bit per neuron per cycle).
    """
    if not packed:
        block = jax.lax.all_gather(
            block_local, subgroup_axis, axis=2, tiled=True)
        for ax in reversed(tuple(area_axes)):
            block = jax.lax.all_gather(block, ax, axis=1, tiled=True)
        return block
    n_loc = block_local.shape[-1]
    wire = pack_bits(block_local)           # [D, A_loc, n_loc/8] uint8
    per = (n_loc + 7) // 8
    wire = jax.lax.all_gather(wire, subgroup_axis, axis=2, tiled=True)
    for ax in reversed(tuple(area_axes)):
        # Gather innermost axis first so the final order is row-major over
        # (pod, data), i.e. global area index = (p * n_data + d) * A_loc + a.
        wire = jax.lax.all_gather(wire, ax, axis=1, tiled=True)
    d, a_tot, _ = wire.shape
    n_shards = wire.shape[-1] // per
    wire = wire.reshape(d, a_tot, n_shards, per)
    out = unpack_bits(wire, n_loc)
    return out.reshape(d, a_tot, n_shards * n_loc)


def gather_full(
    spikes_local: jax.Array,
    axes: Sequence[str],
    *,
    packed: bool = True,
) -> jax.Array:
    """Conventional pathway: one global gather of the per-cycle spike vector
    ([A, n_loc] -> [A, n_pad], over ALL mesh axes), bit-packed on the wire."""
    if not packed:
        return jax.lax.all_gather(spikes_local, tuple(axes), axis=1, tiled=True)
    n_loc = spikes_local.shape[-1]
    per = (n_loc + 7) // 8
    wire = pack_bits(spikes_local)
    wire = jax.lax.all_gather(wire, tuple(axes), axis=1, tiled=True)
    n_shards = wire.shape[1] // per
    wire = wire.reshape(wire.shape[0], n_shards, per)
    out = unpack_bits(wire, n_loc)
    return out.reshape(out.shape[0], n_shards * n_loc)


def exchange_bytes(
    shape_local: tuple[int, ...],
    n_gather_devices: int,
    dtype_bytes: int = 1,
) -> int:
    """Bytes a device receives in one tiled all_gather (for the cost model)."""
    n_elems = 1
    for s in shape_local:
        n_elems *= s
    return n_elems * (n_gather_devices - 1) * dtype_bytes


# ---------------------------------------------------------------------------
# Phase-1 count collectives (the adaptive two-phase exchange's tiny wire)
# ---------------------------------------------------------------------------


def count_max(count: jax.Array, axes) -> jax.Array:
    """Mesh-maximum of a (scalar or small) int32 spike count.

    Phase 1 of the adaptive two-phase exchange (cf. Du et al., "A
    Low-latency Communication Design for Brain Simulations": exchange sizes
    first, then right-sized payloads): every device learns the *largest*
    per-cycle packet need before any payload ships, so all devices select
    the same bucket rung -- the SPMD branch-uniformity requirement of
    ``ops.ladder_switch``. The collective is a pmax over ``axes``; its wire
    cost (4 B per participant) is priced by :func:`count_wire_bytes`.
    """
    return jax.lax.pmax(count, axes)


def gather_counts(
    counts_local: jax.Array,   # [D, A_loc] int32 partial per-area counts
    *,
    area_axes: Sequence[str] = ("pod", "data"),
    subgroup_axis: str = "model",
) -> jax.Array:
    """Assemble the global ``[D, A]`` per-area spike-count table.

    The routed exchange's phase 1: each device's partial per-area counts are
    completed over the intra-area subgroup (psum) and concatenated over the
    area axes (innermost-first, so global area order matches
    :func:`gather_global` and the group layout). From the full table every
    device computes -- identically -- the *exact* per-edge packet need of
    every rotation round, so per-round buckets are both overflow-free and
    branch-uniform. At int32 this is ``D * A`` words: negligible next to
    even one static id packet.
    """
    c = jax.lax.psum(counts_local, subgroup_axis)
    for ax in reversed(tuple(area_axes)):
        c = jax.lax.all_gather(c, ax, axis=1, tiled=True)
    return c


def count_wire_bytes(n_words: int, n_devices: int) -> int:
    """Mesh-total bytes of one phase-1 count collective.

    ``n_words`` int32 words received per device (1 for :func:`count_max`,
    ``D * A`` for :func:`gather_counts`), modelled like the payload
    accounting: every device receives the full result once.
    """
    return n_devices * n_words * 4
