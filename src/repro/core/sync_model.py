"""§2.2 -- statistical model of synchronization time (order statistics).

The paper models per-process, per-cycle compute times as t ~ N(mu, sigma^2).
With blocking collectives, every cycle costs the *maximum* over the M
processes (eq. 3); lumping D cycles between synchronizations (eq. 4-5) turns
the per-sync distribution into N(D mu, D sigma^2) (eq. 6, CLT), cutting the
coefficient of variation by 1/sqrt(D) (eq. 7) and the expected total
synchronization time by the same factor (eq. 11).

This module provides:
  * the analytic pieces (Blom's E[max] approximation, eq. 8-12),
  * a Monte-Carlo simulator that *also* models what the paper measures but
    the CLT argument ignores -- AR(1) serial correlation of per-process cycle
    times and the bimodal cycle-time distribution (Fig. 7b / Fig. 12) -- which
    reproduces the measured CV-ratio gap (0.71 observed vs 0.32 predicted).

No scipy available: Phi and Phi^{-1} are implemented via math.erf and
Acklam's rational approximation (|rel err| < 1.15e-9).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "norm_cdf",
    "norm_pdf",
    "norm_ppf",
    "blom_xi",
    "expected_wall_conventional",
    "expected_wall_structure_aware",
    "expected_max_normals",
    "expected_wall_overlapped",
    "sync_time_ratio",
    "max_tail_probability",
    "tail_for_max_coverage",
    "CycleTimeModel",
    "simulate_schedules",
    "ScheduleSample",
]


def norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def norm_pdf(x: float) -> float:
    return math.exp(-0.5 * x * x) / math.sqrt(2.0 * math.pi)


# Acklam's inverse normal CDF coefficients.
_A = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
      1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
_B = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
      6.680131188771972e+01, -1.328068155288572e+01)
_C = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
      -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
_D = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
      3.754408661907416e+00)


def norm_ppf(p: float) -> float:
    """Inverse standard normal CDF (Acklam's approximation)."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0,1), got {p}")
    p_low, p_high = 0.02425, 1 - 0.02425
    if p < p_low:
        q = math.sqrt(-2 * math.log(p))
        return (((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
               ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)
    if p > p_high:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]) / \
                ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4]) * r + _A[5]) * q / \
           (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r + _B[4]) * r + 1)


def blom_xi(m: int, alpha: float = 0.375) -> float:
    """Blom (1958): E[max of m iid N(0,1)] ~= Phi^{-1}((m - alpha)/(m - 2 alpha + 1)).

    This is the xi_M factor of paper eqs. (8)-(9): how many standard
    deviations above the mean the expected per-cycle maximum sits.
    """
    if m < 1:
        raise ValueError("m >= 1 required")
    if m == 1:
        return 0.0
    return norm_ppf((m - alpha) / (m - 2 * alpha + 1))


def expected_wall_conventional(s: int, m: int, mu: float, sigma: float) -> float:
    """Paper eq. (8): E[T_wall^conv] = S mu + S xi_M sigma."""
    return s * mu + s * blom_xi(m) * sigma


def expected_wall_structure_aware(
    s: int, d: int, m: int, mu: float, sigma: float
) -> float:
    """Paper eq. (9): E[T_wall^struc] = S mu + (S/sqrt(D)) xi_M sigma."""
    if s % d != 0:
        raise ValueError("S must be a multiple of D")
    return s * mu + (s / math.sqrt(d)) * blom_xi(m) * sigma


def sync_time_ratio(d: int) -> float:
    """Paper eq. (11): E[T_sync^struc] / E[T_sync^conv] = 1/sqrt(D)."""
    return 1.0 / math.sqrt(d)


def expected_max_normals(
    mu1: float, sigma1: float, mu2: float, sigma2: float
) -> float:
    """Clark (1961): E[max(X1, X2)] for independent normals.

    With theta = sqrt(sigma1^2 + sigma2^2) and delta = (mu1 - mu2)/theta:
    ``E[max] = mu1 Phi(delta) + mu2 Phi(-delta) + theta phi(delta)``.
    This is the analytic heart of the overlapped-schedule claim: when the
    window-end exchange of window ``w`` runs concurrently with the compute
    of ``w+1``, the per-window wall is governed by the *maximum* of the two
    straggler times, not their sum -- the correction term ``theta phi``
    vanishes as the means separate, so a pipeline dominated by either phase
    costs exactly that phase.
    """
    theta = math.hypot(sigma1, sigma2)
    if theta == 0.0:
        return max(mu1, mu2)
    delta = (mu1 - mu2) / theta
    return (mu1 * norm_cdf(delta) + mu2 * norm_cdf(-delta)
            + theta * norm_pdf(delta))


def expected_wall_overlapped(
    n_windows: int,
    compute_window_s: float,
    compute_spread_s: float,
    comm_window_s: float,
    comm_spread_s: float,
) -> float:
    """Expected pipelined wall over ``n_windows``: the steady-state window
    costs E[max(compute, comm)] (the exchange of window ``w`` hides behind
    the compute of ``w+1``), plus the pipeline's fill/drain edges -- the
    first window has no in-flight exchange to hide and the last exchange has
    no compute left to hide behind. The sequential reference over the same
    windows is ``n_windows * (compute + comm)``."""
    if n_windows < 1:
        raise ValueError("n_windows >= 1 required")
    steady = expected_max_normals(
        compute_window_s, compute_spread_s, comm_window_s, comm_spread_s)
    return compute_window_s + (n_windows - 1) * steady + comm_window_s


def max_tail_probability(p_tail: float, m: int) -> float:
    """Paper eq. (12): P(max falls in a tail of per-process probability p)."""
    return 1.0 - (1.0 - p_tail) ** m


def tail_for_max_coverage(coverage: float, m: int) -> float:
    """Invert eq. (12): the per-process tail probability whose maxima cover
    ``coverage`` of the per-cycle maxima distribution (e.g. 0.99 -> 3.5% for
    M=128, the number quoted in §2.2)."""
    return 1.0 - (1.0 - coverage) ** (1.0 / m)


@dataclasses.dataclass(frozen=True)
class CycleTimeModel:
    """Generative model of per-process cycle times.

    ``mu``/``sigma``: body of the distribution. ``rho``: AR(1) serial
    correlation of each process's successive cycle times. ``minor_mode_*``:
    bimodal mixture (Fig. 7b: major mode ~1.62 ms, minor ~1.90 ms) modelled as
    a *sticky* two-state Markov chain with mean dwell ``minor_mode_dwell``
    cycles -- Fig. 12 shows elevated phases persisting over thousands of
    cycles, which is precisely what breaks the CLT independence assumption and
    caps the realised synchronization gain (§2.4.1). ``process_spread``:
    per-process *systematic* mean offsets (heterogeneous areas -> slow/fast
    processes; drives Fig. 8a/9).
    """

    mu: float = 1.62e-3
    sigma: float = 0.05e-3
    rho: float = 0.0
    minor_mode_shift: float = 0.0
    minor_mode_weight: float = 0.0
    minor_mode_dwell: float = 500.0
    process_spread: float = 0.0

    def sample(self, m: int, s: int, rng: np.random.Generator) -> np.ndarray:
        """[M, S] per-process cycle times."""
        proc_mu = self.mu + self.process_spread * rng.standard_normal(m)
        if self.rho > 0:
            # AR(1) with stationary variance sigma^2.
            eps = rng.standard_normal((m, s)) * self.sigma * math.sqrt(1 - self.rho**2)
            x = np.empty((m, s))
            x[:, 0] = rng.standard_normal(m) * self.sigma
            for t in range(1, s):
                x[:, t] = self.rho * x[:, t - 1] + eps[:, t]
            noise = x
        else:
            noise = rng.standard_normal((m, s)) * self.sigma
        t = proc_mu[:, None] + noise
        if self.minor_mode_weight > 0 and self.minor_mode_shift != 0:
            w, dwell = self.minor_mode_weight, max(self.minor_mode_dwell, 1.0)
            p_exit = 1.0 / dwell
            p_enter = w * p_exit / max(1.0 - w, 1e-9)
            state = rng.random(m) < w  # stationary start
            hits = np.empty((m, s), dtype=bool)
            u = rng.random((m, s))
            for step in range(s):
                state = np.where(
                    state, u[:, step] >= p_exit, u[:, step] < p_enter
                )
                hits[:, step] = state
            t = t + hits * self.minor_mode_shift
        return np.maximum(t, 0.0)


@dataclasses.dataclass(frozen=True)
class ScheduleSample:
    """Monte-Carlo outcome for one schedule."""

    wall: float          # total compute+wait time (excl. data exchange)
    compute: float       # mean over processes of their own compute time
    sync: float          # wall - compute: the synchronization overhead
    cv_lumped: float     # CV of the (lumped) cycle-time distribution
    n_syncs: int


def simulate_schedules(
    model: CycleTimeModel,
    m: int,
    s: int,
    d: int,
    seed: int = 0,
) -> tuple[ScheduleSample, ScheduleSample]:
    """Simulate conventional vs structure-aware totals on one cycle-time draw.

    Uses a *common random numbers* design: both schedules see the same [M, S]
    cycle-time matrix, exactly like the paper's pairing of benchmark runs.
    Returns (conventional, structure_aware).
    """
    if s % d != 0:
        raise ValueError("S must be a multiple of D")
    rng = np.random.default_rng(seed)
    t = model.sample(m, s, rng)  # [M, S]

    compute = float(t.mean(axis=1).sum())  # == mean process compute * S
    mean_compute = float(t.sum(axis=1).mean())

    # Conventional: synchronize after every cycle (eq. 3).
    wall_conv = float(t.max(axis=0).sum())
    conv = ScheduleSample(
        wall=wall_conv,
        compute=mean_compute,
        sync=wall_conv - mean_compute,
        cv_lumped=float(t.std() / t.mean()),
        n_syncs=s,
    )

    # Structure-aware: lump D cycles (eq. 4-5).
    lumped = t.reshape(m, s // d, d).sum(axis=2)  # [M, S/D]
    wall_struc = float(lumped.max(axis=0).sum())
    struc = ScheduleSample(
        wall=wall_struc,
        compute=mean_compute,
        sync=wall_struc - mean_compute,
        cv_lumped=float(lumped.std() / lumped.mean()),
        n_syncs=s // d,
    )
    del compute
    return conv, struc
