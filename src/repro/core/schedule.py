"""The shared window/cycle core: one deliver -> update -> collocate body.

Before this module the single-host engine (``engine.py``) and the distributed
engine (``dist_engine.py``) each carried their own copy of the window
machinery -- per-cycle scan, fused D-cycle superstep, legacy window + lumped
exchange -- ~400 lines of drift-prone duplication. Both engines now assemble
the *same* window body from here, parameterized by an
:class:`repro.core.exchange.Exchange`:

* what happens *inside* a cycle (ring read, neuron update, spike counting)
  and *around* a window (blocked ring open/merge, superstep scan vs unroll,
  the legacy per-cycle reference) lives here, once;
* *how spikes travel* -- single-host identity, dense mesh collectives, or
  connectivity-routed packets -- lives in the exchange object.

The schedules (paper Fig. 3):

* ``conventional``: the long-range pathway is exercised every cycle
  (``inter_now=True`` in the cycle hook);
* ``structure_aware``: long-range spikes accumulate for the whole window and
  travel once, in the window-end hook. Causal because every inter-area delay
  is >= D steps; bit-identical because delivery weights live on the exact
  1/256 grid.

Every variant produces bit-identical spike trains; the equivalence suites
(tests/test_system.py, tests/test_distributed.py, tests/test_exchange.py)
pin that across schedules, backends, exchanges and meshes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_lib
from repro.core import neuron as neuron_lib
from repro.core import partition as partition_lib
from repro.core import ring_buffer

__all__ = [
    "CONVENTIONAL",
    "STRUCTURE_AWARE",
    "SimState",
    "SimCheckpointer",
    "RunResult",
    "make_update_fn",
    "make_window_fn",
    "make_overlap_window_fn",
    "restore_sim",
    "resume_config_hash",
    "run_windows",
]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes
    # Scalar int32: spikes dropped because a fixed-size packet (event
    # backend, or a routed-exchange edge) exceeded its static s_max bound
    # (0 on the dense pathways; any nonzero value means the run is no longer
    # exact and s_max_headroom/floor must be raised). Under the adaptive
    # two-phase exchange (EngineConfig.adaptive_exchange) this is provably
    # always 0: phase-1 counts size every packet and the bucket ladders top
    # out at the hard population cap.
    overflow: Any = None
    # Scalar f32: cumulative mesh-total wire bytes the exchanges actually
    # shipped (counts + payloads). Static packets add their fixed byte
    # constants; adaptive packets add the bytes of the bucket each window
    # actually selected -- the *measured* counterpart of the static
    # worst-case accounting in Engine.wire_bytes / exchange.wire_report
    # (f32: byte totals overflow int32 long before they lose f32 precision
    # that matters for reporting).
    shipped_bytes: Any = None
    # Optional per-neuron drive seed ([A, n_pad] uint32) -- the serving
    # layer's trial axis: a folded batch of trials carries each trial's seed
    # on its own block of neurons, and the counter-based drive reads it
    # instead of the engine-wide EngineConfig.seed. None (the default)
    # contributes no pytree leaf, so every pre-serving state, checkpoint
    # manifest and shard_map spec tree is structurally unchanged; a
    # broadcast scalar equal to cfg.seed is bit-identical to None.
    seed: Any = None
    # Optional per-neuron stimulus scale ([A, n_pad] f32) multiplying the
    # external drive rate -- the per-trial stimulus knob of a serving
    # request. None contributes no leaf; an all-ones array is bit-identical
    # to None (x * 1.0f is exact).
    stim: Any = None


def make_update_fn(
    cfg,                       # EngineConfig (duck-typed to avoid a cycle)
    spec,                      # MultiAreaSpec
    dt_ms: float,
    lif_params,
    fused_lif: Callable | None,
) -> Callable:
    """The neuron-update closure shared by both engines.

    ``update(neuron_state, i_in, t, net_view, gids, seed=None, stim=None) ->
    (state', spikes)`` where ``net_view`` may be the full network (single
    host) or a shard_map view -- the drive uses the view's
    ``rate_hz``/``alive`` and the *global* ids in ``gids``, so any sharding
    sees bit-identical noise. The drive rate is
    ``rate_hz * (ext_rate_hz / 2.5)`` -- one expression everywhere (the
    engines previously used two algebraically-equal-but-ULP-different forms;
    the shared core makes the cross-engine bit-equality structural instead
    of coincidental).

    ``seed``/``stim`` are the per-trial drive leaves of ``SimState`` (the
    serving layer's trial axis): ``seed`` replaces ``cfg.seed`` in the
    counter-based drive and ``stim`` scales the drive rate. ``None`` (every
    pre-serving caller) keeps the classic expressions verbatim.
    """
    drive_scale = spec.ext_rate_hz / 2.5

    def update(neuron_state, i_in, t, net, gids, seed=None, stim=None):
        if cfg.neuron_model == "lif":
            rate = net.rate_hz * drive_scale
            if stim is not None:
                rate = rate * stim
            drive = neuron_lib.poisson_drive(
                cfg.seed if seed is None else seed, t, gids, rate, dt_ms,
                spec.w_ext,
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, net.alive)
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params)
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, dt_ms)

    return update


def make_window_fn(
    cfg,
    exchange,
    update_fn: Callable,
    *,
    fused_superstep: Callable | None = None,
) -> Callable:
    """Build the ``window(state, net, gids) -> (state', block)`` body.

    ``net``/``gids`` may be full arrays (single-host) or shard_map views
    (distributed) -- all communication is delegated to ``exchange``:

    * ``exchange.cycle(ring, spikes, t, net, gids, inter_now=...)`` runs the
      per-cycle short-range pathway (and, under the conventional schedule,
      the per-cycle long-range exchange too);
    * ``exchange.window_end(ring, block, t0, net, gids, blocked=...)`` runs
      the structure-aware schedule's lumped window-end exchange.

    During a superstep, ``ring`` handed to the cycle hook is the *live
    window buffer* and ``t`` the within-window slot index -- deposits are
    wrap-free by construction (``Network.live_window``), so the same
    delivery code serves both modes.

    ``fused_superstep`` (single-host only) replaces the whole in-window loop
    with the fused Pallas superstep kernel; the lumped exchange still goes
    through the exchange hook.
    """

    compute_window = _make_compute_window(
        cfg, exchange, update_fn, fused_superstep)

    if cfg.schedule == CONVENTIONAL:
        return compute_window

    blocked = bool(cfg.use_superstep)

    def window(state: SimState, net, gids):
        t0 = state.t
        state, block = compute_window(state, net, gids)
        # The lumped 'global communication': the whole [D, ...] block in
        # one pass. Every inter-area delay is >= D, so slot (t0+s+d) is
        # strictly in the future of the window -- causal (paper §2.1)
        # and bit-identical to D per-cycle deliveries.
        ring, d_over, d_ship = exchange.window_end(
            state.ring, block, t0, net, gids, blocked=blocked)
        return dataclasses.replace(
            state, ring=ring, overflow=state.overflow + d_over,
            shipped_bytes=state.shipped_bytes + d_ship), block

    return window


def _make_compute_window(cfg, exchange, update_fn, fused_superstep):
    """The window body *without* the structure-aware window-end exchange.

    Shared by the sequential window (which appends ``exchange.window_end``)
    and the overlapped window (which brackets it with ``finish``/``start``);
    under the conventional schedule this IS the whole window (the per-cycle
    hook runs the global pathway too).
    """

    def compute_window(state: SimState, net, gids):
        D = net.delay_ratio
        t0 = state.t

        def cycle_state(st: SimState, inter_now: bool):
            """One deliver -> update -> collocate cycle on full SimState."""
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = update_fn(
                st.neuron, i_in, st.t, net, gids, seed=st.seed, stim=st.stim)
            ring, over, shipped = exchange.cycle(
                ring, spikes, st.t, net, gids, inter_now=inter_now)
            return dataclasses.replace(
                st,
                neuron=nstate,
                ring=ring,
                t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
                overflow=st.overflow + over,
                shipped_bytes=st.shipped_bytes + shipped,
            ), spikes

        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence long-range delivery) every cycle.
            def body(st, _):
                return cycle_state(st, inter_now=True)

            return jax.lax.scan(body, state, None, length=D)

        if cfg.use_superstep:
            # One fused D-cycle superstep: the window's D input slots are one
            # contiguous ring block (phase alignment: t0 ≡ 0 mod D and
            # ring_len ≡ 0 mod D), read and cleared once; cycles consume
            # window-static columns of the live buffer ``fut``.
            W = net.live_window
            fut, ring = ring_buffer.open_window(state.ring, t0, D, W)
            neuron, over = state.neuron, state.overflow
            shipped = state.shipped_bytes
            if fused_superstep is not None:
                neuron, block, fut = fused_superstep(neuron, fut, t0)
            elif cfg.superstep_unroll:
                cols = []
                for s in range(D):  # unrolled: s static, slot math vanishes
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids,
                        seed=state.seed, stim=state.stim)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    over = over + d_over
                    shipped = shipped + d_ship
                    cols.append(spikes)
                block = jnp.stack(cols)
            else:
                # Scan over the live window: slot access touches only the
                # small [.., W] buffer (wrap-free), never the ring.
                def body(carry, s):
                    neuron, fut, over, shipped = carry
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids,
                        seed=state.seed, stim=state.stim)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    return (neuron, fut, over + d_over,
                            shipped + d_ship), spikes

                (neuron, fut, over, shipped), block = jax.lax.scan(
                    body, (neuron, fut, over, shipped),
                    jnp.arange(D, dtype=jnp.int32))
            ring = ring_buffer.merge_window_tail(ring, fut[..., D:], t0 + D)
            return dataclasses.replace(
                state,
                neuron=neuron,
                ring=ring,
                t=t0 + D,
                spike_count=state.spike_count + block.astype(jnp.int32).sum(0),
                overflow=over,
                shipped_bytes=shipped,
            ), block

        # Legacy structure-aware window (the semantic reference for the
        # superstep): per-cycle scan, the window-end exchange appended by
        # the caller.
        def body(st, _):
            return cycle_state(st, inter_now=False)

        return jax.lax.scan(body, state, None, length=D)

    return compute_window


def make_overlap_window_fn(
    cfg,
    exchange,
    update_fn: Callable,
    *,
    fused_superstep: Callable | None = None,
) -> tuple[Callable, Callable]:
    """Build the double-buffered window pair ``(window_overlap, drain)``.

    ``window_overlap(state, inflight, net, gids) -> (state', inflight',
    block)`` runs one window of the overlapped pipeline: it first *finishes*
    the previous window's in-flight exchange (the collective-free receive
    scatter -- its earliest deposit lands exactly on the first ring slot
    this window reads, so it cannot be deferred further), then runs the
    compute body, then *starts* this window's exchange (assembly + all
    collectives), handing the received payload back as the new in-flight
    state. On hardware with async collectives the start's transfers overlap
    the next window's compute; the schedule's wall becomes
    ``max(compute, comm)`` per window instead of their sum.

    ``drain(state, inflight, net, gids) -> state'`` retires an in-flight
    window at a pipeline boundary (checkpoint, preemption, end of run) so
    the ring equals the sequential schedule's -- a drained pipeline is
    bitwise the sequential trajectory, which is what keeps checkpoints
    layout-free and resume exact.
    """
    if cfg.schedule == CONVENTIONAL:
        raise ValueError(
            "overlap_exchange requires the structure-aware schedule: the "
            "conventional schedule has no lumped window-end exchange to "
            "overlap with compute")
    compute_window = _make_compute_window(
        cfg, exchange, update_fn, fused_superstep)
    blocked = bool(cfg.use_superstep)

    def window_overlap(state: SimState, inflight, net, gids):
        ring = exchange.finish_window_end(
            state.ring, inflight, net, gids, blocked=blocked)
        state = dataclasses.replace(state, ring=ring)
        t0 = state.t
        state, block = compute_window(state, net, gids)
        inflight, d_over, d_ship = exchange.start_window_end(
            block, t0, net, gids, blocked=blocked)
        return dataclasses.replace(
            state, overflow=state.overflow + d_over,
            shipped_bytes=state.shipped_bytes + d_ship), inflight, block

    def drain(state: SimState, inflight, net, gids):
        ring = exchange.finish_window_end(
            state.ring, inflight, net, gids, blocked=blocked)
        return dataclasses.replace(state, ring=ring)

    return window_overlap, drain


# ---------------------------------------------------------------------------
# Windowed checkpoint / resume / fault-tolerant run loop
# ---------------------------------------------------------------------------
#
# Checkpoints are only taken at *window boundaries*: there t ≡ 0 (mod D), the
# live window buffer is merged back and the ring's phase alignment
# (ring_len ≡ 0 mod D) is the same invariant a fresh init satisfies, so a
# restored SimState re-enters the superstep exactly where an uninterrupted
# run would. The external drive is a counter-based pure function of
# (seed, t, gid) -- the "RNG state" is fully captured by recording the seed
# and the absolute cycle index t in the manifest -- which is what makes
# resume *bitwise* identical rather than statistically identical.
#
# State arrays are keyed by area in global layout ([A, n_pad, ...]), so a
# checkpoint gathered to host memory is mesh-independent: restoring onto a
# different group count is gather -> (re-order per the elastic reshard plan,
# the identity for contiguous plans) -> re-scatter through the new engine's
# shardings, while the distributed factory (make_simulation with a mesh)
# re-cuts the inter receive tables for the new mesh via
# connectivity.shard_inter_tables.


# Config fields that are *layout*, not *trajectory*: every value produces
# bit-identical spike trains (sharded inter tables are re-cut by the
# distributed factory for whatever mesh the resume runs on; a drained overlap
# pipeline IS the sequential trajectory; a sharded build regenerates the
# exact same tables from the counter-based rules a host build draws), so
# checkpoints must stay exchangeable across them. Recorded in the manifest
# payload for forensics, excluded from the compatibility hash and the
# mismatch diff.
_LAYOUT_KEYS = frozenset(
    {"shard_inter_tables", "subgroup_inter_tables", "overlap_exchange",
     "sharded_build"})


def resume_config_hash(cfg, net, *, exchange: str | None = None):
    """``(hash, payload)`` identifying what a checkpoint can resume into.

    Covers everything that changes the *trajectory* (neuron model, schedule,
    exchange, adaptive flag, delivery backend, seed, packet bounds) plus the
    network invariants a SimState's shapes encode (D, ring length, area
    grid). Deliberately excludes the mesh shape: elastic reshard-restart
    resumes the same config on a different group count. Layout-only fields
    (``_LAYOUT_KEYS``: replicated vs sharded inter tables, overlapped vs
    sequential exchange) ride along in the payload but do not enter the
    hash -- they change how the run executes, never what it computes.
    ``exchange`` overrides ``cfg.exchange`` so launchers can hash the
    requested exchange independently of how it resolves for the current
    device count.
    """
    payload = {
        "neuron_model": cfg.neuron_model,
        "schedule": cfg.schedule,
        "exchange": cfg.exchange if exchange is None else exchange,
        "adaptive_exchange": bool(cfg.adaptive_exchange),
        "delivery_backend": cfg.backend,
        "seed": int(cfg.seed),
        "s_max_headroom": float(cfg.s_max_headroom),
        "s_max_floor": int(cfg.s_max_floor),
        "delay_ratio": int(net.delay_ratio),
        "ring_len": int(net.ring_len),
        "n_areas": int(net.n_areas),
        "n_pad": int(net.n_pad),
        "shard_inter_tables": bool(cfg.shard_inter_tables),
        "subgroup_inter_tables": bool(
            getattr(cfg, "subgroup_inter_tables", True)),
        "overlap_exchange": bool(getattr(cfg, "overlap_exchange", False)),
        "sharded_build": bool(getattr(cfg, "sharded_build", False)),
    }
    hashed = {k: v for k, v in payload.items() if k not in _LAYOUT_KEYS}
    digest = hashlib.sha256(
        json.dumps(hashed, sort_keys=True).encode()).hexdigest()[:16]
    return digest, payload


class SimCheckpointer:
    """Windowed SimState checkpointing through ``checkpoint.AsyncWriter``.

    ``save`` submits the full SimState pytree (neuron state, phase-aligned
    rings, ``t``, ``spike_count``, ``overflow``, ``shipped_bytes``) with a
    manifest recording the window phase, seed (the drive's RNG state), the
    group count the run executed on, and the resume-config hash. The step id
    is the count of *completed windows* (``t // D``), so ``latest_step`` is
    directly "how far did the dead run get".
    """

    def __init__(
        self,
        directory: str,
        engine,
        net,
        *,
        every: int = 50,
        keep: int = 3,
        exchange: str | None = None,
        n_groups: int = 1,
        injector: faults_lib.FaultInjector | None = None,
        retries: int = 3,
        backoff_s: float = 0.05,
    ):
        from repro.checkpoint import manager as ckpt_manager

        self.directory = directory
        self.every = every
        self.delay_ratio = int(engine.delay_ratio)
        self.seed = int(engine.config.seed)
        self.n_groups = int(n_groups)
        self.config_hash, self.config_payload = resume_config_hash(
            engine.config, net, exchange=exchange)
        save_fn = None
        if injector is not None and injector.cfg.ckpt_write_failures > 0:
            save_fn = injector.wrap_save(ckpt_manager.save)
        self.writer = ckpt_manager.AsyncWriter(
            directory, keep=keep, retries=retries, backoff_s=backoff_s,
            save_fn=save_fn)
        self.saved_windows: list[int] = []

    def due(self, window: int) -> bool:
        """Does the cadence fire at this completed-window count? Callers
        running the overlapped pipeline check this *before* touching the
        state so the in-flight window can drain first (the save must see
        the sequential-equivalent ring for resume to stay bitwise)."""
        w = int(window)
        return self.every > 0 and w > 0 and w % self.every == 0

    def maybe_save(self, state: SimState, window: int | None = None) -> int | None:
        """Cadence hook: save when the completed-window count hits `every`.

        Pass ``window`` (the caller's host-side completed-window count) to
        keep the off-cadence path free of device syncs -- reading
        ``state.t`` forces a transfer every window, which is exactly the
        overhead budget checkpointing must not spend.
        """
        w = int(state.t) // self.delay_ratio if window is None else int(window)
        if self.due(w):
            return self.save(state)
        return None

    def save(self, state: SimState) -> int:
        """Submit a window-boundary checkpoint; returns the step id."""
        t = int(state.t)
        if t % self.delay_ratio != 0:
            raise ValueError(
                f"checkpoint requested mid-window (t={t}, D="
                f"{self.delay_ratio}): only window boundaries keep the ring "
                f"phase alignment a resumed superstep needs")
        w = t // self.delay_ratio
        if self.saved_windows and self.saved_windows[-1] == w:
            return w  # boundary already checkpointed (cadence + preemption)
        ring_len = int(state.ring.shape[-1])
        extra = {
            "kind": "simstate",
            "t": t,
            "window": w,
            "window_phase": 0,
            "delay_ratio": self.delay_ratio,
            "ring_len": ring_len,
            "ring_phase": t % ring_len,
            "seed": self.seed,
            "n_groups": self.n_groups,
            "config_hash": self.config_hash,
            "config": self.config_payload,
        }
        self.writer.submit(w, state, extra=extra)
        self.saved_windows.append(w)
        return w

    @property
    def retry_count(self) -> int:
        return self.writer.retry_count

    def close(self) -> None:
        self.writer.close()


def _permute_areas(state: SimState, order: np.ndarray) -> SimState:
    """Re-order the per-area leading axis of every area-keyed leaf."""
    n_areas = int(state.spike_count.shape[0])
    idx = jnp.asarray(order, dtype=jnp.int32)

    def permute(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[0] == n_areas:
            return jnp.take(x, idx, axis=0)
        return x

    return jax.tree.map(permute, state)


def restore_sim(
    directory: str,
    engine,
    net,
    *,
    step: int | None = None,
    exchange: str | None = None,
    n_groups: int = 1,
):
    """Restore a SimState checkpoint into ``engine``, resharding if needed.

    Fails fast -- before any array is materialised -- when the checkpoint's
    resume-config hash differs from the current run's (clear field-by-field
    error instead of a deep shape mismatch), or when its recorded window
    phase is unaligned. If the checkpoint was taken on a different group
    count, the elastic reshard plan
    (:func:`repro.core.partition.elastic_reshard_plan`) validates the
    re-mesh, the per-area state rows are re-ordered per the plan (identity
    for contiguous plans), and the new engine's ``shard_state`` re-scatters
    them over the new mesh. Returns ``(state, info)`` where ``info`` carries
    the manifest, resumed step and reshard accounting.
    """
    from repro.checkpoint import manager as ckpt_manager

    manifest, step = ckpt_manager.read_manifest(directory, step)
    extra = manifest.get("extra", {})
    expect_hash, payload = resume_config_hash(
        engine.config, net, exchange=exchange)
    got_hash = extra.get("config_hash")
    if got_hash is not None and got_hash != expect_hash:
        old = extra.get("config", {})
        diffs = [
            f"  {k}: checkpoint={old.get(k)!r} != run={v!r}"
            for k, v in payload.items()
            if k not in _LAYOUT_KEYS and old.get(k) != v
        ] or [f"  config hash {got_hash} != {expect_hash}"]
        raise ValueError(
            "checkpoint is incompatible with this run's config -- resuming "
            "would not reproduce the uninterrupted trajectory:\n"
            + "\n".join(diffs))
    if extra.get("window_phase", 0) != 0:
        raise ValueError(
            f"checkpoint at step {step} is not window-phase aligned "
            f"(window_phase={extra.get('window_phase')}); only "
            f"window-boundary checkpoints can resume the D-cycle superstep")

    state, _ = ckpt_manager.restore(directory, like=engine.init(), step=step)

    old_groups = int(extra.get("n_groups", n_groups))
    reshard_info = None
    if n_groups != old_groups:
        sizes = np.asarray(net.alive).sum(axis=1).astype(int)
        placement = partition_lib.placement_from_sizes(
            sizes, old_groups, n_pad=int(net.n_pad))
        # Raises (fail fast) when the areas cannot rebalance onto n_groups.
        plan = partition_lib.elastic_reshard_plan(placement, n_groups)
        order = partition_lib.reshard_area_order(plan)
        if not np.array_equal(order, np.arange(order.size)):
            state = _permute_areas(state, order)
        reshard_info = {
            "old_n_groups": old_groups,
            "new_n_groups": n_groups,
            "moved_areas": partition_lib.reshard_moves(plan),
        }
    if engine.shard_state is not None:
        state = engine.shard_state(state)
    return state, {"step": step, "manifest": manifest,
                   "reshard": reshard_info}


@dataclasses.dataclass
class RunResult:
    """Outcome of :func:`run_windows` (also returned inside ``Preempted``)."""

    state: SimState
    spikes_per_window: np.ndarray   # [windows_done] int64
    window_times_s: np.ndarray      # wall per window, incl. injected jitter
    windows_done: int               # completed in THIS call
    injected_sleep_s: float = 0.0
    overlapped: bool = False        # ran the double-buffered pipeline
    drains: int = 0                 # in-flight windows retired at boundaries


def run_windows(
    engine,
    state: SimState,
    n_windows: int,
    *,
    checkpointer: SimCheckpointer | None = None,
    faults: "faults_lib.FaultConfig | faults_lib.FaultInjector | None" = None,
    on_window: Callable[[int, SimState], None] | None = None,
    on_block: Callable[[int, Any], None] | None = None,
    stop_requested: Callable[[], bool] | None = None,
) -> RunResult:
    """The engines' resilient run loop: windowed, checkpointed, fault-aware.

    ``Engine.run`` is the fast path -- one jitted scan, no host control in
    between. This loop trades one dispatch per window for window-boundary
    control, which is exactly where checkpoints are phase-safe: after every
    window it blocks on the state, submits a checkpoint when the cadence
    fires, injects configured faults, and stops SIGTERM-style on simulated
    preemption or when ``stop_requested()`` turns true (a real signal
    handler's flag) -- writing a final checkpoint first, then raising
    :class:`repro.core.faults.Preempted` with the result attached as
    ``exc.result``. Works unchanged for the single-host and distributed
    engines -- both assemble their window from this module.

    When the engine carries the overlapped pipeline (``engine.window_overlap``
    is set), the loop threads the in-flight window through and *drains* it at
    every pipeline boundary -- before a checkpoint save, on preemption/stop,
    and at the end of the run -- so everything observable (saved state,
    returned state) is the sequential-equivalent trajectory. Injected faults
    then model the pipeline: the sequential loop sleeps ``compute + comm``
    per window, the overlapped loop ``max(compute, prev window's comm)``
    with the last window's comm paid at the drain -- the realized sleeps ARE
    the order-statistics quantities ``sync_model.expected_wall_overlapped``
    prices.

    ``faults`` defaults to ``engine.config.faults``; pass an injector to
    share fault state (e.g. the transient-write budget also wired into the
    checkpointer) across resume legs.

    ``on_window(w, state)`` fires after every window; under the overlapped
    pipeline ``state`` may still have an undrained in-flight window (its
    ``spike_count``/``t`` are exact, the ring is missing the last window's
    inter deposits).

    ``on_block(w, block)`` is the per-request streaming cadence hook the
    serving layer hangs its result plumbing on: it fires after every window
    with the window's raw ``[D, A, n_pad]`` bool spike block (exact even
    when the overlap pipeline has an undrained exchange in flight -- the
    block is this window's own emissions). A multi-tenant batch slices each
    trial's rows out of the block and finalises a request the moment its
    own duration is reached, independent of the batch's longest trial.
    """
    fault_arg = faults if faults is not None else getattr(
        engine.config, "faults", None)
    if isinstance(fault_arg, faults_lib.FaultInjector):
        injector = fault_arg
    elif fault_arg is not None and fault_arg.any_enabled:
        injector = faults_lib.FaultInjector(
            fault_arg, n_devices=jax.device_count(),
            delay_ratio=engine.delay_ratio)
    else:
        injector = None

    overlapped = getattr(engine, "window_overlap", None) is not None
    inflight = engine.init_inflight() if overlapped else None
    in_flight_dirty = False
    pending_comm = 0.0
    drains = 0

    D = int(engine.delay_ratio)
    w_done = int(jax.device_get(state.t)) // D  # absolute windows completed
    spikes: list[int] = []
    times: list[float] = []
    slept = 0.0

    def result() -> RunResult:
        return RunResult(
            state=state,
            spikes_per_window=np.asarray(spikes, dtype=np.int64),
            window_times_s=np.asarray(times, dtype=np.float64),
            windows_done=len(times),
            injected_sleep_s=slept,
            overlapped=overlapped,
            drains=drains,
        )

    def drain_pipeline():
        """Retire the in-flight window (and pay its modelled comm time)."""
        nonlocal state, inflight, in_flight_dirty, pending_comm, slept, drains
        if not overlapped or not in_flight_dirty:
            return
        state = engine.drain(state, inflight)
        inflight = engine.init_inflight()
        jax.block_until_ready(state.ring)
        if injector is not None and pending_comm > 0.0:
            slept += injector.inject(pending_comm)
        pending_comm = 0.0
        in_flight_dirty = False
        drains += 1

    for _ in range(n_windows):
        t0 = time.perf_counter()
        if overlapped:
            state, inflight, block = engine.window_overlap(state, inflight)
            in_flight_dirty = True
        else:
            state, block = engine.window(state)
        jax.block_until_ready(state.ring)
        w_done += 1
        if injector is not None:
            comp = injector.window_jitter_s(w_done)
            comm = injector.window_comm_jitter_s(w_done)
            if overlapped:
                # This window's compute straggler overlaps the *previous*
                # window's exchange; its own exchange becomes next window's
                # in-flight time.
                slept += injector.inject(max(comp, pending_comm))
                pending_comm = comm
            else:
                slept += injector.inject(comp + comm)
        times.append(time.perf_counter() - t0)
        spikes.append(int(np.asarray(jnp.sum(block.astype(jnp.int32)))))
        if on_block is not None:
            on_block(w_done, block)
        if checkpointer is not None and checkpointer.due(w_done):
            drain_pipeline()
            checkpointer.maybe_save(state, window=w_done)
        if on_window is not None:
            on_window(w_done, state)
        stop = stop_requested is not None and stop_requested()
        if (injector is not None and injector.preempt_now(w_done)) or stop:
            drain_pipeline()
            path = None
            if checkpointer is not None:
                checkpointer.save(state)   # the SIGTERM-grace checkpoint
                checkpointer.close()
                path = checkpointer.directory
            exc = faults_lib.Preempted(w_done, path)
            exc.result = result()
            raise exc
    drain_pipeline()
    return result()
