"""The shared window/cycle core: one deliver -> update -> collocate body.

Before this module the single-host engine (``engine.py``) and the distributed
engine (``dist_engine.py``) each carried their own copy of the window
machinery -- per-cycle scan, fused D-cycle superstep, legacy window + lumped
exchange -- ~400 lines of drift-prone duplication. Both engines now assemble
the *same* window body from here, parameterized by an
:class:`repro.core.exchange.Exchange`:

* what happens *inside* a cycle (ring read, neuron update, spike counting)
  and *around* a window (blocked ring open/merge, superstep scan vs unroll,
  the legacy per-cycle reference) lives here, once;
* *how spikes travel* -- single-host identity, dense mesh collectives, or
  connectivity-routed packets -- lives in the exchange object.

The schedules (paper Fig. 3):

* ``conventional``: the long-range pathway is exercised every cycle
  (``inter_now=True`` in the cycle hook);
* ``structure_aware``: long-range spikes accumulate for the whole window and
  travel once, in the window-end hook. Causal because every inter-area delay
  is >= D steps; bit-identical because delivery weights live on the exact
  1/256 grid.

Every variant produces bit-identical spike trains; the equivalence suites
(tests/test_system.py, tests/test_distributed.py, tests/test_exchange.py)
pin that across schedules, backends, exchanges and meshes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_lib
from repro.core import neuron as neuron_lib
from repro.core import partition as partition_lib
from repro.core import ring_buffer

__all__ = [
    "CONVENTIONAL",
    "STRUCTURE_AWARE",
    "SimState",
    "SimCheckpointer",
    "RunResult",
    "make_update_fn",
    "make_window_fn",
    "restore_sim",
    "resume_config_hash",
    "run_windows",
]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes
    # Scalar int32: spikes dropped because a fixed-size packet (event
    # backend, or a routed-exchange edge) exceeded its static s_max bound
    # (0 on the dense pathways; any nonzero value means the run is no longer
    # exact and s_max_headroom/floor must be raised). Under the adaptive
    # two-phase exchange (EngineConfig.adaptive_exchange) this is provably
    # always 0: phase-1 counts size every packet and the bucket ladders top
    # out at the hard population cap.
    overflow: Any = None
    # Scalar f32: cumulative mesh-total wire bytes the exchanges actually
    # shipped (counts + payloads). Static packets add their fixed byte
    # constants; adaptive packets add the bytes of the bucket each window
    # actually selected -- the *measured* counterpart of the static
    # worst-case accounting in Engine.wire_bytes / exchange.wire_report
    # (f32: byte totals overflow int32 long before they lose f32 precision
    # that matters for reporting).
    shipped_bytes: Any = None


def make_update_fn(
    cfg,                       # EngineConfig (duck-typed to avoid a cycle)
    spec,                      # MultiAreaSpec
    dt_ms: float,
    lif_params,
    fused_lif: Callable | None,
) -> Callable:
    """The neuron-update closure shared by both engines.

    ``update(neuron_state, i_in, t, net_view, gids) -> (state', spikes)``
    where ``net_view`` may be the full network (single host) or a shard_map
    view -- the drive uses the view's ``rate_hz``/``alive`` and the *global*
    ids in ``gids``, so any sharding sees bit-identical noise. The drive rate
    is ``rate_hz * (ext_rate_hz / 2.5)`` -- one expression everywhere (the
    engines previously used two algebraically-equal-but-ULP-different forms;
    the shared core makes the cross-engine bit-equality structural instead
    of coincidental).
    """
    drive_scale = spec.ext_rate_hz / 2.5

    def update(neuron_state, i_in, t, net, gids):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, net.rate_hz * drive_scale, dt_ms,
                spec.w_ext,
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, net.alive)
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params)
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, dt_ms)

    return update


def make_window_fn(
    cfg,
    exchange,
    update_fn: Callable,
    *,
    fused_superstep: Callable | None = None,
) -> Callable:
    """Build the ``window(state, net, gids) -> (state', block)`` body.

    ``net``/``gids`` may be full arrays (single-host) or shard_map views
    (distributed) -- all communication is delegated to ``exchange``:

    * ``exchange.cycle(ring, spikes, t, net, gids, inter_now=...)`` runs the
      per-cycle short-range pathway (and, under the conventional schedule,
      the per-cycle long-range exchange too);
    * ``exchange.window_end(ring, block, t0, net, gids, blocked=...)`` runs
      the structure-aware schedule's lumped window-end exchange.

    During a superstep, ``ring`` handed to the cycle hook is the *live
    window buffer* and ``t`` the within-window slot index -- deposits are
    wrap-free by construction (``Network.live_window``), so the same
    delivery code serves both modes.

    ``fused_superstep`` (single-host only) replaces the whole in-window loop
    with the fused Pallas superstep kernel; the lumped exchange still goes
    through the exchange hook.
    """

    def window(state: SimState, net, gids):
        D = net.delay_ratio
        t0 = state.t

        def cycle_state(st: SimState, inter_now: bool):
            """One deliver -> update -> collocate cycle on full SimState."""
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = update_fn(st.neuron, i_in, st.t, net, gids)
            ring, over, shipped = exchange.cycle(
                ring, spikes, st.t, net, gids, inter_now=inter_now)
            return SimState(
                neuron=nstate,
                ring=ring,
                t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
                overflow=st.overflow + over,
                shipped_bytes=st.shipped_bytes + shipped,
            ), spikes

        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence long-range delivery) every cycle.
            def body(st, _):
                return cycle_state(st, inter_now=True)

            return jax.lax.scan(body, state, None, length=D)

        if cfg.use_superstep:
            # One fused D-cycle superstep: the window's D input slots are one
            # contiguous ring block (phase alignment: t0 ≡ 0 mod D and
            # ring_len ≡ 0 mod D), read and cleared once; cycles consume
            # window-static columns of the live buffer ``fut``.
            W = net.live_window
            fut, ring = ring_buffer.open_window(state.ring, t0, D, W)
            neuron, over = state.neuron, state.overflow
            shipped = state.shipped_bytes
            if fused_superstep is not None:
                neuron, block, fut = fused_superstep(neuron, fut, t0)
            elif cfg.superstep_unroll:
                cols = []
                for s in range(D):  # unrolled: s static, slot math vanishes
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    over = over + d_over
                    shipped = shipped + d_ship
                    cols.append(spikes)
                block = jnp.stack(cols)
            else:
                # Scan over the live window: slot access touches only the
                # small [.., W] buffer (wrap-free), never the ring.
                def body(carry, s):
                    neuron, fut, over, shipped = carry
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    return (neuron, fut, over + d_over,
                            shipped + d_ship), spikes

                (neuron, fut, over, shipped), block = jax.lax.scan(
                    body, (neuron, fut, over, shipped),
                    jnp.arange(D, dtype=jnp.int32))
            ring = ring_buffer.merge_window_tail(ring, fut[..., D:], t0 + D)

            # The lumped 'global communication': the whole [D, ...] block in
            # one pass. Every inter-area delay is >= D, so slot (t0+s+d) is
            # strictly in the future of the window -- causal (paper §2.1)
            # and bit-identical to D per-cycle deliveries.
            ring, d_over, d_ship = exchange.window_end(
                ring, block, t0, net, gids, blocked=True)
            return SimState(
                neuron=neuron,
                ring=ring,
                t=t0 + D,
                spike_count=state.spike_count + block.astype(jnp.int32).sum(0),
                overflow=over + d_over,
                shipped_bytes=shipped + d_ship,
            ), block

        # Legacy structure-aware window (the semantic reference for the
        # superstep): per-cycle scan + a window-end replay of D deliveries.
        def body(st, _):
            return cycle_state(st, inter_now=False)

        state, block = jax.lax.scan(body, state, None, length=D)
        ring, d_over, d_ship = exchange.window_end(
            state.ring, block, t0, net, gids, blocked=False)
        return dataclasses.replace(
            state, ring=ring, overflow=state.overflow + d_over,
            shipped_bytes=state.shipped_bytes + d_ship), block

    return window


# ---------------------------------------------------------------------------
# Windowed checkpoint / resume / fault-tolerant run loop
# ---------------------------------------------------------------------------
#
# Checkpoints are only taken at *window boundaries*: there t ≡ 0 (mod D), the
# live window buffer is merged back and the ring's phase alignment
# (ring_len ≡ 0 mod D) is the same invariant a fresh init satisfies, so a
# restored SimState re-enters the superstep exactly where an uninterrupted
# run would. The external drive is a counter-based pure function of
# (seed, t, gid) -- the "RNG state" is fully captured by recording the seed
# and the absolute cycle index t in the manifest -- which is what makes
# resume *bitwise* identical rather than statistically identical.
#
# State arrays are keyed by area in global layout ([A, n_pad, ...]), so a
# checkpoint gathered to host memory is mesh-independent: restoring onto a
# different group count is gather -> (re-order per the elastic reshard plan,
# the identity for contiguous plans) -> re-scatter through the new engine's
# shardings, while make_dist_engine re-cuts the inter receive tables for the
# new mesh via connectivity.shard_inter_tables.


def resume_config_hash(cfg, net, *, exchange: str | None = None):
    """``(hash, payload)`` identifying what a checkpoint can resume into.

    Covers everything that changes the *trajectory* (neuron model, schedule,
    exchange, adaptive flag, delivery backend, seed, packet bounds) plus the
    network invariants a SimState's shapes encode (D, ring length, area
    grid). Deliberately excludes the mesh shape: elastic reshard-restart
    resumes the same config on a different group count. ``exchange``
    overrides ``cfg.exchange`` so launchers can hash the requested exchange
    independently of how it resolves for the current device count.
    """
    payload = {
        "neuron_model": cfg.neuron_model,
        "schedule": cfg.schedule,
        "exchange": cfg.exchange if exchange is None else exchange,
        "adaptive_exchange": bool(cfg.adaptive_exchange),
        "delivery_backend": cfg.backend,
        "seed": int(cfg.seed),
        "s_max_headroom": float(cfg.s_max_headroom),
        "s_max_floor": int(cfg.s_max_floor),
        "delay_ratio": int(net.delay_ratio),
        "ring_len": int(net.ring_len),
        "n_areas": int(net.n_areas),
        "n_pad": int(net.n_pad),
    }
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:16]
    return digest, payload


class SimCheckpointer:
    """Windowed SimState checkpointing through ``checkpoint.AsyncWriter``.

    ``save`` submits the full SimState pytree (neuron state, phase-aligned
    rings, ``t``, ``spike_count``, ``overflow``, ``shipped_bytes``) with a
    manifest recording the window phase, seed (the drive's RNG state), the
    group count the run executed on, and the resume-config hash. The step id
    is the count of *completed windows* (``t // D``), so ``latest_step`` is
    directly "how far did the dead run get".
    """

    def __init__(
        self,
        directory: str,
        engine,
        net,
        *,
        every: int = 50,
        keep: int = 3,
        exchange: str | None = None,
        n_groups: int = 1,
        injector: faults_lib.FaultInjector | None = None,
        retries: int = 3,
        backoff_s: float = 0.05,
    ):
        from repro.checkpoint import manager as ckpt_manager

        self.directory = directory
        self.every = every
        self.delay_ratio = int(engine.delay_ratio)
        self.seed = int(engine.config.seed)
        self.n_groups = int(n_groups)
        self.config_hash, self.config_payload = resume_config_hash(
            engine.config, net, exchange=exchange)
        save_fn = None
        if injector is not None and injector.cfg.ckpt_write_failures > 0:
            save_fn = injector.wrap_save(ckpt_manager.save)
        self.writer = ckpt_manager.AsyncWriter(
            directory, keep=keep, retries=retries, backoff_s=backoff_s,
            save_fn=save_fn)
        self.saved_windows: list[int] = []

    def maybe_save(self, state: SimState, window: int | None = None) -> int | None:
        """Cadence hook: save when the completed-window count hits `every`.

        Pass ``window`` (the caller's host-side completed-window count) to
        keep the off-cadence path free of device syncs -- reading
        ``state.t`` forces a transfer every window, which is exactly the
        overhead budget checkpointing must not spend.
        """
        w = int(state.t) // self.delay_ratio if window is None else int(window)
        if self.every > 0 and w > 0 and w % self.every == 0:
            return self.save(state)
        return None

    def save(self, state: SimState) -> int:
        """Submit a window-boundary checkpoint; returns the step id."""
        t = int(state.t)
        if t % self.delay_ratio != 0:
            raise ValueError(
                f"checkpoint requested mid-window (t={t}, D="
                f"{self.delay_ratio}): only window boundaries keep the ring "
                f"phase alignment a resumed superstep needs")
        w = t // self.delay_ratio
        if self.saved_windows and self.saved_windows[-1] == w:
            return w  # boundary already checkpointed (cadence + preemption)
        ring_len = int(state.ring.shape[-1])
        extra = {
            "kind": "simstate",
            "t": t,
            "window": w,
            "window_phase": 0,
            "delay_ratio": self.delay_ratio,
            "ring_len": ring_len,
            "ring_phase": t % ring_len,
            "seed": self.seed,
            "n_groups": self.n_groups,
            "config_hash": self.config_hash,
            "config": self.config_payload,
        }
        self.writer.submit(w, state, extra=extra)
        self.saved_windows.append(w)
        return w

    @property
    def retry_count(self) -> int:
        return self.writer.retry_count

    def close(self) -> None:
        self.writer.close()


def _permute_areas(state: SimState, order: np.ndarray) -> SimState:
    """Re-order the per-area leading axis of every area-keyed leaf."""
    n_areas = int(state.spike_count.shape[0])
    idx = jnp.asarray(order, dtype=jnp.int32)

    def permute(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and x.shape[0] == n_areas:
            return jnp.take(x, idx, axis=0)
        return x

    return jax.tree.map(permute, state)


def restore_sim(
    directory: str,
    engine,
    net,
    *,
    step: int | None = None,
    exchange: str | None = None,
    n_groups: int = 1,
):
    """Restore a SimState checkpoint into ``engine``, resharding if needed.

    Fails fast -- before any array is materialised -- when the checkpoint's
    resume-config hash differs from the current run's (clear field-by-field
    error instead of a deep shape mismatch), or when its recorded window
    phase is unaligned. If the checkpoint was taken on a different group
    count, the elastic reshard plan
    (:func:`repro.core.partition.elastic_reshard_plan`) validates the
    re-mesh, the per-area state rows are re-ordered per the plan (identity
    for contiguous plans), and the new engine's ``shard_state`` re-scatters
    them over the new mesh. Returns ``(state, info)`` where ``info`` carries
    the manifest, resumed step and reshard accounting.
    """
    from repro.checkpoint import manager as ckpt_manager

    manifest, step = ckpt_manager.read_manifest(directory, step)
    extra = manifest.get("extra", {})
    expect_hash, payload = resume_config_hash(
        engine.config, net, exchange=exchange)
    got_hash = extra.get("config_hash")
    if got_hash is not None and got_hash != expect_hash:
        old = extra.get("config", {})
        diffs = [
            f"  {k}: checkpoint={old.get(k)!r} != run={v!r}"
            for k, v in payload.items() if old.get(k) != v
        ] or [f"  config hash {got_hash} != {expect_hash}"]
        raise ValueError(
            "checkpoint is incompatible with this run's config -- resuming "
            "would not reproduce the uninterrupted trajectory:\n"
            + "\n".join(diffs))
    if extra.get("window_phase", 0) != 0:
        raise ValueError(
            f"checkpoint at step {step} is not window-phase aligned "
            f"(window_phase={extra.get('window_phase')}); only "
            f"window-boundary checkpoints can resume the D-cycle superstep")

    state, _ = ckpt_manager.restore(directory, like=engine.init(), step=step)

    old_groups = int(extra.get("n_groups", n_groups))
    reshard_info = None
    if n_groups != old_groups:
        sizes = np.asarray(net.alive).sum(axis=1).astype(int)
        placement = partition_lib.placement_from_sizes(
            sizes, old_groups, n_pad=int(net.n_pad))
        # Raises (fail fast) when the areas cannot rebalance onto n_groups.
        plan = partition_lib.elastic_reshard_plan(placement, n_groups)
        order = partition_lib.reshard_area_order(plan)
        if not np.array_equal(order, np.arange(order.size)):
            state = _permute_areas(state, order)
        reshard_info = {
            "old_n_groups": old_groups,
            "new_n_groups": n_groups,
            "moved_areas": partition_lib.reshard_moves(plan),
        }
    if engine.shard_state is not None:
        state = engine.shard_state(state)
    return state, {"step": step, "manifest": manifest,
                   "reshard": reshard_info}


@dataclasses.dataclass
class RunResult:
    """Outcome of :func:`run_windows` (also returned inside ``Preempted``)."""

    state: SimState
    spikes_per_window: np.ndarray   # [windows_done] int64
    window_times_s: np.ndarray      # wall per window, incl. injected jitter
    windows_done: int               # completed in THIS call
    injected_sleep_s: float = 0.0


def run_windows(
    engine,
    state: SimState,
    n_windows: int,
    *,
    checkpointer: SimCheckpointer | None = None,
    faults: "faults_lib.FaultConfig | faults_lib.FaultInjector | None" = None,
    on_window: Callable[[int, SimState], None] | None = None,
) -> RunResult:
    """The engines' resilient run loop: windowed, checkpointed, fault-aware.

    ``Engine.run`` is the fast path -- one jitted scan, no host control in
    between. This loop trades one dispatch per window for window-boundary
    control, which is exactly where checkpoints are phase-safe: after every
    window it blocks on the state, submits a checkpoint when the cadence
    fires, injects configured faults, and stops SIGTERM-style on simulated
    preemption (writing a final checkpoint first, then raising
    :class:`repro.core.faults.Preempted` with the result attached as
    ``exc.result``). Works unchanged for the single-host and distributed
    engines -- both assemble their window from this module.

    ``faults`` defaults to ``engine.config.faults``; pass an injector to
    share fault state (e.g. the transient-write budget also wired into the
    checkpointer) across resume legs.
    """
    fault_arg = faults if faults is not None else getattr(
        engine.config, "faults", None)
    if isinstance(fault_arg, faults_lib.FaultInjector):
        injector = fault_arg
    elif fault_arg is not None and fault_arg.any_enabled:
        injector = faults_lib.FaultInjector(
            fault_arg, n_devices=jax.device_count(),
            delay_ratio=engine.delay_ratio)
    else:
        injector = None

    D = int(engine.delay_ratio)
    w_done = int(jax.device_get(state.t)) // D  # absolute windows completed
    spikes: list[int] = []
    times: list[float] = []
    slept = 0.0

    def result() -> RunResult:
        return RunResult(
            state=state,
            spikes_per_window=np.asarray(spikes, dtype=np.int64),
            window_times_s=np.asarray(times, dtype=np.float64),
            windows_done=len(times),
            injected_sleep_s=slept,
        )

    for _ in range(n_windows):
        t0 = time.perf_counter()
        state, block = engine.window(state)
        jax.block_until_ready(state.ring)
        w_done += 1
        if injector is not None:
            slept += injector.sleep(w_done)
        times.append(time.perf_counter() - t0)
        spikes.append(int(np.asarray(jnp.sum(block.astype(jnp.int32)))))
        if checkpointer is not None:
            checkpointer.maybe_save(state, window=w_done)
        if on_window is not None:
            on_window(w_done, state)
        if injector is not None and injector.preempt_now(w_done):
            path = None
            if checkpointer is not None:
                checkpointer.save(state)   # the SIGTERM-grace checkpoint
                checkpointer.close()
                path = checkpointer.directory
            exc = faults_lib.Preempted(w_done, path)
            exc.result = result()
            raise exc
    return result()
