"""The shared window/cycle core: one deliver -> update -> collocate body.

Before this module the single-host engine (``engine.py``) and the distributed
engine (``dist_engine.py``) each carried their own copy of the window
machinery -- per-cycle scan, fused D-cycle superstep, legacy window + lumped
exchange -- ~400 lines of drift-prone duplication. Both engines now assemble
the *same* window body from here, parameterized by an
:class:`repro.core.exchange.Exchange`:

* what happens *inside* a cycle (ring read, neuron update, spike counting)
  and *around* a window (blocked ring open/merge, superstep scan vs unroll,
  the legacy per-cycle reference) lives here, once;
* *how spikes travel* -- single-host identity, dense mesh collectives, or
  connectivity-routed packets -- lives in the exchange object.

The schedules (paper Fig. 3):

* ``conventional``: the long-range pathway is exercised every cycle
  (``inter_now=True`` in the cycle hook);
* ``structure_aware``: long-range spikes accumulate for the whole window and
  travel once, in the window-end hook. Causal because every inter-area delay
  is >= D steps; bit-identical because delivery weights live on the exact
  1/256 grid.

Every variant produces bit-identical spike trains; the equivalence suites
(tests/test_system.py, tests/test_distributed.py, tests/test_exchange.py)
pin that across schedules, backends, exchanges and meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import neuron as neuron_lib
from repro.core import ring_buffer

__all__ = [
    "CONVENTIONAL",
    "STRUCTURE_AWARE",
    "SimState",
    "make_update_fn",
    "make_window_fn",
]

CONVENTIONAL = "conventional"
STRUCTURE_AWARE = "structure_aware"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    neuron: Any               # LIFState or IafState pytree
    ring: jax.Array           # [A, n_pad, R]
    t: jax.Array              # scalar int32, absolute cycle index
    spike_count: jax.Array    # [A, n_pad] int32 cumulative spikes
    # Scalar int32: spikes dropped because a fixed-size packet (event
    # backend, or a routed-exchange edge) exceeded its static s_max bound
    # (0 on the dense pathways; any nonzero value means the run is no longer
    # exact and s_max_headroom/floor must be raised). Under the adaptive
    # two-phase exchange (EngineConfig.adaptive_exchange) this is provably
    # always 0: phase-1 counts size every packet and the bucket ladders top
    # out at the hard population cap.
    overflow: Any = None
    # Scalar f32: cumulative mesh-total wire bytes the exchanges actually
    # shipped (counts + payloads). Static packets add their fixed byte
    # constants; adaptive packets add the bytes of the bucket each window
    # actually selected -- the *measured* counterpart of the static
    # worst-case accounting in Engine.wire_bytes / exchange.wire_report
    # (f32: byte totals overflow int32 long before they lose f32 precision
    # that matters for reporting).
    shipped_bytes: Any = None


def make_update_fn(
    cfg,                       # EngineConfig (duck-typed to avoid a cycle)
    spec,                      # MultiAreaSpec
    dt_ms: float,
    lif_params,
    fused_lif: Callable | None,
) -> Callable:
    """The neuron-update closure shared by both engines.

    ``update(neuron_state, i_in, t, net_view, gids) -> (state', spikes)``
    where ``net_view`` may be the full network (single host) or a shard_map
    view -- the drive uses the view's ``rate_hz``/``alive`` and the *global*
    ids in ``gids``, so any sharding sees bit-identical noise. The drive rate
    is ``rate_hz * (ext_rate_hz / 2.5)`` -- one expression everywhere (the
    engines previously used two algebraically-equal-but-ULP-different forms;
    the shared core makes the cross-engine bit-equality structural instead
    of coincidental).
    """
    drive_scale = spec.ext_rate_hz / 2.5

    def update(neuron_state, i_in, t, net, gids):
        if cfg.neuron_model == "lif":
            drive = neuron_lib.poisson_drive(
                cfg.seed, t, gids, net.rate_hz * drive_scale, dt_ms,
                spec.w_ext,
            )
            if fused_lif is not None:
                return fused_lif(neuron_state, i_in + drive, net.alive)
            return neuron_lib.lif_update(
                neuron_state, i_in + drive, net.alive, lif_params)
        return neuron_lib.ignore_and_fire_update(
            neuron_state, i_in, net.alive, net.rate_hz, dt_ms)

    return update


def make_window_fn(
    cfg,
    exchange,
    update_fn: Callable,
    *,
    fused_superstep: Callable | None = None,
) -> Callable:
    """Build the ``window(state, net, gids) -> (state', block)`` body.

    ``net``/``gids`` may be full arrays (single-host) or shard_map views
    (distributed) -- all communication is delegated to ``exchange``:

    * ``exchange.cycle(ring, spikes, t, net, gids, inter_now=...)`` runs the
      per-cycle short-range pathway (and, under the conventional schedule,
      the per-cycle long-range exchange too);
    * ``exchange.window_end(ring, block, t0, net, gids, blocked=...)`` runs
      the structure-aware schedule's lumped window-end exchange.

    During a superstep, ``ring`` handed to the cycle hook is the *live
    window buffer* and ``t`` the within-window slot index -- deposits are
    wrap-free by construction (``Network.live_window``), so the same
    delivery code serves both modes.

    ``fused_superstep`` (single-host only) replaces the whole in-window loop
    with the fused Pallas superstep kernel; the lumped exchange still goes
    through the exchange hook.
    """

    def window(state: SimState, net, gids):
        D = net.delay_ratio
        t0 = state.t

        def cycle_state(st: SimState, inter_now: bool):
            """One deliver -> update -> collocate cycle on full SimState."""
            i_in, ring = ring_buffer.read_and_clear(st.ring, st.t)
            nstate, spikes = update_fn(st.neuron, i_in, st.t, net, gids)
            ring, over, shipped = exchange.cycle(
                ring, spikes, st.t, net, gids, inter_now=inter_now)
            return SimState(
                neuron=nstate,
                ring=ring,
                t=st.t + 1,
                spike_count=st.spike_count + spikes.astype(jnp.int32),
                overflow=st.overflow + over,
                shipped_bytes=st.shipped_bytes + shipped,
            ), spikes

        if cfg.schedule == CONVENTIONAL:
            # Global exchange (and hence long-range delivery) every cycle.
            def body(st, _):
                return cycle_state(st, inter_now=True)

            return jax.lax.scan(body, state, None, length=D)

        if cfg.use_superstep:
            # One fused D-cycle superstep: the window's D input slots are one
            # contiguous ring block (phase alignment: t0 ≡ 0 mod D and
            # ring_len ≡ 0 mod D), read and cleared once; cycles consume
            # window-static columns of the live buffer ``fut``.
            W = net.live_window
            fut, ring = ring_buffer.open_window(state.ring, t0, D, W)
            neuron, over = state.neuron, state.overflow
            shipped = state.shipped_bytes
            if fused_superstep is not None:
                neuron, block, fut = fused_superstep(neuron, fut, t0)
            elif cfg.superstep_unroll:
                cols = []
                for s in range(D):  # unrolled: s static, slot math vanishes
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    over = over + d_over
                    shipped = shipped + d_ship
                    cols.append(spikes)
                block = jnp.stack(cols)
            else:
                # Scan over the live window: slot access touches only the
                # small [.., W] buffer (wrap-free), never the ring.
                def body(carry, s):
                    neuron, fut, over, shipped = carry
                    neuron, spikes = update_fn(
                        neuron, fut[..., s], t0 + s, net, gids)
                    fut, d_over, d_ship = exchange.cycle(
                        fut, spikes, s, net, gids, inter_now=False)
                    return (neuron, fut, over + d_over,
                            shipped + d_ship), spikes

                (neuron, fut, over, shipped), block = jax.lax.scan(
                    body, (neuron, fut, over, shipped),
                    jnp.arange(D, dtype=jnp.int32))
            ring = ring_buffer.merge_window_tail(ring, fut[..., D:], t0 + D)

            # The lumped 'global communication': the whole [D, ...] block in
            # one pass. Every inter-area delay is >= D, so slot (t0+s+d) is
            # strictly in the future of the window -- causal (paper §2.1)
            # and bit-identical to D per-cycle deliveries.
            ring, d_over, d_ship = exchange.window_end(
                ring, block, t0, net, gids, blocked=True)
            return SimState(
                neuron=neuron,
                ring=ring,
                t=t0 + D,
                spike_count=state.spike_count + block.astype(jnp.int32).sum(0),
                overflow=over + d_over,
                shipped_bytes=shipped + d_ship,
            ), block

        # Legacy structure-aware window (the semantic reference for the
        # superstep): per-cycle scan + a window-end replay of D deliveries.
        def body(st, _):
            return cycle_state(st, inter_now=False)

        state, block = jax.lax.scan(body, state, None, length=D)
        ring, d_over, d_ship = exchange.window_end(
            state.ring, block, t0, net, gids, blocked=False)
        return dataclasses.replace(
            state, ring=ring, overflow=state.overflow + d_over,
            shipped_bytes=state.shipped_bytes + d_ship), block

    return window
