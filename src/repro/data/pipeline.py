"""Deterministic synthetic data pipeline (counter-based, shardable, no I/O).

Tokens are a pure function of (seed, step, batch index, position) via the same
splitmix32 mixer the SNN drive uses -- any host in a multi-host launch can
materialise exactly its own shard without coordination, and restarts resume
bit-identically from the step counter (fault tolerance without a data log).

A light Zipf-ish transform gives the stream enough structure that loss curves
move (pure uniform tokens give a flat CE at log V).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "host_batch"]


def _splitmix32_np(x: np.ndarray) -> np.ndarray:
    x = (x + 0x9E3779B9).astype(np.uint32)
    x = ((x ^ (x >> 16)) * np.uint32(0x21F0AAAD)).astype(np.uint32)
    x = ((x ^ (x >> 15)) * np.uint32(0x735A2D97)).astype(np.uint32)
    return (x ^ (x >> 15)).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Next-token LM stream: labels are tokens shifted by one."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        b, s = self.global_batch, self.seq_len
        idx = (
            np.uint32(self.seed) * np.uint32(0x9E37)
            + np.uint32(step) * np.uint32(b * (s + 1))
            + np.arange(b * (s + 1), dtype=np.uint32)
        ).reshape(b, s + 1)
        u = _splitmix32_np(idx).astype(np.float64) / 2**32
        # Zipf-ish skew: low token ids are exponentially more common.
        toks = np.minimum(
            (-np.log(1 - u * (1 - np.exp(-6.0))) / 6.0 * self.vocab),
            self.vocab - 1,
        ).astype(np.int32)
        # Plant learnable bigram structure: every other token repeats the
        # previous one shifted by a constant (gives CE headroom below log V).
        toks[:, 1::2] = (toks[:, 0:-1:2] + 17) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def host_batch(
    batch: dict[str, np.ndarray],
    mesh: jax.sharding.Mesh | None,
    batch_axes: tuple[str, ...] = ("data",),
    pod_axis: str | None = None,
) -> dict[str, jax.Array]:
    """device_put a host batch with DP sharding (and an optional leading pod
    axis for the hierarchical trainer)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    out: dict[str, jax.Array] = {}
    for k, v in batch.items():
        arr = jnp.asarray(v)
        if mesh is None:
            out[k] = arr
            continue
        if pod_axis is not None:
            n_pods = mesh.shape[pod_axis]
            arr = arr.reshape((n_pods, -1) + arr.shape[1:])
            spec = P(pod_axis, batch_axes, *([None] * (arr.ndim - 2)))
        else:
            spec = P(batch_axes, *([None] * (arr.ndim - 1)))
        out[k] = jax.device_put(arr, NamedSharding(mesh, spec))
    return out
