"""Train/serve step builders: the glue between bundles, optimizer and mesh.

``make_train_artifacts`` produces everything the launcher and the dry-run
need: the step callable(s), parameter/optimizer/batch sharding trees, and
state ShapeDtypeStructs (no allocation). Two training modes:

* ``sync`` (baseline): one parameter replica; gradients all-reduce over every
  DP axis each step (including cross-pod -- the conventional scheme).
* ``hierarchical`` (the paper's technique): per-pod replicas, vmapped local
  steps with zero pod-axis collectives + a separate D-step sync_step. The
  dry-run lowers both and diffs their collective bytes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.common import Bundle, ShapeSpec
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.hierarchical import Hierarchical, HierarchicalConfig

__all__ = ["TrainArtifacts", "make_train_artifacts", "ServeArtifacts",
           "make_serve_artifacts"]


def _sharded_sds(tree_sds: Any, tree_specs: Any, mesh: Mesh | None) -> Any:
    """Attach NamedShardings to ShapeDtypeStructs (dry-run inputs)."""
    if mesh is None:
        return tree_sds
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree_sds, tree_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


@dataclasses.dataclass
class TrainArtifacts:
    step_fn: Callable           # (params, opt_state, batch) -> (p', s', metrics)
    sync_fn: Callable | None    # hierarchical only: (params, sync_state) -> ...
    params_sds: Any             # ShapeDtypeStructs (sharded when mesh given)
    opt_sds: Any
    batch_specs: Any            # PartitionSpec tree for batches
    params_specs: Any
    opt_specs: Any
    sync_sds: Any = None
    sync_specs: Any = None
    hier: Hierarchical | None = None

    def batch_sds(self, bundle: Bundle, shape: ShapeSpec, mesh: Mesh | None):
        specs = bundle.input_specs(shape)
        if self.hier is not None:
            n_pods = self.hier.n_pods
            specs = {
                k: jax.ShapeDtypeStruct(
                    (n_pods, v.shape[0] // n_pods) + v.shape[1:], v.dtype
                )
                for k, v in specs.items()
            }
        return _sharded_sds(specs, self.batch_specs, mesh)


def make_train_artifacts(
    bundle: Bundle,
    opt_cfg: AdamWConfig | None = None,
    mesh: Mesh | None = None,
    *,
    batch_axes: tuple[str, ...] = ("data",),
    fsdp_axis: str | None = "data",
    tp_axis: str = "model",
    hier_cfg: HierarchicalConfig | None = None,
    donate: bool = True,
    n_micro: int = 1,
) -> TrainArtifacts:
    """``n_micro`` > 1 enables gradient accumulation over microbatches: the
    step reshapes the (per-pod) batch to [n_micro, B/n_micro, ...] and scans,
    accumulating f32 gradients -- the standard memory lever that keeps
    activations (and chunked-CE logits) bounded at large global batch."""
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=bundle.moment_dtype)
    model = bundle.model
    p_specs = model.param_pspecs(fsdp=fsdp_axis, tp=tp_axis)

    def grads_of(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(bundle.loss)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch,
        )

        def acc(carry, mb):
            loss_sum, g_sum = carry
            loss, g = jax.value_and_grad(bundle.loss)(params, mb)
            g_sum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_sum, g)
            return (loss_sum + loss, g_sum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if mesh is not None and hier_cfg is None:
            # ZeRO-2-style: keep the f32 accumulation buffer sharded exactly
            # like the parameters -- the 400B-class models' f32 grads would
            # otherwise add 4 bytes/param of *replicated* per-device state.
            zeros = jax.tree.map(
                lambda z, spec: jax.lax.with_sharding_constraint(
                    z, NamedSharding(mesh, spec)),
                zeros, p_specs,
                is_leaf=lambda x: isinstance(x, (jax.Array, P)),
            )
        (loss_sum, g_sum), _ = jax.lax.scan(
            acc, (jnp.float32(0.0), zeros), micro)
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def base_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics}

    params_sds = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0))
    )
    opt_sds = jax.eval_shape(lambda: adamw_init(params_sds, opt_cfg))
    o_specs = {
        "m": p_specs, "v": p_specs, "count": P(),
    }

    if hier_cfg is None:
        # Fully synchronous baseline: batch over all DP axes (incl. pod).
        b_specs = {
            k: P(batch_axes, *([None] * (len(v.shape) - 1)))
            for k, v in bundle.input_specs(
                ShapeSpec("probe", "train", 8, 8)
            ).items()
        }
        step = jax.jit(
            base_step,
            donate_argnums=(0, 1) if donate else (),
        )
        return TrainArtifacts(
            step_fn=step, sync_fn=None,
            params_sds=_sharded_sds(params_sds, p_specs, mesh),
            opt_sds=_sharded_sds(opt_sds, o_specs, mesh),
            batch_specs=b_specs, params_specs=p_specs, opt_specs=o_specs,
        )

    # ----- hierarchical (paper technique): per-pod replicas ------------------
    n_pods = mesh.shape[hier_cfg.pod_axis] if mesh is not None else 2
    hier = Hierarchical(hier_cfg, n_pods, mesh, param_specs=p_specs)

    pp_specs = hier.pspecs(p_specs)
    po_specs = {"m": pp_specs, "v": pp_specs,
                "count": P(hier_cfg.pod_axis)}
    pb_specs = {
        k: P(hier_cfg.pod_axis, batch_axes, *([None] * (len(v.shape) - 1)))
        for k, v in bundle.input_specs(ShapeSpec("probe", "train", 8, 8)).items()
    }
    pparams_sds = jax.eval_shape(hier.replicate, params_sds)
    popt_sds = jax.eval_shape(hier.replicate, opt_sds)
    # per-pod count is a vector [n_pods]; replicate() handles it uniformly.

    local_step = jax.jit(
        hier.local_step(base_step), donate_argnums=(0, 1) if donate else ()
    )
    sync_sds = jax.eval_shape(hier.init_sync_state, params_sds)
    sync_specs = {"anchor": p_specs}
    if hier_cfg.compression != "none":
        sync_specs["ef"] = hier.pspecs(p_specs)
    sync_fn = jax.jit(hier.sync_step, donate_argnums=(0,) if donate else ())

    return TrainArtifacts(
        step_fn=local_step, sync_fn=sync_fn,
        params_sds=_sharded_sds(pparams_sds, pp_specs, mesh),
        opt_sds=_sharded_sds(popt_sds, po_specs, mesh),
        batch_specs=pb_specs, params_specs=pp_specs, opt_specs=po_specs,
        sync_sds=_sharded_sds(sync_sds, sync_specs, mesh),
        sync_specs=sync_specs,
        hier=hier,
    )


# ---------------------------------------------------------------------------
# serving


@dataclasses.dataclass
class ServeArtifacts:
    prefill_fn: Callable        # (params, batch) -> (logits, serve_state)
    decode_fn: Callable         # (params, serve_state, tokens, idx) -> (logits, state)
    params_sds: Any
    params_specs: Any
    state_sds: Any              # serve_state ShapeDtypeStructs
    state_specs: Any
    batch_axes: tuple[str, ...] | None
    token_spec: P


def make_serve_artifacts(
    bundle: Bundle,
    shape: ShapeSpec,
    mesh: Mesh | None = None,
    *,
    fsdp_axis: str | None = "data",
    tp_axis: str = "model",
    cache_dtype=jnp.bfloat16,
) -> ServeArtifacts:
    """Build prefill/decode callables + sharding/shape metadata for a cell.

    Cache layout policy: decode shards the batch over the DP axes; the
    ``long_500k`` cell (batch=1) shards the cache *sequence* over the TP axis
    instead (documented in DESIGN.md §4).
    """
    model = bundle.model
    b, s = shape.global_batch, shape.seq_len
    long_context = shape.name == "long_500k"

    # KV heads of the arch (None for attention-free archs).
    cfg = bundle.cfg
    n_kv = getattr(cfg, "n_kv", None)
    if n_kv is None and hasattr(cfg, "backbone"):
        n_kv = cfg.backbone.n_kv
    if n_kv is None and hasattr(cfg, "n_heads") and bundle.family == "audio":
        n_kv = cfg.n_heads
    tp_size = mesh.shape[tp_axis] if mesh is not None else 1

    batch_axes: tuple[str, ...] | None
    head_axis: str | None = None
    if long_context:
        # batch=1: the attention caches shard their *sequence* over TP.
        batch_axes, seq_axis = None, tp_axis
    else:
        batch_axes = (("pod", "data") if mesh is not None
                      and "pod" in mesh.axis_names else ("data",))
        if mesh is None:
            batch_axes = None
        # Cache second-tier sharding: KV heads over TP when they divide the
        # axis (gemma3/whisper/zamba2), else the sequence (kv<16 archs) --
        # decode_32k per-device cache stays inside the HBM budget either way.
        if n_kv is not None and tp_size > 1:
            if n_kv % tp_size == 0:
                head_axis, seq_axis = tp_axis, None
            else:
                head_axis, seq_axis = None, tp_axis
        else:
            seq_axis = None

    is_audio = bundle.family == "audio"
    is_vlm = bundle.family == "vlm"

    cache_specs = model.cache_pspecs(
        batch_axes=batch_axes, seq_axis=seq_axis, head_axis=head_axis
    )
    state_specs: dict = {"cache": cache_specs}
    if is_audio:
        state_specs["enc_out"] = P(batch_axes, None, None)

    def _constrain_state(state):
        if mesh is None:
            return state
        return jax.tree.map(
            lambda x, spec: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec)),
            state, state_specs,
            is_leaf=lambda x: isinstance(x, (jax.Array, P)),
        )

    def prefill(params, batch):
        cache = model.init_cache(b, s, cache_dtype)
        if mesh is not None:
            cache = jax.tree.map(
                lambda x, spec: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec)),
                cache, cache_specs,
                is_leaf=lambda x: isinstance(x, (jax.Array, P)),
            )
        if is_audio:
            enc_out = model.encode(params, batch["frames"])
            logits, cache = model.forward_with_cache(
                params, batch["tokens"], cache, jnp.int32(0), enc_out=enc_out,
                last_only=True,
            )
            return logits, {"cache": cache, "enc_out": enc_out}
        if is_vlm:
            logits, cache = model.forward_with_cache(
                params, batch["tokens"], cache, jnp.int32(0),
                patch_embeds=batch["patch_embeds"], last_only=True,
            )
            return logits, {"cache": cache}
        logits, cache = model.forward_with_cache(
            params, batch["tokens"], cache, jnp.int32(0), last_only=True
        )
        return logits, {"cache": cache}

    def decode(params, serve_state, tokens, cache_index):
        kwargs = {"enc_out": serve_state["enc_out"]} if is_audio else {}
        logits, cache = model.forward_with_cache(
            params, tokens, serve_state["cache"], cache_index, **kwargs
        )
        return logits, {**serve_state, "cache": cache}

    params_sds = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    p_specs = model.param_pspecs(fsdp=fsdp_axis, tp=tp_axis)

    cache_sds = jax.eval_shape(lambda: model.init_cache(b, s, cache_dtype))
    state_sds: dict[str, Any] = {"cache": cache_sds}
    if is_audio:
        state_sds["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )

    if mesh is not None:
        state_out = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), state_specs,
            is_leaf=lambda x: isinstance(x, P))
        logits_out = NamedSharding(mesh, P(batch_axes, None, None))
        prefill_jit = jax.jit(prefill, out_shardings=(logits_out, state_out))
        decode_jit = jax.jit(decode, donate_argnums=(1,),
                             out_shardings=(logits_out, state_out))
    else:
        prefill_jit = jax.jit(prefill)
        decode_jit = jax.jit(decode, donate_argnums=(1,))
    return ServeArtifacts(
        prefill_fn=prefill_jit,
        decode_fn=decode_jit,
        params_sds=_sharded_sds(params_sds, p_specs, mesh),
        params_specs=p_specs,
        state_sds=_sharded_sds(state_sds, state_specs, mesh),
        state_specs=state_specs,
        batch_axes=batch_axes,
        token_spec=P(batch_axes, None),
    )
