"""Pipeline parallelism: GPipe-style microbatch streaming over a 'pipe' axis.

Each device (or device group) holds one *stage* -- a contiguous slice of the
layer stack -- and activations stream stage-to-stage with
``lax.ppermute`` (a neighbour collective, the cheapest in the ICI mesh).
The schedule is the classic GPipe fill-drain: with S stages and M
microbatches the bubble fraction is (S-1)/(M+S-1).

This composes with the paper's two-tier idea: stages are the *fast* tier
(neighbour permutes every step), the optimizer's cross-pod sync stays on the
slow tier. It is exposed as an optional wrapper (the 40-cell dry-run uses
DP/TP/EP/SP; PP has its own tests and can be enabled per config).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,        # pytree, leaves [S, ...] (stage-stacked)
    microbatches: jax.Array,  # [M, mb, ...] inputs (logically on stage 0)
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run ``y_m = stages_{S-1} ∘ ... ∘ stages_0 (x_m)`` for every microbatch.

    Returns [M, mb, ...] outputs (logically on the last stage). Correctness
    contract: identical to applying the stages sequentially (tested in an
    8-device subprocess against the unsharded reference).
    """
    n_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    steps = m + n_stages - 1

    def run(params_local, mb_local):
        # params_local: leaves [1, ...] (this stage); mb_local: [M, mb, ...]
        # on every device (replicated input; stage 0 is the consumer).
        params_me = jax.tree.map(lambda x: x[0], params_local)
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(mb_local[0])
        out = jnp.zeros_like(mb_local)

        def step(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t (if any) -- others keep their buf
            feed = jax.lax.dynamic_index_in_dim(
                mb_local, jnp.clip(t, 0, m - 1), keepdims=False)
            x = jnp.where((idx == 0) & (t < m), feed, buf)
            y = stage_fn(params_me, x)
            # last stage stores its result at position t - (S-1)
            slot = jnp.clip(t - (n_stages - 1), 0, m - 1)
            store = (idx == n_stages - 1) & (t >= n_stages - 1)
            out = jax.lax.cond(
                store,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, slot, axis=0),
                lambda o: o,
                out,
            )
            # shift activations to the next stage (neighbour permute)
            buf = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(step, (buf, out),
                                     jnp.arange(steps, dtype=jnp.int32))
        # replicate the collected outputs from the last stage to all devices
        # (ppermute is a strict permutation; broadcast = psum of a mask).
        out = jax.lax.psum(
            jnp.where(idx == n_stages - 1, out, jnp.zeros_like(out)), axis
        )
        return out

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        run, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, microbatches)
