"""Version-compat shims for the jax API surface this repo relies on.

The repo targets both the jax that ships in the pinned container
(0.4.x, where ``shard_map`` lives in ``jax.experimental`` and takes a
``check_rep`` flag) and newer releases (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``). Everything else imports these names
from here so the divergence is confined to one module.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map", "set_mesh"]

try:  # jax >= 0.6: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map

    _REP_KWARG = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _REP_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check flag name papered over."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_REP_KWARG: check_vma},
    )


def set_mesh(mesh):
    """``jax.set_mesh`` where available, else the legacy global-mesh context.

    On jax 0.4.x entering the ``Mesh`` object itself installs it as the
    ambient physical mesh, which is what pjit/shard_map consult.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext() if mesh is None else mesh
