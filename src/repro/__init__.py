"""Structure-aware brain-scale SNN simulation in JAX -- public surface.

The stable API, re-exported from the subpackages:

* :func:`make_simulation` -- the one engine constructor (single-host or
  distributed, dispatching on ``mesh``); :class:`EngineConfig` configures
  it and :class:`ConfigError` reports every broken config rule at once.
* :func:`run_windows` / :class:`SimCheckpointer` -- the windowed run loop
  with checkpoint/resume and the serving layer's per-block streaming hook.
* ``SimServer`` / ``serve_simulation`` (:mod:`repro.launch.serve`) -- the
  batched multi-tenant serving layer; loaded lazily so ``import repro``
  stays light.

Everything else (``repro.core.*``, ``repro.launch.*``, ...) remains
importable but is not part of the stability contract; the legacy
``make_engine`` / ``make_dist_engine`` constructors are deprecated shims.
"""

from __future__ import annotations

from repro.core import (
    AreaSpec,
    ConfigError,
    ConfigViolation,
    Engine,
    EngineConfig,
    MultiAreaSpec,
    Network,
    SimCheckpointer,
    SimState,
    build_network,
    make_simulation,
    mam_benchmark_spec,
    mam_spec,
    run_windows,
)

__all__ = [
    "AreaSpec",
    "ConfigError",
    "ConfigViolation",
    "Engine",
    "EngineConfig",
    "MultiAreaSpec",
    "Network",
    "SimCheckpointer",
    "SimState",
    "build_network",
    "make_simulation",
    "mam_benchmark_spec",
    "mam_spec",
    "run_windows",
    "SimServer",
    "TrialRequest",
    "serve_simulation",
]

_LAZY = {"SimServer", "TrialRequest", "serve_simulation"}


def __getattr__(name: str):
    # The serving layer pulls in threading/signal machinery; load it only
    # when asked for so `import repro` stays a core-only import.
    if name in _LAZY:
        from repro.launch import serve as _serve

        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
