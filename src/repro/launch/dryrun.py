import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before ANY other import (jax locks the device
# count at first initialisation). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the arch bundle and the train/prefill/decode artifacts,
  3. ``jit(...).lower(ShapeDtypeStructs).compile()`` -- no allocation,
  4. records ``memory_analysis()`` (proves the cell fits the per-chip HBM),
     ``cost_analysis()`` (FLOPs/bytes for §Roofline), and the collective
     statistics parsed from the compiled HLO (§Roofline's third term),
  5. derives the three roofline terms against TPU v5e constants.

Also lowers the paper's own workload (``--arch mam-snn``): the distributed
SNN engine window at full MAM scale, under both the conventional and the
structure-aware schedule -- the collective-bytes/op-count delta between the
two IS the paper's claim, visible in compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out dryrun_results.json
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k \
      --mesh single --hierarchical
"""

import argparse
import dataclasses
import json
import math
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

from repro.configs.common import SHAPES, ShapeSpec
from repro.configs.registry import arch_skips, get_arch, list_archs
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.optim.hierarchical import HierarchicalConfig
from repro.train.steps import make_serve_artifacts, make_train_artifacts

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

SNN_ARCH = "mam-snn"


def _cost_get(cost: dict, key: str) -> float:
    try:
        return float(cost.get(key, 0.0))
    except AttributeError:
        return 0.0


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   n_devices: int, total: bool) -> dict:
    """Three roofline terms in seconds (per device).

    ``total=True`` when flops/bytes are whole-program totals (divide by
    chips); False when they are already per-device.
    """
    div = n_devices if total else 1
    return {
        "compute_s": flops / div / PEAK_FLOPS,
        "memory_s": hbm_bytes / div / HBM_BW,
        "collective_s": wire_bytes / ICI_BW,
    }


def _dominant(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def modelled_hbm_gib(row: dict) -> float:
    """Per-device footprint (GiB) from XLA's memory_analysis on the row."""
    mem = row.get("memory_analysis") or {}
    return (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
            + mem.get("output_bytes", 0)) / 2**30


def enforce_hbm_budget(row: dict, budget_gib: float | None) -> dict:
    """Fail-fast HBM gate: the modelled per-device footprint must fit.

    Flips an OK row to FAIL (which trips the dry run's nonzero exit) when
    XLA's own memory analysis says the compiled cell cannot live within
    ``budget_gib`` per device -- the bound is recorded on the row either way
    so the JSON stays auditable.
    """
    if not budget_gib or row.get("status") != "OK":
        return row
    got = modelled_hbm_gib(row)
    row["hbm_gib_modelled"] = round(got, 3)
    row["hbm_gib_budget"] = budget_gib
    if got > budget_gib:
        row["status"] = (f"FAIL(HBM: modelled {got:.2f} GiB/device exceeds "
                         f"the --hbm-gib {budget_gib:g} budget)")
    return row


def _analyze(lowered, compiled, n_devices: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Trip-count-aware accounting: XLA's own cost_analysis counts each while
    # body once, which under-counts scan-stacked layers by ~L x n_micro; the
    # hlo_stats parser multiplies per-computation costs by loop trip counts.
    stats = analyze_hlo(hlo, n_devices)
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    # The SPMD-partitioned module is per-device: stats are per-device.
    # Memory term uses the *fused* bound (elementwise chains VMEM-resident,
    # as on TPU); the naive every-op bound is kept alongside in the row.
    terms = roofline_terms(stats.flops, stats.hbm_bytes_fused,
                           stats.total_wire_bytes, n_devices, total=False)
    terms["memory_naive_s"] = stats.hbm_bytes / HBM_BW
    return {
        "flops_per_device": stats.flops,
        "hbm_bytes_per_device": stats.hbm_bytes_fused,
        "hbm_bytes_naive_per_device": stats.hbm_bytes,
        "xla_cost_flops_raw": _cost_get(cost, "flops"),
        "memory_analysis": mem_info,
        "collectives": stats.as_dict(),
        "roofline": terms,
        "dominant": _dominant(terms),
    }


def model_flops(bundle, shape: ShapeSpec) -> float:
    """6 * N_active * tokens (train) / 2 * N_active * tokens (inference)."""
    n_active = bundle.cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens


def dryrun_lm_cell(arch_id: str, shape_name: str, multi_pod: bool,
                   hierarchical: bool) -> dict:
    shape = SHAPES[shape_name]
    skip = arch_skips(arch_id).get(shape_name)
    row: dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": ("hierarchical" if hierarchical else "sync"),
    }
    if skip:
        row["status"] = f"SKIP({skip})"
        return row

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    dp_axes = (("pod", "data") if multi_pod and not (hierarchical
               and shape.kind == "train") else ("data",))
    # Activation/logits sharding constraints need the DP axis names; the
    # batch=1 long-context cell cannot shard its batch at all.
    act_axes = None if shape.name == "long_500k" else dp_axes
    # Attention activation layout: head-parallel when KV heads divide the
    # 16-way TP axis, else context-parallel (see models/layers.py).
    probe = get_arch(arch_id)
    n_kv = getattr(probe.cfg, "n_kv", None)
    if n_kv is None and hasattr(probe.cfg, "backbone"):
        n_kv = probe.cfg.backbone.n_kv
    if n_kv is None and probe.family == "audio":
        n_kv = probe.cfg.n_heads
    attn_mode = None
    if n_kv is not None:
        attn_mode = "heads" if n_kv % 16 == 0 else "seq"
    bundle = get_arch(arch_id, act_batch_axes=act_axes, attn_sharding=attn_mode)
    # FSDP policy: parameters below ~1B replicate (per-microbatch ZeRO-3
    # gathers cost more than they save); larger models shard over 'data'.
    fsdp_axis = "data" if bundle.cfg.param_count() >= 1e9 else None
    t0 = time.time()

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            hier_cfg = None
            if hierarchical and multi_pod:
                hier_cfg = HierarchicalConfig(sync_every=10, compression="int8")
            # Microbatch so each accumulation slice is one sample per DP
            # shard (the memory-minimal production setting).
            n_dp = math.prod(mesh.shape[a] for a in dp_axes)
            per_replica = shape.global_batch // (
                n_dp * (mesh.shape["pod"] if hier_cfg is not None else 1))
            n_micro = max(1, per_replica)
            art = make_train_artifacts(
                bundle, mesh=mesh,
                batch_axes=dp_axes,
                fsdp_axis=fsdp_axis,
                hier_cfg=hier_cfg,
                n_micro=n_micro,
            )
            batch_sds = art.batch_sds(bundle, shape, mesh)
            lowered = art.step_fn.lower(art.params_sds, art.opt_sds, batch_sds)
            compiled = lowered.compile()
            row.update(_analyze(lowered, compiled, n_devices))
            if hier_cfg is not None and art.sync_fn is not None:
                lowered_s = art.sync_fn.lower(art.params_sds, art.sync_sds)
                compiled_s = lowered_s.compile()
                row["sync_step"] = _analyze(lowered_s, compiled_s, n_devices)
                row["sync_every"] = hier_cfg.sync_every
        elif shape.kind == "prefill":
            art = make_serve_artifacts(bundle, shape, mesh, fsdp_axis=fsdp_axis)
            batch = bundle.input_specs(shape)
            del batch["labels"]
            b_specs = {k: P(art.batch_axes, *([None] * (len(v.shape) - 1)))
                       for k, v in batch.items()}
            batch_sds = {
                k: jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=NamedSharding(mesh, b_specs[k]))
                for k, v in batch.items()
            }
            lowered = art.prefill_fn.lower(art.params_sds, batch_sds)
            compiled = lowered.compile()
            row.update(_analyze(lowered, compiled, n_devices))
        else:  # decode
            art = make_serve_artifacts(bundle, shape, mesh, fsdp_axis=fsdp_axis)
            tok_sds = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=NamedSharding(mesh, art.token_spec))
            extra = {}
            if bundle.family == "audio":
                pass  # enc_out already part of state_sds
            idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = art.decode_fn.lower(
                art.params_sds, art.state_sds, tok_sds, idx_sds)
            compiled = lowered.compile()
            row.update(_analyze(lowered, compiled, n_devices))

    row["status"] = "OK"
    row["compile_s"] = round(time.time() - t0, 1)
    mf = model_flops(bundle, shape)
    row["model_flops_total"] = mf
    hlo_total = row["flops_per_device"] * n_devices
    row["useful_flops_ratio"] = mf / hlo_total if hlo_total else 0.0
    return row


def verify_inter_table_bounds(
    n_shards: int = 2, subgroup: int = 2, seed: int = 12
) -> dict:
    """Laptop-scale instantiated-shard check behind the production SDS rows.

    The production ``--snn`` cells price their inter tables from
    ``network_sds`` width *bounds* (nothing is allocated). This builds a
    small real network, cuts the same inbound slices
    (``shard_inter_tables(mode='group', subgroup=...)``), and asserts the
    SDS bound brackets the instantiated bytes: same leading shard/lane
    axes, bound width >= the data-dependent width, and the instantiated
    bytes within the bound's padding slack. A violated bound FAILs the dry
    run -- the production memory claims are only as good as these bounds.
    """
    from repro.core.areas import mam_benchmark_spec
    from repro.core.connectivity import (
        build_network, network_sds, shard_inter_tables, slice_intra_tables)

    spec = mam_benchmark_spec(n_areas=4, n_per_area=64, k_intra=8, k_inter=12)
    row: dict[str, Any] = {
        "arch": SNN_ARCH, "shape": "table_bounds",
        "mesh": f"{n_shards}x{subgroup}", "mode": "verify",
    }
    # outgoing="intra" skips the outgoing *inter* inversion: the inbound
    # slices are cut from the incoming tensors (shard_inter_tables) and the
    # intra check only needs tgt_intra, so the dense [A, n_pad, K_out_e]
    # tables would be built, held, and never read.
    net = build_network(spec, seed=seed, size_multiple=8, outgoing="intra")
    sds = network_sds(spec, size_multiple=8, outgoing=True,
                      inter_shards=n_shards, subgroup=subgroup)
    cut = shard_inter_tables(net, n_shards, mode="group", subgroup=subgroup)
    syn_b = net.bytes_per_synapse()
    got = cut.tgt_inter_in
    bound = sds.tgt_inter_in
    if bound.shape[:2] != got.shape[:2]:
        raise AssertionError(
            f"SDS shard/lane axes {bound.shape[:2]} != instantiated "
            f"{got.shape[:2]}")
    if bound.shape[-1] < got.shape[-1]:
        raise AssertionError(
            f"SDS width bound {bound.shape[-1]} < instantiated "
            f"{got.shape[-1]}: the production rows under-price the tables")
    if cut.dout_inter_in.dtype != sds.dout_inter_in.dtype:
        raise AssertionError(
            f"SDS delay dtype {sds.dout_inter_in.dtype} != instantiated "
            f"{cut.dout_inter_in.dtype}")
    # Same bracket for the subgroup-sliced outgoing intra tables (the
    # other table the production rows price via a width bound).
    cut_i = slice_intra_tables(net, subgroup)
    if sds.tgt_intra.shape[:2] != cut_i.tgt_intra.shape[:2]:
        raise AssertionError(
            f"SDS intra lane axis {sds.tgt_intra.shape[:2]} != "
            f"instantiated {cut_i.tgt_intra.shape[:2]}")
    if sds.tgt_intra.shape[-1] < cut_i.tgt_intra.shape[-1]:
        raise AssertionError(
            f"SDS intra width bound {sds.tgt_intra.shape[-1]} < "
            f"instantiated {cut_i.tgt_intra.shape[-1]}: the production "
            f"rows under-price the intra tables")
    if cut_i.dout_intra.dtype != sds.dout_intra.dtype:
        raise AssertionError(
            f"SDS intra delay dtype {sds.dout_intra.dtype} != "
            f"instantiated {cut_i.dout_intra.dtype}")
    # Bytes of ONE device's slice, modelled vs instantiated.
    per_dev_model = int(np.prod(bound.shape[2:])) * syn_b
    per_dev_real = int(np.prod(got.shape[2:])) * syn_b
    row["bytes_per_device_modelled"] = per_dev_model
    row["bytes_per_device_instantiated"] = per_dev_real
    row["bound_slack"] = round(per_dev_model / max(per_dev_real, 1), 3)
    row["intra_bound_slack"] = round(
        sds.tgt_intra.shape[-1] / max(cut_i.tgt_intra.shape[-1], 1), 3)
    row["status"] = "OK"
    return row


def construction_cost_row(
    scale: float = 1.0, min_reduction: float = 4.0
) -> dict:
    """Modelled host peak RSS of constructing the production network.

    Prices the host-build path (``build_network(outgoing=True)`` + the two
    shard cuts: every global tensor plus all S x subgroup inbound slices
    resident in one process) against the sharded build (plan pass + one
    shard's draws, temporaries and output slice). Pure byte arithmetic from
    the same deterministic width bounds as the SDS rows -- nothing is
    allocated. At ``scale=1`` the reduction must clear ``min_reduction``
    (the PR's acceptance bar) or the row FAILs the dry run.
    """
    from repro.core.areas import mam_spec
    from repro.core.connectivity import construction_cost_model

    row: dict[str, Any] = {
        "arch": SNN_ARCH, "shape": f"mam_x{scale:g}_build",
        "mesh": "16x16", "mode": "construction",
    }
    spec = mam_spec(scale=scale)
    # Production structure-aware cut: 16 area groups x 16-lane subgroups.
    cm = construction_cost_model(spec, n_shards=16, subgroup=16,
                                 size_multiple=16)
    row["build_bytes_host_modelled"] = cm["build_bytes_host_modelled"]
    row["build_bytes_shard_modelled"] = cm["build_bytes_shard_modelled"]
    row["build_gib_host_modelled"] = round(
        cm["build_bytes_host_modelled"] / 2**30, 2)
    row["build_gib_shard_modelled"] = round(
        cm["build_bytes_shard_modelled"] / 2**30, 2)
    row["build_reduction"] = round(cm["reduction"], 1)
    if cm["reduction"] < min_reduction:
        row["status"] = (
            f"FAIL(construction: modelled host-RSS reduction "
            f"{cm['reduction']:.1f}x below the {min_reduction:g}x bar)")
    else:
        row["status"] = "OK"
    return row


def measure_build_rss(
    n_areas: int = 8, n_per_area: int = 4096,
    k_intra: int = 256, k_inter: int = 256,
    n_shards: int = 4, subgroup: int = 2, seed: int = 12,
) -> dict:
    """Measured host peak RSS: host build vs sharded build, real processes.

    Forks two fresh interpreters (so each path's ``ru_maxrss`` is its own,
    not inherited from this process's jax arena) over a mid-size network
    chosen large enough that table bytes dominate the ~quarter-GiB import
    baseline. Child A runs the host path -- global build + both shard cuts;
    child B runs the sharded path -- plan pass, then every (shard, lane)'s
    tables built one at a time (the per-process peak a real shard pays).
    The sharded peak must come in under the host peak or the row FAILs.
    """
    import subprocess
    import sys

    row: dict[str, Any] = {
        "arch": SNN_ARCH, "shape": "build_rss",
        "mesh": f"{n_shards}x{subgroup}", "mode": "construction",
    }
    common = (
        "import resource, sys\n"
        "from repro.core.areas import mam_benchmark_spec\n"
        "spec = mam_benchmark_spec(n_areas=%d, n_per_area=%d, k_intra=%d, "
        "k_inter=%d)\n" % (n_areas, n_per_area, k_intra, k_inter)
    )
    host_src = common + (
        "from repro.core.connectivity import (\n"
        "    build_network, shard_inter_tables, slice_intra_tables)\n"
        "net = build_network(spec, seed=%d, outgoing='intra')\n"
        "cut = shard_inter_tables(net, %d, mode='group', subgroup=%d)\n"
        "cut = slice_intra_tables(cut, %d)\n"
        "print(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)\n"
        % (seed, n_shards, subgroup, subgroup)
    )
    a_loc = n_areas // n_shards
    shard_src = common + (
        "from repro.core.connectivity import (\n"
        "    sharded_build_plan, build_shard_tables, build_lane_intra_tables)\n"
        "plan = sharded_build_plan(spec, %d, %d, mode='group', subgroup=%d)\n"
        "for s in range(%d):\n"
        "    areas = list(range(s * %d, (s + 1) * %d))\n"
        "    for lane in range(%d):\n"
        "        t = build_shard_tables(spec, %d, s, plan=plan, lane=lane)\n"
        "        del t\n"
        "        ti = build_lane_intra_tables(spec, %d, areas, lane, "
        "plan=plan)\n"
        "        del ti\n"
        "print(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)\n"
        % (seed, n_shards, subgroup, n_shards, a_loc, a_loc, subgroup,
           seed, seed)
    )

    def _peak_kib(src: str) -> int:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)  # no forced 512-device init in children
        out = subprocess.run(
            [sys.executable, "-c", src], capture_output=True, text=True,
            env=env, check=True)
        return int(out.stdout.strip().splitlines()[-1])

    t0 = time.time()
    host_kib = _peak_kib(host_src)
    t1 = time.time()
    shard_kib = _peak_kib(shard_src)
    t2 = time.time()
    row["host_peak_rss_mib"] = round(host_kib / 1024, 1)
    row["sharded_peak_rss_mib"] = round(shard_kib / 1024, 1)
    row["rss_reduction"] = round(host_kib / max(shard_kib, 1), 2)
    row["host_build_s"] = round(t1 - t0, 1)
    row["sharded_build_s"] = round(t2 - t1, 1)
    if shard_kib >= host_kib:
        row["status"] = (
            f"FAIL(build RSS: sharded peak {shard_kib} KiB >= host peak "
            f"{host_kib} KiB -- the host-free build saved nothing)")
    else:
        row["status"] = "OK"
    return row


def dryrun_snn_cell(
    schedule: str,
    multi_pod: bool,
    scale: float = 1.0,
    backend: str = "",
    exchange: str = "",
    shard_tables: bool = True,
    subgroup_tables: bool = True,
    adaptive: bool = False,
) -> dict:
    """Lower the distributed SNN engine window at production MAM scale.

    ``backend`` selects the delivery backend (``event`` lowers the sparse
    id-packet paths -- the outgoing tables come from
    ``network_sds(outgoing=True)``, closing the dry-run gap); ``exchange``
    selects the global pathway (``routed`` lowers the ppermute rounds; with
    no spec-level adjacency the MAM graph is all-to-all, so routing skips
    nothing but the per-edge packets still lower). ``shard_tables``
    (default) lowers the sharded inbound inter receive tables
    (``network_sds(inter_shards=...)`` -- per-device table bytes divided by
    ~the shard count); False keeps the replicated-table baseline the
    sharded layout is measured against. The per-device table bytes and
    receive-side work land in ``row["inter_tables"]``. ``adaptive`` lowers
    the two-phase bucket-ladder exchange (count collective + lax.switch
    over pre-compiled payload sizes); ``row["wire_bytes_window"]`` then
    carries both the static worst case and the adaptive byte model, so the
    dry-run rows stay honest about what an adaptive run would actually
    ship.
    """
    from repro.core.areas import mam_spec
    from repro.core.connectivity import area_adjacency, network_sds
    from repro.core.dist_engine import network_pspecs, state_pspecs
    from repro.core.factory import make_simulation
    from repro.core.engine import EngineConfig
    from repro.core import delivery as delivery_lib
    from repro.core import exchange as exchange_lib
    from repro.core import neuron as neuron_lib

    label = "_".join(x for x in (schedule, backend, exchange) if x)
    if not shard_tables:
        label += "_reptables"
    elif not subgroup_tables:
        label += "_nosub"
    if adaptive:
        label += "_adaptive"
    row: dict[str, Any] = {
        "arch": SNN_ARCH, "shape": f"mam_x{scale:g}_{label}",
        "mesh": "2x16x16" if multi_pod else "16x16", "mode": schedule,
    }
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    spec = mam_spec(scale=scale)
    # pad so both the 16-way subgroup and (for conventional) all 512 divide
    mult = 512 if schedule == "conventional" else 16
    needs_outgoing = backend == "event" or exchange == "routed"
    gsz = mesh.shape["model"]
    n_groups = n_devices // gsz
    n_shards = n_groups if schedule == "structure_aware" else n_devices
    shard_mode = "group" if schedule == "structure_aware" else "window"
    # The subgroup (window-within-group) slice only exists under the
    # structure-aware group cut; the conventional "window" cut is already
    # per-device.
    sub = (gsz if shard_tables and subgroup_tables
           and schedule == "structure_aware" else 1)
    net_sds = network_sds(
        spec, size_multiple=mult, outgoing=needs_outgoing,
        inter_shards=(n_shards if needs_outgoing and shard_tables else 0),
        inter_shard_mode=shard_mode, subgroup=sub)
    cfg = EngineConfig(neuron_model="lif", schedule=schedule,
                       delivery_backend=backend, exchange=exchange,
                       shard_inter_tables=shard_tables,
                       subgroup_inter_tables=subgroup_tables,
                       adaptive_exchange=adaptive)
    eng = make_simulation(spec, cfg, net=net_sds, mesh=mesh)
    if needs_outgoing and spec.k_inter > 0:
        # Static per-device receive-table accounting, replicated vs sharded
        # (the tentpole's memory claim, independent of XLA's analysis).
        routing = None
        if exchange == "routed":
            routing = exchange_lib.build_routing(
                area_adjacency(net_sds, spec), n_groups,
                exp_area_spikes=delivery_lib.expected_area_spikes(net_sds),
                headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
        row["inter_tables"] = exchange_lib.priced_inter_table_report(
            net_sds, n_groups=n_groups, gsz=gsz, schedule=schedule,
            headroom=cfg.s_max_headroom, floor=cfg.s_max_floor,
            routing=routing, subgroup=sub)
    if needs_outgoing and net_sds.tgt_inter_in is not None:
        # Mirror the engine's event-path drop of the dense incoming inter
        # tensors (never read once the inbound slices are cut) in the
        # lowering arguments, so memory_analysis().argument_bytes prices
        # what a production run actually holds -- not both layouts at once.
        k_e = net_sds.k_inter
        net_sds = dataclasses.replace(
            net_sds,
            src_inter=jax.ShapeDtypeStruct(
                (0, 0, k_e), net_sds.src_inter.dtype),
            w_inter=jax.ShapeDtypeStruct(
                (0, 0, k_e), net_sds.w_inter.dtype),
            delay_inter=jax.ShapeDtypeStruct(
                (0, 0, k_e), net_sds.delay_inter.dtype),
        )
    A, n_pad = net_sds.alive.shape
    R = net_sds.ring_len

    st_specs = state_pspecs(mesh, schedule, "lif")
    nt_specs = network_pspecs(mesh, schedule, like=net_sds)
    sds = jax.ShapeDtypeStruct

    def shard(x, spec_):
        return sds(x.shape, x.dtype, sharding=NamedSharding(mesh, spec_))

    state_sds = jax.tree.map(
        lambda leaf, spec_: shard(leaf, spec_),
        {
            "neuron": neuron_lib.LIFState(
                v=sds((A, n_pad), jnp.float32),
                i_syn=sds((A, n_pad), jnp.float32),
                refrac=sds((A, n_pad), jnp.int32),
            ),
            "ring": sds((A, n_pad, R), jnp.float32),
            "t": sds((), jnp.int32),
            "spike_count": sds((A, n_pad), jnp.int32),
            "overflow": sds((), jnp.int32),
            "shipped_bytes": sds((), jnp.float32),
        },
        {
            "neuron": st_specs.neuron, "ring": st_specs.ring,
            "t": st_specs.t, "spike_count": st_specs.spike_count,
            "overflow": st_specs.overflow,
            "shipped_bytes": st_specs.shipped_bytes,
        },
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    from repro.core.engine import SimState
    state_sds = SimState(**state_sds)
    net_in = jax.tree.map(
        lambda leaf, spec_: shard(leaf, spec_), net_sds, nt_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    gid_spec = (st_specs.spike_count)  # same layout as per-neuron arrays
    gids_sds = shard(sds((A, n_pad), jnp.int32), gid_spec)

    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jax.jit(eng.window_raw).lower(state_sds, net_in, gids_sds)
        compiled = lowered.compile()
    row.update(_analyze(lowered, compiled, n_devices))
    row["status"] = "OK"
    row["compile_s"] = round(time.time() - t0, 1)
    row["n_neurons"] = spec.n_total
    row["n_synapses_per_neuron"] = spec.k_total
    row["delay_ratio_D"] = spec.delay_ratio
    row["wire_bytes_window"] = eng.wire_bytes
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id | 'all' | 'mam-snn' (comma separated ok)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--hierarchical", action="store_true",
                    help="use the paper-technique trainer (multi-pod only)")
    ap.add_argument("--snn-schedule", default="structure_aware")
    ap.add_argument("--snn-scale", type=float, default=1.0)
    ap.add_argument("--snn-backend", default="",
                    help="delivery backend for the SNN cells "
                         "('' = config default, 'event' lowers the sparse "
                         "id-packet paths via outgoing-table SDS)")
    ap.add_argument("--snn-exchange", default="",
                    help="global pathway for the SNN cells "
                         "('' = dense, 'routed' lowers the ppermute rounds)")
    ap.add_argument("--snn-replicated-tables", action="store_true",
                    help="lower the legacy replicated inter receive tables "
                         "instead of the sharded inbound slices (the "
                         "before/after baseline of the sharded-table PR)")
    ap.add_argument("--snn-no-subgroup-tables", action="store_true",
                    help="keep the PR 4 per-group inbound slices instead of "
                         "the subgroup-sliced [S, gsz, rows, K_in] layout "
                         "(the before/after baseline of the memory-diet PR)")
    ap.add_argument("--snn-adaptive", action="store_true",
                    help="lower the adaptive two-phase exchange (phase-1 "
                         "count collective + bucket-ladder payloads via "
                         "lax.switch) instead of static s_max packets")
    ap.add_argument("--hbm-gib", type=float, default=16.0,
                    help="per-device HBM budget (GiB) enforced on the SNN "
                         "rows: a cell whose modelled footprint (argument + "
                         "temp + output bytes from XLA's memory_analysis) "
                         "exceeds this FAILs the dry run instead of just "
                         "printing the number (default 16, the v5e chip; "
                         "0 disables the gate)")
    ap.add_argument("--build-rss", action="store_true",
                    help="also *measure* construction host peak RSS: fork "
                         "one fresh interpreter per build path (host build "
                         "+ shard cuts vs plan + per-shard builders) over a "
                         "mid-size network and FAIL unless the sharded "
                         "build's ru_maxrss comes in under the host "
                         "build's")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    if SNN_ARCH in archs:
        # Fail fast if the SDS width bounds the production rows are priced
        # from do not bracket an instantiated laptop-scale shard.
        try:
            rows.append(verify_inter_table_bounds())
        except Exception as e:
            rows.append({
                "arch": SNN_ARCH, "shape": "table_bounds",
                "mesh": "2x2", "status": f"FAIL({type(e).__name__}: {e})",
            })
            traceback.print_exc()
        _print_row(rows[-1])
        # Construction rows: what building the production network costs the
        # host, before any window runs -- the host-free build's claim.
        try:
            rows.append(construction_cost_row(args.snn_scale))
        except Exception as e:
            rows.append({
                "arch": SNN_ARCH, "shape": "build",
                "mesh": "16x16", "status": f"FAIL({type(e).__name__}: {e})",
            })
            traceback.print_exc()
        _print_row(rows[-1])
        if args.build_rss:
            try:
                rows.append(measure_build_rss())
            except Exception as e:
                rows.append({
                    "arch": SNN_ARCH, "shape": "build_rss",
                    "mesh": "4x2",
                    "status": f"FAIL({type(e).__name__}: {e})",
                })
                traceback.print_exc()
            _print_row(rows[-1])
    for multi_pod in meshes:
        for arch in archs:
            if arch == SNN_ARCH:
                # --snn-schedule "" runs only the verify/construction rows
                # (no production lowering) -- the CI construction gate.
                for sched in filter(None, args.snn_schedule.split(",")):
                    try:
                        rows.append(enforce_hbm_budget(dryrun_snn_cell(
                            sched, multi_pod, args.snn_scale,
                            backend=args.snn_backend,
                            # routed applies to the structure-aware lumped
                            # pathway only; conventional stays dense.
                            exchange=(args.snn_exchange
                                      if sched == "structure_aware" else ""),
                            shard_tables=not args.snn_replicated_tables,
                            subgroup_tables=not args.snn_no_subgroup_tables,
                            adaptive=args.snn_adaptive), args.hbm_gib))
                    except Exception as e:
                        rows.append({
                            "arch": arch, "shape": sched,
                            "mesh": "2x16x16" if multi_pod else "16x16",
                            "status": f"FAIL({type(e).__name__}: {e})",
                        })
                        traceback.print_exc()
                    _print_row(rows[-1])
                continue
            for shape in shapes:
                try:
                    rows.append(dryrun_lm_cell(arch, shape, multi_pod,
                                               args.hierarchical))
                except Exception as e:
                    rows.append({
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "status": f"FAIL({type(e).__name__}: {e})",
                    })
                    traceback.print_exc()
                _print_row(rows[-1])

    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"\nwrote {len(rows)} rows to {args.out}")

    n_ok = sum(r["status"] == "OK" for r in rows)
    n_skip = sum(r["status"].startswith("SKIP") for r in rows)
    n_fail = len(rows) - n_ok - n_skip
    print(f"\n=== dry-run summary: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL ===")
    if n_fail:
        raise SystemExit(1)


def _print_row(row: dict) -> None:
    status = row.get("status", "?")
    base = f"[{row['mesh']}] {row['arch']:28s} {row['shape']:12s} "
    if status != "OK":
        print(base + status)
        return
    if "build_reduction" in row:  # modelled construction row
        print(base + f"OK build host={row['build_gib_host_modelled']}GiB "
              f"sharded={row['build_gib_shard_modelled']}GiB "
              f"({row['build_reduction']}x)")
        return
    if "rss_reduction" in row:  # measured construction row
        print(base + f"OK build-rss host={row['host_peak_rss_mib']}MiB/"
              f"{row['host_build_s']}s "
              f"sharded={row['sharded_peak_rss_mib']}MiB/"
              f"{row['sharded_build_s']}s ({row['rss_reduction']}x)")
        return
    if "roofline" not in row:  # bounds-verify row: no lowering behind it
        print(base + f"OK modelled={row['bytes_per_device_modelled']}B "
              f"instantiated={row['bytes_per_device_instantiated']}B "
              f"slack={row['bound_slack']}x")
        return
    r = row["roofline"]
    per_dev_gb = modelled_hbm_gib(row)
    tables = ""
    if "inter_tables" in row:
        tb = row["inter_tables"]["table_bytes"]
        tables = (f" inter-tables rep={tb['replicated'] / 2**30:.1f}GiB "
                  f"sharded={tb['sharded'] / 2**30:.1f}GiB "
                  f"({tb['reduction']:.1f}x)")
    print(base + f"OK compute={r['compute_s']*1e3:9.3f}ms "
          f"memory={r['memory_s']*1e3:9.3f}ms "
          f"collective={r['collective_s']*1e3:9.3f}ms "
          f"dom={row['dominant'][:-2]:10s} mem/dev={per_dev_gb:7.2f}GiB "
          f"compile={row.get('compile_s', 0):6.1f}s" + tables)


if __name__ == "__main__":
    main()
