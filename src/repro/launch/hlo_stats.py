"""Trip-count-aware cost statistics from compiled HLO text (for §Roofline).

``compiled.cost_analysis()`` counts each ``while`` body ONCE -- useless for
scan-stacked models (a 62-layer scanned transformer reports 1/62 of its
FLOPs). This module parses the optimized HLO module text itself:

* splits it into computations, building a per-computation symbol table
  (instruction -> shape) so dot FLOPs can be derived from operand shapes,
* extracts while-loop trip counts from their condition computations (the
  loop bound is the s32 constant feeding the compare),
* propagates multipliers entry -> while body -> nested bodies (and through
  ``calls=`` for fusions), then sums

    FLOPs          2 * prod(result dims) * prod(contracted dims) per dot
    HBM bytes      operands + results of top-level instructions (models
                   perfect fusion-internal reuse)
    collectives    effective wire bytes per device, per kind:
                     all-gather          out * (g-1)/g
                     all-reduce          2 * bytes * (g-1)/g   (ring)
                     reduce-scatter      out * (g-1)
                     all-to-all          bytes * (g-1)/g
                     collective-permute  bytes
  each multiplied by its computation's trip multiplier.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HloStats", "analyze_hlo", "parse_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(
    r"\b(pred|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|bf16|f16|f32|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_WHILE_RE = re.compile(r"condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _TYPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


@dataclasses.dataclass
class _Comp:
    name: str
    lines: list[str]
    symbols: dict[str, list]            # instr -> shape list
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_bytes_fused: float = 0.0
    coll_counts: dict = None
    coll_wire: dict = None
    coll_wire_by_group: dict = None     # group size -> wire bytes
    whiles: list = None                 # (cond_name, body_name)
    calls: list = None                  # fusion/call targets
    max_s32_const: int = 1


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float          # naive bound: every top-level op's in+out
    hbm_bytes_fused: float    # fused bound: only dot/fusion/slice/scatter/
                              # copy/reduce/collective traffic (elementwise
                              # chains assumed VMEM-resident, as on TPU)
    coll_counts: dict[str, float]
    coll_wire: dict[str, float]
    # Tier attribution: replica-group size -> wire bytes. Group sizes <= the
    # intra-pod extent are fast-tier (ICI); the full-mesh size crosses pods.
    coll_wire_by_group: dict[int, float]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.coll_wire.values())

    @property
    def total_coll_ops(self) -> float:
        return sum(self.coll_counts.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_fused": self.hbm_bytes_fused,
            "counts": dict(self.coll_counts),
            "wire_bytes": dict(self.coll_wire),
            "wire_bytes_by_group": {str(k): v for k, v in
                                    self.coll_wire_by_group.items()},
            "total_wire_bytes": self.total_wire_bytes,
            "total_ops": self.total_coll_ops,
        }


def _split_computations(text: str) -> list[_Comp]:
    comps: list[_Comp] = []
    cur: _Comp | None = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(1)
                if not name.startswith("%"):
                    name = "%" + name
                cur = _Comp(name=name, lines=[], symbols={},
                            coll_counts=defaultdict(float),
                            coll_wire=defaultdict(float),
                            coll_wire_by_group=defaultdict(float),
                            whiles=[], calls=[])
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps.append(cur)
            cur = None
            continue
        cur.lines.append(line)
    if cur is not None:
        comps.append(cur)
    return comps


def _analyze_comp(c: _Comp, n_devices: int) -> None:
    # pass 1: symbol table
    for line in c.lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # shapes up to the op name: take shapes before the first '(' that
        # follows the type annotation -- simplest robust cut: shapes in the
        # segment before ' op_name(' is hard; take all shapes up to the op
        # token by cutting at the first alphabetic op keyword match below.
        # For the symbol table we only need the RESULT type(s): they come
        # first, before the op name.
        paren = rhs.find("(")
        head = rhs[:paren] if paren > 0 else rhs
        c.symbols[name] = _shape_list(head)

    for line in c.lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        result_shapes = c.symbols.get(name, [])
        out_bytes = _nbytes(result_shapes)

        cm = _CONST_S32_RE.search(line)
        if cm:
            c.max_s32_const = max(c.max_s32_const, int(cm.group(1)))

        wm = _WHILE_RE.search(line)
        if wm and " while(" in rhs:
            c.whiles.append((wm.group(1), wm.group(2)))
            continue
        fm = _CALLS_RE.search(line)
        if fm:
            c.calls.append(fm.group(1))

        # operand bytes (resolve via symbol table)
        paren = rhs.find("(")
        operand_bytes = 0
        op_names: list[str] = []
        if paren > 0:
            om = _OPERANDS_RE.search(rhs[paren:])
            if om and om.group(1):
                op_names = [o.strip() for o in om.group(1).split(",")]
                for o in op_names:
                    operand_bytes += _nbytes(c.symbols.get(o, []))

        # HBM traffic model per op kind. Pure plumbing (tuple shuffling,
        # aliasing, control flow wrappers) moves no data; slicing ops touch
        # only the slice, not the whole operand (XLA updates in place).
        def _is(op: str) -> bool:
            return f" {op}(" in rhs or rhs.startswith(f"{op}(")

        if (_is("get-tuple-element") or _is("tuple") or _is("bitcast")
                or _is("parameter") or _is("constant") or _is("while")
                or _is("conditional") or _is("after-all") or _is("reshape")
                or _is("iota")):
            pass  # no traffic
        elif _is("dynamic-slice"):
            c.hbm_bytes += 2 * out_bytes
            c.hbm_bytes_fused += 2 * out_bytes
        elif _is("dynamic-update-slice"):
            upd = (_nbytes(c.symbols.get(op_names[1], []))
                   if len(op_names) > 1 else out_bytes)
            c.hbm_bytes += 2 * upd
            c.hbm_bytes_fused += 2 * upd
        elif _is("gather"):
            c.hbm_bytes += 2 * out_bytes
            c.hbm_bytes_fused += 2 * out_bytes
        elif _is("scatter"):
            upd = (_nbytes(c.symbols.get(op_names[2], []))
                   if len(op_names) > 2 else out_bytes)
            c.hbm_bytes += 3 * upd
            c.hbm_bytes_fused += 3 * upd
        else:
            c.hbm_bytes += out_bytes + operand_bytes
            # Fused bound: only ops that necessarily touch HBM on a
            # well-fused TPU program. Bare elementwise chains (add, exp,
            # convert, select, ...) are assumed fused into their producers.
            if any(_is(op) for op in (
                    "dot", "fusion", "copy", "convolution", "reduce",
                    "reduce-window", "sort", "custom-call", "rng",
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "pad",
                    "concatenate", "transpose", "slice")):
                c.hbm_bytes_fused += out_bytes + operand_bytes

        # dot flops
        if " dot(" in rhs:
            km = _CONTRACT_RE.search(rhs)
            contract = [int(x) for x in km.group(1).split(",")] if km and km.group(1) else []
            lhs_shape: tuple[int, ...] = ()
            if op_names:
                lhs_syms = c.symbols.get(op_names[0], [])
                if lhs_syms:
                    lhs_shape = lhs_syms[0][1]
            kdim = 1
            for d in contract:
                if d < len(lhs_shape):
                    kdim *= lhs_shape[d]
            rdim = 1
            for _, shape in result_shapes[:1]:
                for d in shape:
                    rdim *= d
            c.flops += 2.0 * rdim * kdim

        # collectives
        for kind in _COLLECTIVES:
            if f" {kind}(" in rhs or f" {kind}-start(" in rhs:
                g = _group_size(line, n_devices)
                frac = (g - 1) / g if g > 1 else 0.0
                c.coll_counts[kind] += 1
                if kind == "all-gather":
                    w = out_bytes * frac
                elif kind == "all-reduce":
                    w = 2 * out_bytes * frac
                elif kind == "reduce-scatter":
                    w = out_bytes * (g - 1)
                elif kind == "all-to-all":
                    w = out_bytes * frac
                else:  # collective-permute
                    w = out_bytes
                c.coll_wire[kind] += w
                c.coll_wire_by_group[g] += w
                break


def analyze_hlo(text: str, n_devices: int) -> HloStats:
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}
    for c in comps:
        _analyze_comp(c, n_devices)

    # multiplier propagation (entry = last ENTRY-like computation or the one
    # not referenced by anyone)
    referenced: set[str] = set()
    for c in comps:
        for _, body in c.whiles:
            referenced.add(body)
        for callee in c.calls:
            referenced.add(callee)
        for _, cond in [(b, cond) for cond, b in c.whiles]:
            referenced.add(cond)
    entries = [c for c in comps if c.name not in referenced]
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e.name] += 1.0

    # topological-ish propagation: iterate until fixpoint (bounded passes)
    for _ in range(64):
        changed = False
        new_mult = defaultdict(float)
        for e in entries:
            new_mult[e.name] = 1.0
        for c in comps:
            m = new_mult.get(c.name, mult.get(c.name, 0.0))
            if m == 0.0:
                m = mult.get(c.name, 0.0)
            for cond, body in c.whiles:
                trip = by_name[cond].max_s32_const if cond in by_name else 1
                new_mult[body] += m * max(trip, 1)
                new_mult[cond] += m * max(trip, 1)
            for callee in c.calls:
                new_mult[callee] += m
        if dict(new_mult) != dict(mult):
            mult = new_mult
            changed = True
        if not changed:
            break

    flops = 0.0
    hbm = 0.0
    hbm_f = 0.0
    counts: dict[str, float] = defaultdict(float)
    wire: dict[str, float] = defaultdict(float)
    wire_g: dict[int, float] = defaultdict(float)
    for c in comps:
        m = mult.get(c.name, 1.0 if c in entries else 0.0)
        if c in entries:
            m = max(m, 1.0)
        flops += c.flops * m
        hbm += c.hbm_bytes * m
        hbm_f += c.hbm_bytes_fused * m
        for k, v in c.coll_counts.items():
            counts[k] += v * m
        for k, v in c.coll_wire.items():
            wire[k] += v * m
        for k, v in c.coll_wire_by_group.items():
            wire_g[k] += v * m
    return HloStats(flops=flops, hbm_bytes=hbm, hbm_bytes_fused=hbm_f,
                    coll_counts=dict(counts), coll_wire=dict(wire),
                    coll_wire_by_group=dict(wire_g))


def parse_collectives(text: str, n_devices: int) -> HloStats:
    """Backwards-compatible alias (collective stats live on HloStats)."""
    return analyze_hlo(text, n_devices)
