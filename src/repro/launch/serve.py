"""Simulation-as-a-service: a batched multi-tenant serving layer.

JetStream-style serving on top of the unified engine API
(:func:`repro.core.make_simulation`): many independent tenants submit
*trials* -- ``(seed, stimulus scale, duration)`` -- against one shared
network spec, and the server packs them into batches that run as a single
engine dispatch per window.

**Folded batching.** A batch of ``B`` trials runs as ONE block-diagonal
super-network: the connectivity is tiled ``B`` times along the area axis
(:func:`repro.core.connectivity.tile_network` -- no synapse crosses a copy
boundary), each copy is fed the single-trial gid table
(:func:`~repro.core.connectivity.tile_gids`) and its own per-trial
``seed``/``stim`` drive leaves (:class:`~repro.core.schedule.SimState`).
Each block then reproduces the corresponding single-trial run *bitwise*
(1/256-grid weights make ring accumulation associative-exact, and the
per-copy scatter order is the single-trial order), while the batch pays
the per-window dispatch and host-loop overhead once instead of ``B``
times. Unlike a ``vmap`` over trials -- which lowers the event path's
sorts and scatters to slow batched variants -- the folded network runs
the *single-trial* code shape. How much of the window that amortises is
host-dependent: on accelerators the fixed per-dispatch cost dominates
small windows; on a single-core CPU host per-neuron compute dominates
and the fold's warm-loop gain is small. The serving layer's headline
throughput win there is the startup AOT warm instead -- every tenant
shares one compiled executable rather than paying engine build + jit
compile per trial (>=2x over per-trial cold clients is the benchmarked
floor; see ``benchmarks/bench_delivery.py::bench_serve``).

**Execution model.** At startup the server builds the folded engine,
AOT-compiles its window executable (``Engine.window.lower(...).compile()``)
and warms it with a filler batch. One *executor* thread owns all device
work (one host process drives one device queue; submitters are free to be
many): it groups queued requests by duration bucket (a power-of-two ladder
of window counts), assembles the per-copy drive leaves, and advances the
batch window by window through :func:`repro.core.schedule.run_windows`,
whose ``on_block`` hook is the per-request streaming cadence -- every
window, each trial's rows are sliced out of the ``[D, B*A, n_pad]`` spike
block and a request finalises the moment its *own* duration completes,
independent of the batch's longest trial. The window executable is
duration-independent, so every bucket shares one compiled artifact;
buckets exist to pack requests of similar length together (a short trial
never waits out a long batch-mate's tail).

**Draining.** ``SIGTERM`` (or :meth:`SimServer.shutdown`) flips the server
to draining: new submissions are rejected with :class:`ServerClosed`,
accepted requests are run to completion, and on a non-draining shutdown
the unserved requests are journaled through :mod:`repro.checkpoint.manager`
(atomic ``step_<N>/`` directory) so a restarted server can resubmit them.

CLI::

    PYTHONPATH=src python -m repro.launch.serve --trials 16 --batch 8
    PYTHONPATH=src python -m repro.launch.serve --selftest   # CI smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import threading
import time
from typing import Callable

import numpy as np

from repro.core.areas import MultiAreaSpec, tile_spec
from repro.core import connectivity as connectivity_lib
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation
from repro.core import schedule as schedule_lib

__all__ = [
    "TrialRequest",
    "TrialResult",
    "TrialHandle",
    "ServerClosed",
    "SimServer",
    "serve_simulation",
]


class ServerClosed(RuntimeError):
    """Raised by ``submit`` once the server is draining or stopped."""


@dataclasses.dataclass(frozen=True)
class TrialRequest:
    """One tenant's trial: an independent simulation of the shared spec.

    ``seed`` keys the counter-based external drive (the trial's only
    source of randomness -- trajectories are a pure function of it);
    ``stim`` scales the drive rate (1.0 = the spec's calibrated ground
    state); ``windows`` is the duration in D-cycle windows.
    """

    seed: int
    stim: float = 1.0
    windows: int = 1

    def __post_init__(self) -> None:
        if self.windows < 1:
            raise ValueError(f"windows must be >= 1, got {self.windows}")


@dataclasses.dataclass
class TrialResult:
    request: TrialRequest
    # [windows * D, A, n_pad] bool -- the trial's full spike train.
    spikes: np.ndarray
    # The batch's overflow counter after this trial's run. 0 is the event
    # path's exactness condition; nonzero means packet bounds clipped.
    overflow: int
    # Seconds from submit to result (queue wait + compute).
    latency_s: float


class TrialHandle:
    """Future for a submitted trial; fulfilled by the executor thread."""

    def __init__(self, request: TrialRequest,
                 on_block: Callable[[int, np.ndarray], None] | None = None):
        self.request = request
        self._on_block = on_block
        self._event = threading.Event()
        self._result: TrialResult | None = None
        self._error: BaseException | None = None
        self._t_submit = time.perf_counter()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> TrialResult:
        if not self._event.wait(timeout):
            raise TimeoutError("trial not finished")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    # -- executor side ---------------------------------------------------
    def _stream(self, w: int, rows: np.ndarray) -> None:
        if self._on_block is not None:
            self._on_block(w, rows)

    def _fulfil(self, spikes: np.ndarray, overflow: int) -> None:
        self._result = TrialResult(
            request=self.request, spikes=spikes, overflow=overflow,
            latency_s=time.perf_counter() - self._t_submit)
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


def _bucket_ladder(max_windows: int) -> tuple[int, ...]:
    """Power-of-two duration buckets up to (and including) max_windows."""
    ladder = []
    w = 1
    while w < max_windows:
        ladder.append(w)
        w *= 2
    ladder.append(max_windows)
    return tuple(ladder)


class SimServer:
    """Batched multi-tenant trial server over one folded engine.

    ``max_batch`` trials run per dispatch as a ``max_batch``-copy
    block-diagonal super-network (see the module docstring); unfilled
    slots are padded with filler trials whose results are dropped.
    ``max_batch=1`` is the sequential-loop baseline the benchmark
    compares against -- same machinery, no folding.
    """

    def __init__(
        self,
        spec: MultiAreaSpec,
        config: EngineConfig = EngineConfig(delivery_backend="event"),
        *,
        max_batch: int = 16,
        max_windows: int = 32,
        build_seed: int = 12,
        checkpoint_dir: str | None = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if config.neuron_model != "lif":
            raise ValueError(
                "serving needs neuron_model='lif': trials are distinguished "
                "by their drive seed, and ignore_and_fire has no seed or "
                "input dependence (every trial would be identical)")
        if config.superstep_kernel:
            raise ValueError(
                "serving needs per-trial seed leaves, which the fused "
                "superstep kernel does not take (it bakes cfg.seed in)")
        self.spec = spec
        self.config = config
        self.max_batch = max_batch
        self.buckets = _bucket_ladder(max_windows)
        self.checkpoint_dir = checkpoint_dir

        # ---- build the folded engine (B network copies, one executable).
        net = connectivity_lib.build_network(
            spec, seed=build_seed, outgoing=config.backend == "event")
        self._A, self._n_pad = net.alive.shape
        B = max_batch
        self._spec_b = tile_spec(spec, B)
        net_b = connectivity_lib.tile_network(net, B)
        gids_b = connectivity_lib.tile_gids(self._A, self._n_pad, B)
        # The event path's whole-network packet bound carries a constant
        # `+ 4*floor` burst term that does NOT grow with the fold: a B-copy
        # batch would run a strictly tighter per-copy bound than its B
        # sequential references and clip first -- and a clipped global
        # packet mixes copies (cross-trial interference). s_max_burst=B
        # widens exactly that term, keeping the folded global bound >= the
        # sum of the sequential ones while leaving the per-area bound (and
        # so the per-area scatter width, the event path's cost driver)
        # untouched; widths beyond the realised spike count are inert
        # (invalid-id padding), so this cannot change an unclipped
        # trajectory.
        cfg_b = dataclasses.replace(
            config, s_max_burst=config.s_max_burst * B)
        self.engine = make_simulation(
            self._spec_b, cfg_b, net=net_b, gids=gids_b)
        self.delay_ratio = self.engine.delay_ratio

        # ---- request plumbing.
        self._lock = threading.Condition()
        self._queue: list[TrialHandle] = []
        self._closed = False
        self._drain = True
        self._stopped = threading.Event()
        self._worker: threading.Thread | None = None

        # ---- SLO bookkeeping.
        self._latencies: list[float] = []
        self._trials_done = 0
        self._t_started: float | None = None
        self._busy_s = 0.0

    # ------------------------------------------------------------------
    # lifecycle

    def start(self) -> "SimServer":
        """AOT-compile + warm the window executable, start the executor."""
        st = self._init_state(
            [TrialRequest(seed=int(self.config.seed))] )
        # One window executable serves every duration bucket (the windowed
        # executor streams blocks; a fixed-length scan would return only
        # spike counts). AOT-compile it for the folded state shape, then
        # warm with one real dispatch so the first tenant never pays
        # compile or first-touch cost.
        compiled = self.engine.window.lower(st).compile()
        self.engine = self.engine._replace(window=compiled)
        out_st, _ = self.engine.window(st)
        import jax
        jax.block_until_ready(out_st.ring)
        self._t_started = time.perf_counter()
        self._worker = threading.Thread(
            target=self._run_loop, name="sim-serve-executor", daemon=True)
        self._worker.start()
        return self

    def __enter__(self) -> "SimServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def install_sigterm(self) -> None:
        """SIGTERM -> drain: reject new submissions, finish accepted ones."""
        signal.signal(signal.SIGTERM, lambda *_: self.close(drain=True))

    def close(self, *, drain: bool = True) -> None:
        """Stop accepting; signal the executor to drain (or abandon)."""
        with self._lock:
            self._closed = True
            self._drain = drain
            self._lock.notify_all()

    def shutdown(self, *, drain: bool = True, timeout: float | None = None
                 ) -> None:
        """Close, wait for the executor, journal anything unserved."""
        self.close(drain=drain)
        if self._worker is not None:
            self._worker.join(timeout)
        self._journal_unserved()

    # ------------------------------------------------------------------
    # tenant side

    def submit(self, request: TrialRequest,
               on_block: Callable[[int, np.ndarray], None] | None = None,
               ) -> TrialHandle:
        """Queue a trial; returns its handle (thread-safe).

        ``on_block(w, rows)`` streams the trial's own ``[D, A, n_pad]``
        spike rows after every window, from the executor thread.
        """
        if request.windows > self.buckets[-1]:
            raise ValueError(
                f"windows={request.windows} exceeds the server's "
                f"max_windows={self.buckets[-1]}")
        handle = TrialHandle(request, on_block)
        with self._lock:
            if self._closed:
                raise ServerClosed("server is draining; not accepting trials")
            self._queue.append(handle)
            self._lock.notify_all()
        return handle

    def stats(self) -> dict:
        """Serving SLOs so far: trials/s and p50/p99 time-to-result."""
        lat = np.asarray(self._latencies, dtype=np.float64)
        elapsed = (time.perf_counter() - self._t_started
                   if self._t_started else 0.0)
        return dict(
            trials=self._trials_done,
            max_batch=self.max_batch,
            elapsed_s=elapsed,
            busy_s=self._busy_s,
            trials_per_s=(self._trials_done / elapsed) if elapsed else 0.0,
            p50_ms=float(np.percentile(lat, 50) * 1e3) if lat.size else None,
            p99_ms=float(np.percentile(lat, 99) * 1e3) if lat.size else None,
        )

    # ------------------------------------------------------------------
    # executor side

    def _bucket_for(self, windows: int) -> int:
        for b in self.buckets:
            if windows <= b:
                return b
        return self.buckets[-1]

    def _init_state(self, requests: list[TrialRequest]):
        """The folded batch's initial SimState: per-copy seed/stim leaves."""
        import jax.numpy as jnp

        A, n_pad, B = self._A, self._n_pad, self.max_batch
        seeds = [int(r.seed) for r in requests]
        stims = [float(r.stim) for r in requests]
        # Filler copies run the engine-wide seed at unit stimulus; their
        # blocks are discarded (block-diagonality keeps them from touching
        # any tenant's copy).
        seeds += [int(self.config.seed)] * (B - len(seeds))
        stims += [1.0] * (B - len(stims))
        seed_leaf = jnp.broadcast_to(
            jnp.repeat(jnp.asarray(seeds, jnp.uint32), A)[:, None],
            (B * A, n_pad))
        stim_leaf = jnp.broadcast_to(
            jnp.repeat(jnp.asarray(stims, jnp.float32), A)[:, None],
            (B * A, n_pad))
        st = self.engine.init(seed=0, stim=1.0)
        return dataclasses.replace(st, seed=seed_leaf, stim=stim_leaf)

    def _take_batch(self) -> list[TrialHandle] | None:
        """Block for work; group up to max_batch same-bucket requests."""
        with self._lock:
            while not self._queue:
                if self._closed:
                    return None
                self._lock.wait(timeout=0.1)
            if self._closed and not self._drain:
                return None
            bucket = self._bucket_for(self._queue[0].request.windows)
            batch, rest = [], []
            for h in self._queue:
                if (len(batch) < self.max_batch
                        and self._bucket_for(h.request.windows) == bucket):
                    batch.append(h)
                else:
                    rest.append(h)
            self._queue = rest
            return batch

    def _run_batch(self, batch: list[TrialHandle]) -> None:
        import jax

        A, D = self._A, self.delay_ratio
        bucket = max(self._bucket_for(h.request.windows) for h in batch)
        st = self._init_state([h.request for h in batch])
        collected: list[list[np.ndarray]] = [[] for _ in batch]
        done = [False] * len(batch)

        def on_block(w: int, block) -> None:
            host = np.asarray(block)  # [D, B*A, n_pad] bool
            for i, h in enumerate(batch):
                if done[i]:
                    continue
                rows = host[:, i * A:(i + 1) * A, :]
                collected[i].append(rows)
                h._stream(w, rows)
                if len(collected[i]) >= h.request.windows:
                    done[i] = True
        t0 = time.perf_counter()
        res = schedule_lib.run_windows(
            self.engine, st, bucket, on_block=on_block)
        jax.block_until_ready(res.state.ring)
        self._busy_s += time.perf_counter() - t0
        overflow = int(jax.device_get(res.state.overflow))
        for i, h in enumerate(batch):
            spikes = np.concatenate(collected[i][:h.request.windows], axis=0)
            h._fulfil(spikes[:h.request.windows * D], overflow)
            self._latencies.append(time.perf_counter() - h._t_submit)
            self._trials_done += 1

    def _run_loop(self) -> None:
        try:
            while True:
                batch = self._take_batch()
                if batch is None:
                    break
                try:
                    self._run_batch(batch)
                except BaseException as e:  # noqa: BLE001 -- fail the batch
                    for h in batch:
                        h._fail(e)
        finally:
            self._stopped.set()

    def _journal_unserved(self) -> None:
        """Write unserved requests through the checkpoint manager.

        Only a non-draining shutdown leaves anything unserved; the journal
        (atomic ``step_<N>/`` rename, crash-safe) lets a restarted server
        resubmit exactly the trials that were accepted but never ran.
        """
        with self._lock:
            unserved = list(self._queue)
            self._queue = []
        for h in unserved:
            h._fail(ServerClosed("server stopped before this trial ran"))
        if not unserved or self.checkpoint_dir is None:
            return
        from repro.checkpoint import manager as ckpt

        reqs = [dataclasses.asdict(h.request) for h in unserved]
        ckpt.save(
            self.checkpoint_dir, step=int(time.time()),
            tree={"n_unserved": np.int64(len(reqs))},
            extra={"unserved": reqs})

    @staticmethod
    def restore_unserved(checkpoint_dir: str) -> list[TrialRequest]:
        """Read back a journal written by a non-draining shutdown."""
        from repro.checkpoint import manager as ckpt

        manifest, _ = ckpt.read_manifest(checkpoint_dir)
        extra = manifest.get("extra") or {}
        return [TrialRequest(**r) for r in extra.get("unserved", [])]


def serve_simulation(
    spec: MultiAreaSpec,
    config: EngineConfig = EngineConfig(delivery_backend="event"),
    **kw,
) -> SimServer:
    """Build and start a :class:`SimServer` (the module's entry point)."""
    return SimServer(spec, config, **kw).start()


# ----------------------------------------------------------------------
# CLI


def _laptop_spec(scale: float) -> MultiAreaSpec:
    from repro.core.areas import mam_spec

    return mam_spec(scale=scale)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scale", type=float, default=0.002,
                    help="MAM downscale factor (laptop config)")
    ap.add_argument("--batch", type=int, default=8,
                    help="max trials folded per dispatch")
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--windows", type=int, default=8,
                    help="duration of each trial, in D-cycle windows")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--vth", type=float, default=15.0,
                    help="LIF threshold (mV); the selftest lowers it to 2.0 "
                         "so the short smoke trials actually spike")
    ap.add_argument("--selftest", action="store_true",
                    help="CI smoke: mixed batch, assert bitwise equality "
                         "to sequential references and nonzero trials/s")
    args = ap.parse_args(argv)

    from repro.core.neuron import LIFParams

    spec = _laptop_spec(args.scale)
    vth = 2.0 if args.selftest else args.vth
    # The lowered selftest threshold drives bursty onset activity far above
    # the 2.5 Hz calibration the default packet bounds price; exactness
    # needs overflow == 0, so raise the floor to the per-area population
    # bound (n_pad is hard per cycle; the selftest asserts overflow == 0,
    # which also covers the whole-net packet's realised peak).
    floor = max(16, spec.padded_area_size(1)) if args.selftest else 16
    cfg = EngineConfig(delivery_backend="event",
                       lif=LIFParams(v_th_mv=vth),
                       s_max_floor=floor)
    rng = np.random.default_rng(0)
    requests = [
        TrialRequest(seed=int(rng.integers(1, 2**31)),
                     stim=float(rng.uniform(0.8, 1.2)),
                     windows=int(rng.integers(1, args.windows + 1))
                     if args.selftest else args.windows)
        for _ in range(args.trials)
    ]

    with SimServer(spec, cfg, max_batch=args.batch,
                   max_windows=args.windows,
                   checkpoint_dir=args.checkpoint_dir) as server:
        server.install_sigterm()
        handles = [server.submit(r) for r in requests]
        results = [h.result(timeout=600) for h in handles]
    stats = server.stats()
    print(json.dumps({k: v for k, v in stats.items()}, indent=2))

    if args.selftest:
        # Bitwise equality: every served trial == its sequential reference.
        eng = make_simulation(spec, cfg)
        for r in results:
            st = eng.init(seed=r.request.seed, stim=r.request.stim)
            blocks = []
            for _ in range(r.request.windows):
                st, blk = eng.window(st)
                blocks.append(np.asarray(blk))
            ref = np.concatenate(blocks, axis=0)
            assert r.spikes.shape == ref.shape, (r.spikes.shape, ref.shape)
            assert np.array_equal(r.spikes, ref), (
                f"trial seed={r.request.seed} diverged from its "
                "sequential reference")
            assert r.overflow == 0, "overflow must be 0 for exactness"
        assert stats["trials_per_s"] > 0, "no throughput recorded"
        print(f"selftest OK: {len(results)} trials bitwise-identical to "
              f"sequential references at "
              f"{stats['trials_per_s']:.2f} trials/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
