"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --ckpt-dir /tmp/ckpt

Runs real training on whatever devices exist (CPU here, TPU pods in
production): synthetic deterministic data pipeline, AdamW, checkpointing with
async writer, crash-resume, and -- when the mesh has a 'pod' axis or
``--pods N`` is given -- the paper's hierarchical two-tier synchronization
(local steps every step, cross-pod averaging every D-th, optionally
int8-compressed). On one host the pods are emulated by the leading replica
axis, so the full fault-tolerance path (divergence -> sync -> elastic resume
with a different pod count) is exercisable anywhere.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.registry import get_arch
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.hierarchical import HierarchicalConfig
from repro.train.steps import make_train_artifacts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--pods", type=int, default=0,
                    help=">0: hierarchical trainer with this many pod replicas")
    ap.add_argument("--sync-every", type=int, default=10,
                    help="D: cross-pod sync period (paper eq. 1)")
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    bundle = get_arch(args.arch, reduced=args.reduced)
    vocab = getattr(bundle.cfg, "vocab", None) or bundle.cfg.backbone.vocab
    n_params_cfg = bundle.cfg.param_count()
    print(f"arch={args.arch} reduced={args.reduced} "
          f"params(cfg)={n_params_cfg/1e6:.1f}M devices={jax.device_count()}")

    hier_cfg = None
    if args.pods > 0:
        hier_cfg = HierarchicalConfig(sync_every=args.sync_every,
                                      compression=args.compression)

    opt_cfg = AdamWConfig(lr=args.lr, moment_dtype=bundle.moment_dtype,
                          warmup_steps=max(args.steps // 10, 1))
    art = make_train_artifacts(
        bundle, opt_cfg, mesh=None, fsdp_axis=None, hier_cfg=hier_cfg,
        n_micro=args.n_micro, donate=False,
    )

    params = bundle.model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt_cfg)
    sync_state = None
    if hier_cfg is not None:
        hier = art.hier
        hier.n_pods = args.pods
        params = hier.replicate(params)
        opt_state = hier.replicate(opt_state)
        sync_state = hier.init_sync_state(
            jax.tree.map(lambda x: x[0], params))

    start_step = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncWriter(args.ckpt_dir, keep=3)
        if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
            state = {"params": params, "opt": opt_state}
            restored, start_step = ckpt.restore(args.ckpt_dir, like=state)
            params, opt_state = restored["params"], restored["opt"]
            print(f"resumed from step {start_step}")

    ds = SyntheticLM(vocab=vocab, seq_len=args.seq_len,
                     global_batch=args.global_batch)

    def make_batch(step):
        b = ds.batch(step)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        for name, make in bundle.extra_inputs.items():
            spec = make(args.global_batch, args.seq_len)
            out[name] = jnp.zeros(spec.shape, spec.dtype)
        if hier_cfg is not None:
            out = {k: v.reshape((args.pods, v.shape[0] // args.pods)
                                + v.shape[1:]) for k, v in out.items()}
        return out

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = make_batch(step)
        params, opt_state, metrics = art.step_fn(params, opt_state, batch)
        if hier_cfg is not None and (step + 1) % hier_cfg.sync_every == 0:
            params, sync_state = art.sync_fn(params, sync_state)
        loss = float(np.mean(np.asarray(metrics["loss"])))
        losses.append(loss)
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / (step + 1 - start_step)
            print(f"step {step+1:5d} loss {loss:7.4f} "
                  f"gnorm {float(np.mean(np.asarray(metrics['grad_norm']))):8.3f} "
                  f"{dt*1e3:7.1f} ms/step")
        if writer and (step + 1) % args.ckpt_every == 0:
            writer.submit(step + 1, {"params": params, "opt": opt_state})

    if writer:
        writer.submit(args.steps, {"params": params, "opt": opt_state})
        writer.close()
    first = np.mean(losses[: max(len(losses) // 10, 1)])
    last = np.mean(losses[-max(len(losses) // 10, 1):])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}) "
          f"over {args.steps - start_step} steps")


if __name__ == "__main__":
    main()
