"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state -- critical because the dry-run must set
XLA_FLAGS before any jax initialisation, and smoke tests must see one device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)                 # 256 chips: (data, model)
MULTI_POD_SHAPE = (2, 16, 16)        # 512 chips: (pod, data, model)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The benchmark mesh: 16x16 single pod, or 2x16x16 across two pods.

    Axis semantics (DESIGN.md §4): 'model' is the fast tier (TP / intra-area
    subgroup), 'data' the intra-pod DP / area axis, 'pod' the slow tier the
    paper's D-cycle scheme synchronises rarely.
    """
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes)
