"""Production SNN simulation launcher (the paper's state-propagation driver).

    PYTHONPATH=src python -m repro.launch.simulate --model mam --scale 0.002 \
        --t-ms 500 --schedule structure_aware --backend event

Runs on whatever devices exist: a single device uses the reference engine; a
multi-device mesh (e.g. under XLA_FLAGS=--xla_force_host_platform_device_count=8
or on real TPU pods) uses the distributed two-tier engine, with the global
pathway selected by ``--exchange`` (``dense`` mesh-wide collectives vs the
connectivity-``routed`` packet rounds of ``repro.core.exchange``). Reports
per-window wall time, spike statistics, wire bytes per window (static worst
case AND the measured ``SimState.shipped_bytes``), and -- with
``--compare`` -- verifies the conventional and structure-aware schedules
produce identical spikes. ``--adaptive`` switches every packet onto the
adaptive two-phase exchange (counts first, then bucket-sized payloads;
overflow is asserted zero); ``--compare-adaptive`` additionally verifies
the adaptive and static paths are bit-identical.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib.util
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.areas import mam_benchmark_spec, mam_spec
from repro.core.connectivity import area_adjacency, build_network
from repro.core.engine import EngineConfig
from repro.core.factory import make_simulation
from repro.core import exchange as exchange_lib
from repro.core import faults as faults_lib
from repro.core import schedule as schedule_lib

# XLA flags that let the overlapped exchange actually run concurrently on
# GPU: collectives issued on their own async stream and the latency-hiding
# scheduler free to move them off the critical path (the standard
# set_platform recipe). GPU-ONLY: CPU/TPU jaxlib builds abort the process on
# unknown --xla_gpu_* flags in XLA_FLAGS, so these must never be appended
# unless a GPU platform is actually present.
_XLA_OVERLAP_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def xla_overlap_flags(platform: str | None = None) -> list[str]:
    """The async-collective XLA flags appropriate for ``platform``.

    ``None`` autodetects: 'gpu' only when a CUDA plugin is importable (the
    cheap check that cannot itself initialize a backend). Everything except
    'gpu' gets ``[]`` -- on this repo's CPU CI the flags would be a fatal
    ``Unknown flags in XLA_FLAGS`` abort, and on TPU the latency-hiding
    scheduler is already the default.
    """
    if platform is None:
        def _importable(mod: str) -> bool:
            try:
                # find_spec raises (not returns None) when the parent
                # package of a dotted name is itself missing.
                return importlib.util.find_spec(mod) is not None
            except ModuleNotFoundError:
                return False

        platform = "gpu" if any(
            _importable(mod)
            for mod in ("jax_cuda12_plugin", "jax_plugins.xla_cuda12")
        ) else "cpu"
    return list(_XLA_OVERLAP_FLAGS) if platform == "gpu" else []


def enable_overlap_flags(platform: str | None = None) -> bool:
    """Append the overlap flags to ``XLA_FLAGS`` (before backend init).

    Must run before the first jax device/backend call of the process --
    XLA parses the env var once at backend initialization. Returns whether
    anything was enabled (False on non-GPU platforms).
    """
    flags = xla_overlap_flags(platform)
    if not flags:
        return False
    current = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags if f not in current]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join([current, *missing]).strip()
    return True


class StopFlag:
    """SIGTERM/SIGINT -> "checkpoint at the next window boundary" flag.

    The handler only flips a bool (async-signal-safe); the windowed run loop
    polls it via ``stop_requested`` and performs the graceful stop -- drain
    the in-flight window, write the final checkpoint, raise ``Preempted`` --
    at the next window boundary, where the ring phase makes a bitwise resume
    possible.
    """

    def __init__(self):
        self.signum: int | None = None

    def __call__(self) -> bool:
        return self.signum is not None

    @property
    def name(self) -> str:
        return signal.Signals(self.signum).name if self.signum else "stop"

    def install(self) -> "StopFlag":
        def handler(signum, frame):
            del frame
            first = self.signum is None
            self.signum = signum
            if first:
                print(f"\n  caught {signal.Signals(signum).name}: finishing "
                      f"the current window, then checkpointing and exiting "
                      f"(repeat to kill immediately)", flush=True)
            else:
                signal.signal(signum, signal.SIG_DFL)
                signal.raise_signal(signum)

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)
        return self


def _time_loop(fn, *args, repeats: int = 3):
    """Best wall time of a jitted callable (compiles on the first call)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def profile_phases(net, spec, cfg: EngineConfig, cycles: int = 200) -> None:
    """Per-phase timing table: where a cycle's wall time actually goes.

    Times each phase of the deliver -> update -> collocate cycle in
    isolation (a jitted scan of `cycles` iterations per phase), so perf PRs
    can attribute wins without ad-hoc instrumentation: ring read/clear
    (per-cycle and blocked), neuron update, intra delivery, and inter
    delivery (per-cycle and the superstep's single-pass block).
    """
    from repro.core import delivery, neuron as neuron_lib, ring_buffer
    from repro.core.engine import resolve_params

    backend = cfg.backend
    A, n_pad = net.alive.shape
    D = net.delay_ratio
    # The engines' own param/drive derivation -- the profiler must time the
    # same math Engine.run executes.
    lif_params, drive_rate = resolve_params(net, spec, cfg)
    eng = make_simulation(spec, cfg, net=net)
    st = eng.init()
    st, blk = eng.window(st)  # warmed-up state + a real spike raster
    ring0 = st.ring
    sf = blk[int(np.argsort(np.asarray(blk).reshape(D, -1).sum(1))[D // 2])
             ].astype(jnp.float32)
    block_f = blk.astype(jnp.float32).reshape(D, -1)
    s_max_area, s_max_all = delivery.event_bounds(
        net, headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
    ts = jnp.arange(cycles, dtype=jnp.int32)

    @jax.jit
    def ph_read(ring):
        def body(r, t):
            i_in, r = ring_buffer.read_and_clear(r, t)
            return r, i_in.sum()
        return jax.lax.scan(body, ring, ts)

    @jax.jit
    def ph_read_block(ring):
        def body(r, w):
            blk_, r = ring_buffer.read_and_clear_block(r, w * D, D)
            return r, blk_.sum()
        return jax.lax.scan(body, ring, jnp.arange(cycles // D, dtype=jnp.int32))

    @jax.jit
    def ph_update(nstate):
        def body(ns, t):
            if cfg.neuron_model == "lif":
                gids = jnp.arange(A * n_pad, dtype=jnp.int32).reshape(A, n_pad)
                drive = neuron_lib.poisson_drive(
                    cfg.seed, t, gids, drive_rate, net.dt_ms, spec.w_ext)
                ns, spk = neuron_lib.lif_update(
                    ns, drive, net.alive, lif_params)
            else:
                ns, spk = neuron_lib.ignore_and_fire_update(
                    ns, None, net.alive, net.rate_hz, net.dt_ms)
            return ns, spk.sum()
        return jax.lax.scan(body, nstate, ts)

    @jax.jit
    def ph_intra(ring):
        def body(r, t):
            return delivery.deliver_intra(
                r, sf, net, t, backend=backend, s_max=s_max_area), None
        return jax.lax.scan(body, ring, ts)

    @jax.jit
    def ph_inter(ring):
        def body(r, t):
            return delivery.deliver_inter(
                r, sf.reshape(-1), net, t, backend=backend,
                s_max=s_max_all), None
        return jax.lax.scan(body, ring, ts)

    @jax.jit
    def ph_inter_block(ring):
        def body(r, w):
            return delivery.deliver_inter_block(
                r, block_f, net, w * D, backend=backend,
                s_max=s_max_all), None
        return jax.lax.scan(body, ring, jnp.arange(cycles // D, dtype=jnp.int32))

    rows = [
        ("ring read/clear (per-cycle)", _time_loop(ph_read, ring0)),
        ("ring read/clear (blocked)", _time_loop(ph_read_block, ring0)),
        ("neuron update (+drive)", _time_loop(ph_update, st.neuron)),
        ("intra deliver", _time_loop(ph_intra, ring0)),
        ("inter deliver (per-cycle)", _time_loop(ph_inter, ring0)),
        ("inter deliver (blocked)", _time_loop(ph_inter_block, ring0)),
    ]
    print(f"\n-- phase profile: backend={backend}, {cycles} cycles each --")
    print(f"{'phase':30s} {'us/cycle':>10s} {'cycles/s':>12s}")
    for name, wall in rows:
        print(f"{name:30s} {wall / cycles * 1e6:10.2f} {cycles / wall:12.1f}")
    win = _time_loop(eng.window, st)
    print(f"{'full window / D':30s} {win / D * 1e6:10.2f} {D / win:12.1f}")
    if cfg.schedule == schedule_lib.STRUCTURE_AWARE:
        # Sequential vs the double-buffered pipeline over the same windows:
        # the pipelined run finishes window w's exchange while computing
        # w+1, so the gap is the per-window comm wall the overlap absorbs
        # (bit-identical trajectory either way).
        eng_o = make_simulation(spec, dataclasses.replace(cfg, overlap_exchange=True), net=net)
        k = max(cycles // D, 1)
        seq = _time_loop(lambda s: eng.run(s, k), st)
        pipe = _time_loop(lambda s: eng_o.run(s, k), st)
        print(f"{f'window seq (run x{k})':30s} "
              f"{seq / (k * D) * 1e6:10.2f} {k * D / seq:12.1f}")
        print(f"{'window overlapped (pipeline)':30s} "
              f"{pipe / (k * D) * 1e6:10.2f} {k * D / pipe:12.1f}")
        print(f"  overlap hides {(seq - pipe) / k * 1e6:+.2f} us/window "
              f"({(seq - pipe) / seq * 100:+.1f}% of sequential wall) "
              f"on this host")


def print_wire_volume(net, spec, cfg: EngineConfig, n_groups: int, gsz: int):
    """Dense-vs-routed wire bytes per window (static accounting).

    Pure shape/adjacency arithmetic (repro.core.exchange.wire_report) for an
    ``n_groups x gsz`` structure-aware mesh -- printable on a single host,
    no devices required; the distributed engines report the same numbers on
    ``Engine.wire_bytes``.
    """
    if (net.k_inter == 0 or n_groups < 2
            or net.n_areas % n_groups != 0 or net.n_pad % gsz != 0):
        # A single group has no inter-group traffic to route, and shapes
        # that don't shard would make the modelled bytes meaningless.
        print(f"\n-- wire volume: n/a (A={net.n_areas}, n_pad={net.n_pad} "
              f"on {n_groups} groups x {gsz})")
        return
    rep = exchange_lib.wire_report(
        net, area_adjacency(net, spec), backend=cfg.backend,
        n_groups=n_groups, gsz=gsz,
        headroom=cfg.s_max_headroom, floor=cfg.s_max_floor)
    dense, routed = rep["dense"], rep["routed"]
    print(f"\n-- wire volume (bytes/window, mesh-total, modelled for "
          f"{n_groups} groups x {gsz} subgroup, backend={cfg.backend}) --")
    print(f"{'exchange':10s} {'local':>12s} {'global':>12s} {'total':>12s}"
          f" {'rounds':>8s}")
    print(f"{'dense':10s} {dense['local_bytes']:12,d} "
          f"{dense['global_bytes']:12,d} {dense['total_bytes']:12,d} "
          f"{max(n_groups - 1, 0):8d}")
    print(f"{'routed':10s} {routed['local_bytes']:12,d} "
          f"{routed['global_bytes']:12,d} {routed['total_bytes']:12,d} "
          f"{routed['rounds']:8d}")
    # The adaptive two-phase model next to the static worst case: phase-1
    # count bytes + expectation-sized payload (live runs report measured
    # bytes from SimState.shipped_bytes).
    print(f"{'exchange':10s} {'counts':>12s} {'payload(exp)':>12s} "
          f"{'worst':>12s} {'saved':>12s}  (adaptive two-phase)")
    for name, entry in (("dense", dense), ("routed", routed)):
        ad = entry["adaptive"]
        if not ad["applies"]:
            print(f"{name:10s} {'n/a (bit-packed wire)':>12s}")
            continue
        print(f"{name:10s} {ad['counts_bytes']:12,d} "
              f"{ad['payload_bytes_expected']:12,d} "
              f"{ad['payload_bytes_worst']:12,d} {ad['saved_bytes']:12,d}")
    if net.tgt_inter is not None or net.tgt_inter_in is not None:
        sub = gsz if getattr(cfg, "subgroup_inter_tables", True) else 1
        tbl = exchange_lib.priced_inter_table_report(
            net, n_groups=n_groups, gsz=gsz,
            headroom=cfg.s_max_headroom, floor=cfg.s_max_floor,
            subgroup=sub)
        tb = tbl["table_bytes"]
        print(f"-- inter receive tables, per device: replicated "
              f"{tb['replicated']:,} B (K={tbl['k_out_replicated']}) vs "
              f"sharded {tb['sharded']:,} B (K={tbl['k_in_sharded']}, "
              f"{tbl['n_shards']} shards, {tb['reduction']:.1f}x)")


def _pick_mesh(n_dev: int, n_areas: int, n_pad: int):
    """A (data, model) mesh shape for the structure-aware placement.

    Prefers the largest area-parallel tier (groups) whose shard constraints
    hold: areas divide the groups, the padded area size divides the
    subgroup. Returns None if nothing fits.
    """
    for gsz in range(1, n_dev + 1):
        if n_dev % gsz:
            continue
        groups = n_dev // gsz
        if n_areas % groups == 0 and n_pad % gsz == 0:
            return groups, gsz
    return None


def _run_resilient(args, eng, net, mesh, exchange, n_windows):
    """The checkpointed/fault-injected leg of a run (schedule.run_windows).

    Resumes from ``--checkpoint-dir`` when asked (elastically resharding if
    the group count changed since the checkpoint was taken), wires the fault
    injector into both the run loop and the checkpoint writer, and converts
    simulated preemption into a clean exit with a resume hint. Returns
    ``(state, wall_s, windows_run)`` for the shared reporting path.
    """
    n_groups = int(mesh.shape["data"]) if mesh is not None else 1
    fault_cfg = faults_lib.parse_fault_specs(args.inject_fault,
                                             seed=args.seed)
    injector = None
    if fault_cfg.any_enabled:
        injector = faults_lib.FaultInjector(
            fault_cfg, n_devices=jax.device_count(),
            delay_ratio=eng.delay_ratio)
        if fault_cfg.jitter_enabled:
            print(f"  fault injection: per-device jitter mu="
                  f"{fault_cfg.jitter_mu_ms} ms sigma="
                  f"{fault_cfg.jitter_sigma_ms} ms/cycle -> predicted "
                  f"straggler overhead "
                  f"{injector.predicted_jitter_s() * 1e3:.2f} ms/window "
                  f"(order-statistics sync model)")
        if fault_cfg.comm_enabled:
            print(f"  fault injection: exchange straggler mu="
                  f"{fault_cfg.comm_mu_ms} ms sigma="
                  f"{fault_cfg.comm_sigma_ms} ms/window -> predicted wall "
                  f"sequential {injector.predicted_sequential_s() * 1e3:.2f}"
                  f" (sum) vs overlapped "
                  f"{injector.predicted_overlap_s() * 1e3:.2f} ms/window "
                  f"(Clark E[max])")
    start_w = 0
    if args.resume:
        st, info = schedule_lib.restore_sim(
            args.checkpoint_dir, eng, net, exchange=exchange,
            n_groups=n_groups)
        start_w = int(info["step"])
        resh = info["reshard"]
        if resh is not None:
            print(f"  resumed window {start_w} from {args.checkpoint_dir}: "
                  f"elastic reshard {resh['old_n_groups']} -> "
                  f"{resh['new_n_groups']} groups "
                  f"({resh['moved_areas']} areas re-homed)")
        else:
            print(f"  resumed window {start_w} from {args.checkpoint_dir} "
                  f"on {n_groups} group(s)")
    else:
        st = eng.init()
    ckpt = None
    if args.checkpoint_dir:
        ckpt = schedule_lib.SimCheckpointer(
            args.checkpoint_dir, eng, net, every=args.checkpoint_every,
            keep=args.checkpoint_keep, exchange=exchange,
            n_groups=n_groups, injector=injector)
    remaining = n_windows - start_w
    if remaining <= 0:
        raise SystemExit(
            f"checkpoint already covers {start_w} windows >= the requested "
            f"{n_windows}; increase --t-ms or start a fresh run")
    # A throwaway compile window would advance the trajectory, so the
    # resilient leg pays compilation inside its first timed window.
    stop = StopFlag().install()
    try:
        res = schedule_lib.run_windows(
            eng, st, remaining, checkpointer=ckpt, faults=injector,
            stop_requested=stop)
    except faults_lib.Preempted as exc:
        leg = exc.result.windows_done
        why = f"caught {stop.name}" if stop() else "simulated preemption"
        hint = (f"checkpoint written to {exc.checkpoint_path} -- resume "
                f"with --resume --checkpoint-dir {exc.checkpoint_path}"
                if exc.checkpoint_path
                else "no --checkpoint-dir was given, so nothing was saved")
        print(f"  PREEMPTED ({why}) after window {exc.window} "
              f"({leg} this leg); {hint}")
        raise SystemExit(0)
    if res.overlapped:
        print(f"  overlapped pipeline: {res.drains} in-flight drain(s) at "
              f"checkpoint/end boundaries")
    if ckpt is not None:
        ckpt.close()
        if ckpt.retry_count:
            print(f"  checkpoint writer retried {ckpt.retry_count} "
                  f"transient write failure(s)")
        if ckpt.saved_windows:
            print(f"  checkpoints at windows {ckpt.saved_windows} "
                  f"(every {args.checkpoint_every}, "
                  f"keep {args.checkpoint_keep})")
    if res.injected_sleep_s:
        print(f"  injected jitter: {res.injected_sleep_s:.3f} s total, "
              f"measured {res.injected_sleep_s / res.windows_done * 1e3:.2f} "
              f"ms/window over {res.windows_done} windows")
    return res.state, float(res.window_times_s.sum()), res.windows_done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mam_benchmark",
                    choices=["mam", "mam_benchmark"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--areas", type=int, default=8,
                    help="areas (mam_benchmark only)")
    ap.add_argument("--n-per-area", type=int, default=256)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--t-ms", type=float, default=500.0)
    ap.add_argument("--schedule", default="structure_aware",
                    choices=["conventional", "structure_aware"])
    ap.add_argument("--neuron", default=None,
                    choices=[None, "lif", "ignore_and_fire"])
    ap.add_argument("--backend", default="",
                    choices=["", "onehot", "scatter", "pallas", "event"],
                    help="delivery backend (repro.core.delivery); "
                         "default scatter")
    ap.add_argument("--exchange", default="dense",
                    choices=["dense", "routed"],
                    help="distributed global pathway (repro.core.exchange): "
                         "mesh-wide collectives vs connectivity-routed "
                         "packet rounds (structure-aware schedule only; "
                         "ignored on a single device)")
    ap.add_argument("--replicated-inter-tables", action="store_true",
                    help="keep the legacy replicated inter receive tables "
                         "on every device instead of the sharded inbound "
                         "slices (the bit-identity baseline of the "
                         "sharded-table refactor; distributed event/routed "
                         "paths only)")
    ap.add_argument("--no-subgroup-inter-tables", action="store_true",
                    help="keep the per-group inbound slices (and the "
                         "lane-replicated outgoing intra tables) instead of "
                         "the subgroup-sliced [S, gsz, rows, K_in] / "
                         "[gsz, A, n_pad, K] layouts (the bit-identity "
                         "baseline of the memory-diet PR; structure-aware "
                         "distributed paths only)")
    ap.add_argument("--sharded-build", action="store_true",
                    help="host-free construction "
                         "(EngineConfig.sharded_build): each device's "
                         "inbound inter slices and lane-cut intra tables "
                         "are generated directly from the seeded "
                         "counter-based connectivity rules "
                         "(dist_engine.build_network_sharded) -- no process "
                         "materialises the global synapse tensors. "
                         "Bitwise-identical trajectories to the host build; "
                         "structure-aware event-backend legs on a "
                         "multi-device mesh only")
    ap.add_argument("--seed", type=int, default=12,
                    help="paper seeds: 12, 654, 91856")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive two-phase exchange "
                         "(EngineConfig.adaptive_exchange): counts first, "
                         "then bucket-sized payloads; SimState.overflow is "
                         "provably 0 and asserted after every run")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered window pipeline "
                         "(EngineConfig.overlap_exchange): window w's "
                         "payload exchange overlaps window w+1's compute -- "
                         "bitwise-identical trajectory, structure-aware "
                         "schedule only; on GPU also enables XLA's "
                         "async-collective + latency-hiding-scheduler flags")
    ap.add_argument("--compare", action="store_true",
                    help="run both schedules, assert identical spikes")
    ap.add_argument("--compare-adaptive", action="store_true",
                    help="run every selected schedule with BOTH the static "
                         "and the adaptive exchange, assert bit-identical "
                         "spike counts and zero adaptive overflow")
    ap.add_argument("--compare-overlap", action="store_true",
                    help="run every structure-aware leg BOTH sequential and "
                         "overlapped, assert bit-identical spike counts; "
                         "with a jitter-only --inject-fault spec the legs "
                         "run through the fault harness and the pipelined "
                         "injected wall must beat the sequential one")
    ap.add_argument("--profile", action="store_true",
                    help="report per-phase timings (ring read/clear, update, "
                         "intra/inter deliver) and the dense-vs-routed wire "
                         "volume before the run")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="window-boundary SimState checkpoints through "
                         "checkpoint.AsyncWriter land here; enables the "
                         "resilient windowed run loop")
    ap.add_argument("--checkpoint-every", type=int, default=50,
                    help="checkpoint cadence in completed windows "
                         "(default 50)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="retain this many newest checkpoints (default 3)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from "
                         "--checkpoint-dir and continue -- bitwise-identical "
                         "to the uninterrupted run, elastically resharding "
                         "when the group count changed")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="SPEC",
                    help="deterministic fault injection (repeatable): "
                         "'jitter:mu_ms=1.6,sigma_ms=0.3[,rho=R][,devices=N]"
                         "[,comm_mu_ms=M][,comm_sigma_ms=S]' per-device "
                         "compute jitter plus a per-window exchange "
                         "straggler, 'ckpt-io:fails=K' transient "
                         "checkpoint-write failures, 'preempt:window=W' "
                         "SIGTERM-style stop after W completed windows")
    ap.add_argument("--spikes-out", default=None,
                    help="write the final per-neuron spike_count to this "
                         ".npz (CI resume-equality checks)")
    args = ap.parse_args()

    # --compare-overlap + a jitter-only fault spec is the one sanctioned
    # fault/compare combination: every leg runs the fault harness with the
    # same deterministic draws, so the sequential-vs-pipelined injected
    # walls are directly comparable (the paper's max-vs-sum claim).
    inject_compare = bool(args.inject_fault and args.compare_overlap)
    resilient = bool(args.checkpoint_dir or args.resume
                     or (args.inject_fault and not inject_compare))
    if resilient and (args.compare or args.compare_adaptive
                      or args.compare_overlap):
        raise SystemExit(
            "--checkpoint-dir/--resume/--inject-fault run one trajectory; "
            "they cannot be combined with --compare/--compare-adaptive/"
            "--compare-overlap (exception: --compare-overlap with a "
            "jitter-only --inject-fault spec)")
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume needs --checkpoint-dir")
    compare_fault_cfg = None
    if inject_compare:
        if args.compare or args.compare_adaptive:
            raise SystemExit(
                "--inject-fault with --compare-overlap cannot also run "
                "--compare/--compare-adaptive legs")
        compare_fault_cfg = faults_lib.parse_fault_specs(
            args.inject_fault, seed=args.seed)
        if (compare_fault_cfg.preempt_after_window > 0
                or compare_fault_cfg.ckpt_write_failures > 0):
            raise SystemExit(
                "--compare-overlap only accepts jitter specs in "
                "--inject-fault; preempt/ckpt-io faults run one trajectory")
    wants_overlap = args.overlap or args.compare_overlap
    if wants_overlap and args.schedule == "conventional" and not args.compare:
        raise SystemExit(
            "--overlap/--compare-overlap need the structure-aware schedule "
            "(the conventional schedule has no window-end exchange to hide)")
    if wants_overlap and enable_overlap_flags():
        print("XLA async-collective/latency-hiding flags enabled (gpu)")

    if args.model == "mam":
        spec = mam_spec(scale=args.scale)
        neuron = args.neuron or "lif"
    else:
        spec = mam_benchmark_spec(
            n_areas=args.areas, n_per_area=args.n_per_area,
            k_intra=args.k // 2, k_inter=args.k // 2)
        neuron = args.neuron or "ignore_and_fire"
    backend = args.backend or "scatter"
    needs_outgoing = backend == "event" or args.exchange == "routed"
    n_dev = jax.device_count()
    print(f"{args.model}: {spec.n_total:,} neurons / {spec.n_areas} areas, "
          f"K={spec.k_total}, D={spec.delay_ratio}, neuron={neuron}, "
          f"backend={backend}, exchange={args.exchange}, seed={args.seed}, "
          f"devices={n_dev}")

    n_pad_spec = spec.padded_area_size(1)
    if args.sharded_build:
        if backend != "event":
            raise SystemExit(
                "--sharded-build generates the event path's tables; run "
                "with --backend event")
        if args.replicated_inter_tables:
            raise SystemExit(
                "--sharded-build emits per-shard inbound slices; it cannot "
                "combine with --replicated-inter-tables")
        if n_dev <= 1:
            raise SystemExit(
                "--sharded-build needs a multi-device mesh (the single-host "
                "engine holds the whole network anyway)")
        if args.schedule == "conventional" and not args.compare:
            raise SystemExit(
                "--sharded-build targets the structure-aware placement; "
                "the conventional schedule slices a host-built network")

    # The host-built global network: skipped entirely when every leg builds
    # sharded (the whole point -- its host RSS is the construction wall).
    # The conventional --compare legs and the profiler still need it.
    runs_conventional = args.compare or args.schedule == "conventional"
    needs_host_net = ((not args.sharded_build) or runs_conventional
                      or args.profile)
    net = (build_network(spec, seed=args.seed, outgoing=needs_outgoing)
           if needs_host_net else None)
    mesh = None
    if n_dev > 1:
        shape = _pick_mesh(n_dev, spec.n_areas, n_pad_spec)
        if shape is None:
            raise SystemExit(
                f"no (data, model) mesh over {n_dev} devices fits "
                f"A={spec.n_areas}, n_pad={n_pad_spec}")
        if runs_conventional and n_pad_spec % n_dev != 0:
            # The round-robin placement slices every area over all devices.
            raise SystemExit(
                f"the conventional schedule needs n_pad={n_pad_spec} "
                f"divisible by {n_dev} devices (pick --n-per-area "
                "accordingly, or run --schedule structure_aware)")
        mesh = jax.make_mesh(shape, ("data", "model"))
        print(f"mesh: {shape[0]} area groups x {shape[1]} subgroup devices")

    base_cfg = EngineConfig(
        neuron_model=neuron, schedule=args.schedule,
        delivery_backend=backend, seed=42)
    if args.profile:
        profile_phases(net, spec, base_cfg)
        n_groups, gsz = (
            (mesh.shape["data"], mesh.shape["model"]) if mesh is not None
            else _pick_mesh(8, net.n_areas, net.n_pad) or (1, 8))
        print_wire_volume(net, spec, base_cfg, n_groups, gsz)

    schedules = ([args.schedule] if not args.compare
                 else ["conventional", "structure_aware"])
    adaptives = ([False, True] if args.compare_adaptive
                 else [args.adaptive])
    spikes = {}
    injected = {}
    for sched in schedules:
        for adaptive in adaptives:
          overlaps = ([False, True]
                      if args.compare_overlap and sched == "structure_aware"
                      else [args.overlap and sched == "structure_aware"])
          for overlap_on in overlaps:
            # The routed exchange routes the structure-aware window's lumped
            # global pathway; the conventional schedule always runs dense.
            exchange = (args.exchange if sched == "structure_aware"
                        else "dense")
            sharded_leg = (args.sharded_build and mesh is not None
                           and sched == "structure_aware")
            cfg = EngineConfig(
                neuron_model=neuron, schedule=sched,
                delivery_backend=backend,
                exchange=exchange if mesh is not None else "", seed=42,
                shard_inter_tables=not args.replicated_inter_tables,
                subgroup_inter_tables=not args.no_subgroup_inter_tables,
                adaptive_exchange=adaptive, overlap_exchange=overlap_on,
                sharded_build=sharded_leg)
            leg_net = net
            if mesh is not None:
                from repro.core.dist_engine import build_network_sharded

                if sharded_leg:
                    t0 = time.perf_counter()
                    leg_net = build_network_sharded(
                        spec, mesh, cfg, seed=args.seed)
                    jax.block_until_ready(leg_net.tgt_intra)
                    print(f"  sharded build: tables generated host-free in "
                          f"{time.perf_counter() - t0:.2f} s "
                          f"(no global tensors materialised)")
                eng = make_simulation(spec, cfg, net=leg_net, mesh=mesh)
            else:
                eng = make_simulation(spec, cfg, net=net)
            n_windows = spec.steps_for(args.t_ms) // spec.delay_ratio
            if resilient:
                st, wall, windows_run = _run_resilient(
                    args, eng, leg_net, mesh, exchange, n_windows)
            elif inject_compare:
                # Same deterministic draws for every leg (injector state is
                # keyed by (seed, window)), so the injected walls realize
                # the exact sum-vs-max quantities the sync model prices.
                injector = faults_lib.FaultInjector(
                    compare_fault_cfg, n_devices=n_dev,
                    delay_ratio=eng.delay_ratio)
                res = schedule_lib.run_windows(
                    eng, eng.init(), n_windows, faults=injector)
                st = res.state
                wall = float(res.window_times_s.sum())
                windows_run = res.windows_done
                injected[(sched, adaptive, overlap_on)] = res.injected_sleep_s
            else:
                st = eng.init()
                st, _ = eng.window(st)  # compile
                jax.block_until_ready(st.ring)
                t0 = time.perf_counter()
                st, per_win = eng.run(st, n_windows - 1)
                jax.block_until_ready(st.ring)
                wall = time.perf_counter() - t0
                windows_run = n_windows - 1
            t_s = float(st.t) * spec.dt_ms / 1000.0
            rate = float(st.spike_count.sum()) / (spec.n_total * t_s)
            rtf = wall / (
                max(windows_run, 1) * spec.delay_ratio * spec.dt_ms / 1000)
            overflow = int(st.overflow)
            wire = eng.wire_bytes or {}
            wire_s = (f", {wire['total_bytes']:,} wire B/window (static)"
                      if wire.get("total_bytes") else "")
            measured = float(st.shipped_bytes) / n_windows
            meas_s = (f", {measured:,.0f} measured B/window"
                      if measured else "")
            mode = ("adaptive" if adaptive else "static") + \
                   ("+overlap" if overlap_on else "")
            print(f"  {sched:16s} "
                  f"({exchange if mesh is not None else 'local'}/{mode}):"
                  f" {wall:6.2f} s wall, RTF {rtf:8.1f}, "
                  f"mean rate {rate:5.2f} Hz, "
                  f"{int(st.spike_count.sum()):,} spikes{wire_s}{meas_s}"
                  + (f", OVERFLOW {overflow} (raise s_max!)"
                     if overflow else ""))
            if adaptive and overflow:
                raise SystemExit(
                    "adaptive exchange reported nonzero overflow -- the "
                    "two-phase sizing is broken (this must be impossible)")
            spikes[(sched, adaptive, overlap_on)] = np.asarray(st.spike_count)
            if args.spikes_out:
                np.savez(args.spikes_out,
                         spike_count=np.asarray(st.spike_count),
                         t=int(st.t))
                print(f"  spike counts -> {args.spikes_out}")

    if args.compare:
        for adaptive in adaptives:
            ref = spikes[("conventional", adaptive, False)]
            for (sched, ad, ovl), spk in spikes.items():
                if sched == "conventional" or ad != adaptive:
                    continue
                same = np.array_equal(ref, spk)
                mode = ("adaptive" if ad else "static") + \
                       ("+overlap" if ovl else "")
                print(f"\nschedules produce identical spike counts "
                      f"({mode}): {same}")
                if not same:
                    raise SystemExit(1)
    if args.compare_adaptive:
        for sched in schedules:
            for ovl in sorted({o for (s, _, o) in spikes if s == sched}):
                same = np.array_equal(spikes[(sched, False, ovl)],
                                      spikes[(sched, True, ovl)])
                print(f"adaptive == static spike counts "
                      f"({sched}{'/overlap' if ovl else ''}): {same}")
                if not same:
                    raise SystemExit(1)
    if args.compare_overlap:
        for (sched, adaptive, ovl) in sorted(spikes):
            if not ovl:
                continue
            same = np.array_equal(spikes[(sched, adaptive, False)],
                                  spikes[(sched, adaptive, True)])
            mode = "adaptive" if adaptive else "static"
            print(f"overlapped == sequential spike counts "
                  f"({sched}/{mode}): {same}")
            if not same:
                raise SystemExit(1)
        if inject_compare and compare_fault_cfg.comm_enabled:
            for (sched, adaptive, ovl), pipe_wall in sorted(
                    injected.items()):
                if not ovl:
                    continue
                seq_wall = injected[(sched, adaptive, False)]
                mode = "adaptive" if adaptive else "static"
                print(f"injected wall ({sched}/{mode}): sequential "
                      f"{seq_wall:.3f} s (sum) vs pipelined "
                      f"{pipe_wall:.3f} s (max) -- "
                      f"{(1 - pipe_wall / seq_wall) * 100:.1f}% hidden")
                if not pipe_wall < seq_wall:
                    raise SystemExit(
                        "pipelined injected wall failed to beat the "
                        "sequential wall under jitter -- the overlap is "
                        "not hiding the exchange")


if __name__ == "__main__":
    main()
