"""Production SNN simulation launcher (the paper's state-propagation driver).

    PYTHONPATH=src python -m repro.launch.simulate --model mam --scale 0.002 \
        --t-ms 500 --schedule structure_aware --delivery event

Runs on whatever devices exist: a single device uses the reference engine; a
multi-device mesh (e.g. under XLA_FLAGS=--xla_force_host_platform_device_count=8
or on real TPU pods) uses the distributed two-tier engine. Reports per-window
wall time, spike statistics, and -- with ``--compare`` -- verifies the
conventional and structure-aware schedules produce identical spikes.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.areas import mam_benchmark_spec, mam_spec
from repro.core.connectivity import build_network
from repro.core.engine import EngineConfig, make_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mam_benchmark",
                    choices=["mam", "mam_benchmark"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--areas", type=int, default=8,
                    help="areas (mam_benchmark only)")
    ap.add_argument("--n-per-area", type=int, default=256)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--t-ms", type=float, default=500.0)
    ap.add_argument("--schedule", default="structure_aware",
                    choices=["conventional", "structure_aware"])
    ap.add_argument("--neuron", default=None,
                    choices=[None, "lif", "ignore_and_fire"])
    ap.add_argument("--delivery", default="dense", choices=["dense", "event"],
                    help="legacy knob; prefer --backend")
    ap.add_argument("--backend", default="",
                    choices=["", "onehot", "scatter", "pallas", "event"],
                    help="delivery backend (repro.core.delivery); "
                         "empty derives from --delivery")
    ap.add_argument("--seed", type=int, default=12,
                    help="paper seeds: 12, 654, 91856")
    ap.add_argument("--compare", action="store_true",
                    help="run both schedules, assert identical spikes")
    args = ap.parse_args()

    if args.model == "mam":
        spec = mam_spec(scale=args.scale)
        neuron = args.neuron or "lif"
    else:
        spec = mam_benchmark_spec(
            n_areas=args.areas, n_per_area=args.n_per_area,
            k_intra=args.k // 2, k_inter=args.k // 2)
        neuron = args.neuron or "ignore_and_fire"
    needs_outgoing = args.backend == "event" or args.delivery == "event"
    print(f"{args.model}: {spec.n_total:,} neurons / {spec.n_areas} areas, "
          f"K={spec.k_total}, D={spec.delay_ratio}, neuron={neuron}, "
          f"backend={args.backend or args.delivery}, seed={args.seed}")

    net = build_network(spec, seed=args.seed, outgoing=needs_outgoing)
    schedules = ([args.schedule] if not args.compare
                 else ["conventional", "structure_aware"])
    spikes = {}
    for sched in schedules:
        eng = make_engine(net, spec, EngineConfig(
            neuron_model=neuron, schedule=sched, delivery=args.delivery,
            delivery_backend=args.backend, deposit_onehot=False, seed=42))
        st = eng.init()
        n_windows = spec.steps_for(args.t_ms) // spec.delay_ratio
        st, _ = eng.window(st)  # compile
        jax.block_until_ready(st.ring)
        t0 = time.perf_counter()
        st, per_win = eng.run(st, n_windows - 1)
        jax.block_until_ready(st.ring)
        wall = time.perf_counter() - t0
        t_s = float(st.t) * spec.dt_ms / 1000.0
        rate = float(st.spike_count.sum()) / (spec.n_total * t_s)
        rtf = wall / ((n_windows - 1) * spec.delay_ratio * spec.dt_ms / 1000)
        overflow = int(st.overflow)
        print(f"  {sched:16s}: {wall:6.2f} s wall, RTF {rtf:8.1f}, "
              f"mean rate {rate:5.2f} Hz, "
              f"{int(st.spike_count.sum()):,} spikes"
              + (f", OVERFLOW {overflow} (raise s_max!)" if overflow else ""))
        spikes[sched] = np.asarray(st.spike_count)

    if args.compare:
        same = np.array_equal(spikes["conventional"],
                              spikes["structure_aware"])
        print(f"\nschedules produce identical spike counts: {same}")
        if not same:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
