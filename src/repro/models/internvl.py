"""InternVL2-style VLM: InternLM2 language backbone + stubbed ViT frontend.

Per the assignment the InternViT frontend is a STUB: ``input_specs()``
supplies precomputed patch embeddings [B, n_patches, d_vit] (what the vision
tower + pixel-shuffle would produce). This module projects them with the
MLP connector and splices them over the first ``n_patches`` token positions
of the language backbone (the '<img>' context-token convention).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.transformer import Transformer, TransformerConfig

__all__ = ["InternVLConfig", "InternVL"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class InternVLConfig:
    name: str
    backbone: TransformerConfig
    d_vit: int = 1024       # stubbed patch-embedding width
    n_patches: int = 256    # image tokens per sample

    @property
    def pdtype(self):
        return self.backbone.pdtype

    @property
    def cdtype(self):
        return self.backbone.cdtype

    def param_count(self) -> int:
        d = self.backbone.d_model
        connector = self.d_vit * d + d * d + 2 * d  # 2-layer MLP connector
        return self.backbone.param_count() + connector

    def active_param_count(self) -> int:
        return self.param_count() - self.backbone.param_count() \
            + self.backbone.active_param_count()


class InternVL:
    def __init__(self, cfg: InternVLConfig):
        self.cfg = cfg
        self.lm = Transformer(cfg.backbone)

    def init_params(self, key: jax.Array) -> Params:
        k_lm, k_c1, k_c2 = jax.random.split(key, 3)
        d = self.cfg.backbone.d_model
        return {
            "lm": self.lm.init_params(k_lm),
            "connector": {
                "fc1": layers.dense_init(k_c1, self.cfg.d_vit, d, bias=True,
                                         dtype=self.cfg.pdtype),
                "fc2": layers.dense_init(k_c2, d, d, bias=True,
                                         dtype=self.cfg.pdtype),
            },
        }

    def _splice(self, params: Params, tokens: jax.Array,
                patch_embeds: jax.Array) -> jax.Array:
        """Project patch embeddings and overwrite the first n_patches slots."""
        h = params["lm"]["embed"][tokens].astype(self.cfg.cdtype)
        c = params["connector"]
        img = layers.dense(c["fc2"], jax.nn.gelu(
            layers.dense(c["fc1"], patch_embeds.astype(self.cfg.cdtype))
        ))
        n_p = self.cfg.n_patches
        return jnp.concatenate([img[:, :n_p], h[:, n_p:]], axis=1)

    def hidden(self, params: Params, tokens: jax.Array, *,
               patch_embeds: jax.Array, positions=None):
        h0 = self._splice(params, tokens, patch_embeds)
        return self.lm.hidden(
            params["lm"], tokens, embeds_override=h0, positions=positions
        )

    def unembed(self, params: Params, h: jax.Array) -> jax.Array:
        return self.lm.unembed(params["lm"], h)

    def forward(self, params: Params, tokens: jax.Array, *,
                patch_embeds: jax.Array, positions=None):
        h, aux = self.hidden(params, tokens, patch_embeds=patch_embeds,
                             positions=positions)
        return self.unembed(params, h), aux

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        return self.lm.init_cache(batch, max_len, dtype)

    def forward_with_cache(self, params, tokens, cache, cache_index, *,
                           patch_embeds: jax.Array | None = None,
                           last_only: bool = False):
        """Decode steps never carry image tokens; prefill may."""
        if patch_embeds is not None:
            # Prefill path: splice the projected patch embeddings, then run
            # the backbone's cached forward with the override.
            h0 = self._splice(params, tokens, patch_embeds)
            return self.lm.forward_with_cache(
                params["lm"], tokens, cache, cache_index,
                last_only=last_only, embeds_override=h0,
            )
        return self.lm.forward_with_cache(
            params["lm"], tokens, cache, cache_index, last_only=last_only
        )

    def param_pspecs(self, *, fsdp: str | None = "data", tp: str = "model") -> Params:
        return {
            "lm": self.lm.param_pspecs(fsdp=fsdp, tp=tp),
            "connector": {
                "fc1": {"w": P(None, fsdp), "b": P(None)},
                "fc2": {"w": P(fsdp, tp), "b": P(tp)},
            },
        }

    def cache_pspecs(self, *, batch_axes, seq_axis=None, head_axis=None) -> Params:
        return self.lm.cache_pspecs(
            batch_axes=batch_axes, seq_axis=seq_axis, head_axis=head_axis
        )
