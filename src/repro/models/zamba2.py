"""Zamba2: Mamba2 backbone + one *shared* attention block (hybrid).

Zamba2 interleaves a single shared transformer block into a Mamba2 stack: the
same attention+MLP parameters are re-applied every ``period`` mamba layers,
with the block input being concat(current hidden, original embedding)
projected back to d_model. We implement exactly that structure (the published
per-invocation LoRA deltas are omitted; noted in DESIGN.md).

Layout: ``n_apps`` groups of (shared block -> ``period`` mamba layers), plus
``n_tail`` trailing mamba layers: n_layers = n_apps * period + n_tail.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.mamba2 import Mamba2, Mamba2Config

__all__ = ["Zamba2Config", "Zamba2"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Zamba2Config:
    name: str
    n_layers: int           # mamba2 layers
    d_model: int
    vocab: int
    n_heads: int = 32
    n_kv: int = 32
    d_head: int = 64
    d_ff: int = 8192
    period: int = 6         # shared block applied every `period` mamba layers
    d_state: int = 64
    headdim: int = 64
    chunk: int = 128
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"
    act_batch_axes: tuple[str, ...] | None = None
    attn_sharding: str | None = None

    @property
    def n_apps(self) -> int:
        return self.n_layers // self.period

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_apps * self.period

    def mamba_cfg(self) -> Mamba2Config:
        return Mamba2Config(
            name=f"{self.name}-mamba",
            n_layers=self.n_layers,
            d_model=self.d_model,
            vocab=self.vocab,
            d_state=self.d_state,
            headdim=self.headdim,
            chunk=self.chunk,
            param_dtype=self.param_dtype,
            compute_dtype=self.compute_dtype,
            act_batch_axes=self.act_batch_axes,
        )

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        base = self.mamba_cfg().param_count()
        d = self.d_model
        shared = (
            2 * d * d  # in_proj [2d, d]
            + d * self.n_heads * self.d_head * 2
            + d * self.n_kv * self.d_head * 2
            + 3 * d * self.d_ff
            + 4 * d
        )
        return base + shared

    def active_param_count(self) -> int:
        return self.param_count()


class Zamba2:
    def __init__(self, cfg: Zamba2Config):
        self.cfg = cfg
        self.mamba = Mamba2(cfg.mamba_cfg())

    # ------------------------------------------------------------------ init

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        pd = cfg.pdtype
        k_m, k_s1, k_s2, k_s3 = jax.random.split(key, 4)
        base = self.mamba.init_params(k_m)
        # Split the stacked mamba layers into the grouped head + the tail.
        n_grp = cfg.n_apps * cfg.period
        grouped = jax.tree.map(lambda x: x[:n_grp], base["layers"])
        tail = jax.tree.map(lambda x: x[n_grp:], base["layers"])

        shared = {
            "in_proj": layers.dense_init(k_s1, 2 * cfg.d_model, cfg.d_model, dtype=pd),
            "ln1": layers.rms_norm_init(cfg.d_model, pd),
            "attn": layers.attention_init(
                k_s2, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head, dtype=pd
            ),
            "ln2": layers.rms_norm_init(cfg.d_model, pd),
            "ffn": layers.swiglu_init(k_s3, cfg.d_model, cfg.d_ff, pd),
        }
        return {
            "embed": base["embed"],
            "shared": shared,
            "groups": grouped,      # leaves: [n_apps, period, ...]
            "tail": tail,           # leaves: [n_tail, ...]
            "final_norm": base["final_norm"],
            "lm_head": base["lm_head"],
        }

    # --------------------------------------------------------------- forward

    def _shared_block(self, p: Params, h, x0, positions, kv_cache=None,
                      cache_index=None):
        cfg = self.cfg
        z = layers.dense(p["in_proj"], jnp.concatenate([h, x0], axis=-1))
        attn_pspecs = None
        if cfg.act_batch_axes is not None and cfg.attn_sharding is not None:
            spec = P(cfg.act_batch_axes, None, "model", None)
            attn_pspecs = (spec, spec)
        attn_out, new_kv = layers.gqa_attention(
            p["attn"], layers.rms_norm(p["ln1"], z), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.d_head,
            kv_cache=kv_cache, cache_index=cache_index,
            attn_pspecs=attn_pspecs,
        )
        z = z + attn_out
        z = z + layers.swiglu(p["ffn"], layers.rms_norm(p["ln2"], z))
        return h + z, new_kv

    def hidden(self, params: Params, tokens: jax.Array,
               *, embeds_override=None, positions=None):
        cfg = self.cfg
        b, s = tokens.shape
        h = params["embed"][tokens].astype(cfg.cdtype)
        if embeds_override is not None:
            h = embeds_override.astype(cfg.cdtype)
        x0 = h
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        mamba = self.mamba

        def mamba_body(h, p_layer):
            out, _ = mamba._mixer(p_layer, layers.rms_norm(p_layer["norm"], h))
            return h + out, None

        if cfg.remat in ("full", "dots"):
            mamba_body = jax.checkpoint(mamba_body)

        # groups leaves are [n_apps * period, ...]; rechunk to scan over apps
        grp = jax.tree.map(
            lambda x: x.reshape((cfg.n_apps, cfg.period) + x.shape[1:]),
            params["groups"],
        )

        def app_body(h, p_app):
            h, _ = self._shared_block(params["shared"], h, x0, positions)
            h, _ = jax.lax.scan(mamba_body, h, p_app)
            return h, None

        h, _ = jax.lax.scan(app_body, h, grp)
        if cfg.n_tail:
            h, _ = jax.lax.scan(mamba_body, h, params["tail"])

        return layers.rms_norm(params["final_norm"], h), jnp.float32(0.0)

    def unembed(self, params: Params, h: jax.Array) -> jax.Array:
        logits = h @ params["lm_head"].astype(h.dtype)
        if self.cfg.act_batch_axes is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, P(self.cfg.act_batch_axes, None, "model"))
        return logits

    def forward(self, params: Params, tokens: jax.Array,
                *, embeds_override=None, positions=None):
        h, aux = self.hidden(params, tokens, embeds_override=embeds_override,
                             positions=positions)
        return self.unembed(params, h), aux

    # -------------------------------------------------------------- serving

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        mc = cfg.mamba_cfg()
        kv = (cfg.n_apps, batch, max_len, cfg.n_kv, cfg.d_head)
        return {
            "x0": jnp.zeros((batch, 1, cfg.d_model), dtype),  # unused slot kept
            "attn_k": jnp.zeros(kv, dtype),
            "attn_v": jnp.zeros(kv, dtype),
            "conv": jnp.zeros(
                (cfg.n_layers, batch, mc.d_conv - 1, mc.conv_dim), dtype
            ),
            "ssm": jnp.zeros(
                (cfg.n_layers, batch, mc.n_heads, mc.headdim, mc.d_state),
                jnp.float32,
            ),
        }

    def forward_with_cache(self, params, tokens, cache, cache_index,
                           *, last_only: bool = False):
        cfg = self.cfg
        b, s = tokens.shape
        h = params["embed"][tokens].astype(cfg.cdtype)
        x0 = h
        positions = cache_index + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s)
        )
        mamba = self.mamba

        def mamba_body(h, xs):
            p_layer, state = xs
            out, new_state = mamba._mixer(
                p_layer, layers.rms_norm(p_layer["norm"], h), state
            )
            return h + out, new_state

        n_grp = cfg.n_apps * cfg.period
        grp = jax.tree.map(
            lambda x: x.reshape((cfg.n_apps, cfg.period) + x.shape[1:]),
            params["groups"],
        )
        grp_state = {
            "conv": cache["conv"][:n_grp].reshape(
                (cfg.n_apps, cfg.period) + cache["conv"].shape[1:]),
            "ssm": cache["ssm"][:n_grp].reshape(
                (cfg.n_apps, cfg.period) + cache["ssm"].shape[1:]),
        }

        def app_body(h, xs):
            p_app, st_app, kv_k, kv_v = xs
            h, new_kv = self._shared_block(
                params["shared"], h, x0, positions,
                kv_cache=(kv_k, kv_v), cache_index=cache_index,
            )
            h, new_st = jax.lax.scan(
                mamba_body, h,
                (p_app, {"conv": st_app["conv"], "ssm": st_app["ssm"]}),
            )
            return h, (new_st, new_kv[0], new_kv[1])

        h, (new_grp_state, new_k, new_v) = jax.lax.scan(
            app_body, h, (grp, grp_state, cache["attn_k"], cache["attn_v"])
        )
        new_conv = new_grp_state["conv"].reshape((n_grp,) + cache["conv"].shape[1:])
        new_ssm = new_grp_state["ssm"].reshape((n_grp,) + cache["ssm"].shape[1:])
        if cfg.n_tail:
            tail_state = {"conv": cache["conv"][n_grp:], "ssm": cache["ssm"][n_grp:]}
            h, new_tail = jax.lax.scan(
                mamba_body, h, (params["tail"], tail_state)
            )
            new_conv = jnp.concatenate([new_conv, new_tail["conv"]], axis=0)
            new_ssm = jnp.concatenate([new_ssm, new_tail["ssm"]], axis=0)
        h = layers.rms_norm(params["final_norm"], h)
        if last_only:
            h = h[:, -1:]
        new_cache = {
            "x0": cache["x0"],
            "attn_k": new_k, "attn_v": new_v,
            "conv": new_conv.astype(cache["conv"].dtype),
            "ssm": new_ssm,
        }
        return h @ params["lm_head"].astype(h.dtype), new_cache

    # ---------------------------------------------------------------- specs

    def param_pspecs(self, *, fsdp: str | None = "data", tp: str = "model") -> Params:
        mspecs = self.mamba.param_pspecs(fsdp=fsdp, tp=tp)
        layer = mspecs["layers"]
        shared = {
            "in_proj": {"w": P(fsdp, tp)},
            "ln1": {"scale": P(None)},
            "attn": {
                "q": {"w": P(fsdp, tp)},
                "k": {"w": P(fsdp, tp)},
                "v": {"w": P(fsdp, tp)},
                "o": {"w": P(tp, fsdp)},
            },
            "ln2": {"scale": P(None)},
            "ffn": {
                "gate": {"w": P(fsdp, tp)},
                "up": {"w": P(fsdp, tp)},
                "down": {"w": P(tp, fsdp)},
            },
        }
        return {
            "embed": mspecs["embed"],
            "shared": shared,
            "groups": layer,
            "tail": layer,
            "final_norm": {"scale": P(None)},
            "lm_head": mspecs["lm_head"],
        }

    def cache_pspecs(self, *, batch_axes, seq_axis=None, head_axis=None) -> Params:
        return {
            "x0": P(batch_axes, None, None),
            "attn_k": P(None, batch_axes, seq_axis, head_axis, None),
            "attn_v": P(None, batch_axes, seq_axis, head_axis, None),
            "conv": P(None, batch_axes, None, None),
            "ssm": P(None, batch_axes, "model", None, None),
        }
