"""Mixture-of-Experts layer: top-k router with capacity-based dispatch.

Dispatch is GShard/Switch-style: each token picks its top-k experts; tokens
beyond an expert's capacity ``C = ceil(T / E * k * capacity_factor)`` are
dropped (their residual passes through). Dense one-hot dispatch would charge
all-experts FLOPs to every token and poison the roofline's compute term, so
the implementation gathers tokens into per-expert buffers ``[E, C, D]``: the
compiled FLOPs are the *active* FLOPs (6 N_active D), matching the MoE
roofline convention.

Sharding: with ``expert_sharding='ep'`` the leading E axis lives on the
``model`` mesh axis (expert parallelism; dispatch/combine lower to
all-to-alls). With ``'tp'`` every device holds all experts but shards d_ff
(tensor parallelism inside experts) -- the right choice when E is smaller
than the mesh axis (e.g. grok-1's 8 experts on a 16-way axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

__all__ = ["MoEConfig", "moe_init", "moe_apply", "moe_pspecs"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden width
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on expert
    interleave: int = 1          # every `interleave`-th layer is MoE
    expert_sharding: str = "ep"  # 'ep' | 'tp'


def moe_init(
    key: jax.Array, d_model: int, cfg: MoEConfig, dtype=jnp.float32
) -> Params:
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    e, ff = cfg.n_experts, cfg.d_ff
    scale = 1.0 / jnp.sqrt(d_model)
    p: Params = {
        "router": layers.dense_init(k_r, d_model, e, dtype=jnp.float32),
        "gate_w": (jax.random.normal(k_g, (e, d_model, ff)) * scale).astype(dtype),
        "up_w": (jax.random.normal(k_u, (e, d_model, ff)) * scale).astype(dtype),
        "down_w": (jax.random.normal(k_d, (e, ff, d_model)) / jnp.sqrt(ff)).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared"] = layers.swiglu_init(k_s, d_model, ff, dtype=dtype)
    return p


def moe_apply(
    p: Params, x: jax.Array, cfg: MoEConfig,
    act_axes: tuple[str, ...] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B, S, D], load-balance aux loss scalar).

    Dispatch is *per sequence* (GShard group = batch element): the expert
    buffers are [B, E, cap_s, D] with ``cap_s = ceil(S * k * cf / E)``, so
    they inherit the batch's data-parallel sharding. A single global buffer
    would be unsharded along its capacity axis and replicate gigabytes per
    device at production batch sizes.

    ``act_axes`` pins the buffer layouts explicitly: without the pin, the
    contraction over the FSDP-sharded d_model axis makes XLA *un-shard the
    batch* of the expert-hidden tensors (tens of GiB per device for grok at
    32k prefill); with it, XLA gathers the (much smaller) per-layer expert
    weights instead -- standard ZeRO-3 behaviour.
    """
    from jax.sharding import PartitionSpec as P
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, -(-s * k * cfg.capacity_factor // e)))  # ceil, per seq

    gates = jax.nn.softmax(
        layers.dense(p["router"], x.astype(jnp.float32)), axis=-1
    )  # [B, S, E] f32
    gate_vals, expert_idx = jax.lax.top_k(gates, k)  # [B, S, k]
    # Renormalise the selected gates (standard for top-k > 1).
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * sum_e f_e * P_e (over all tokens).
    me = gates.mean(axis=(0, 1))                               # [E]
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # ---- capacity dispatch (token-major, slot-minor priority, per seq) ----
    flat_e = expert_idx.reshape(bsz, s * k)                    # [B, S*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # [B, S*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot             # entries before
    pos = (pos_in_e * onehot).sum(-1)                          # [B, S*k]
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                           # overflow slot

    tok_id = jnp.repeat(jnp.arange(s), k)[None, :]             # [1, S*k]
    updates = jnp.take_along_axis(
        x, jnp.broadcast_to(tok_id, (bsz, s * k))[..., None], axis=1
    )  # [B, S*k, D]
    # Scatter tokens into [B, E, cap+1, D]; the +1 slot absorbs drops.
    # vmap over B declares the batch as a scatter *batching* dim -- without
    # it, SPMD cannot partition the scatter and all-gathers the whole batch.
    xe = jax.vmap(lambda e_i, s_i, u: jnp.zeros(
        (e, cap + 1, d), x.dtype).at[e_i, s_i].set(u))(flat_e, slot, updates)
    xe = xe[:, :, :cap]                                        # [B, E, cap, D]

    e_ax = "model" if cfg.expert_sharding == "ep" else None
    f_ax = None if cfg.expert_sharding == "ep" else "model"

    def pin(t, spec):
        if act_axes is None:
            return t
        return jax.lax.with_sharding_constraint(t, P(*spec))

    xe = pin(xe, (act_axes, e_ax, None, None))

    # ---- expert FFN (gated) ------------------------------------------------
    gw = p["gate_w"].astype(x.dtype)
    uw = p["up_w"].astype(x.dtype)
    dw = p["down_w"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, gw))
    h = pin(h, (act_axes, e_ax, None, f_ax))
    h = h * jnp.einsum("becd,edf->becf", xe, uw)
    ye = jnp.einsum("becf,efd->becd", h, dw)                   # [B, E, cap, D]
    ye = pin(ye, (act_axes, e_ax, None, None))

    # ---- combine (vmap'd gather, same batching-dim argument) --------------
    ye_pad = jnp.concatenate(
        [ye, jnp.zeros((bsz, e, 1, d), ye.dtype)], axis=2)
    gathered = jax.vmap(lambda buf, e_i, s_i: buf[e_i, s_i])(
        ye_pad, flat_e, slot)                                  # [B, S*k, D]
    weights = (gate_vals.reshape(bsz, s * k) * keep).astype(x.dtype)
    combined = (gathered * weights[..., None]).reshape(bsz, s, k, d).sum(axis=2)

    if cfg.shared_expert:
        combined = combined + layers.swiglu(p["shared"], x)

    return combined, aux.astype(jnp.float32)


def moe_pspecs(cfg: MoEConfig, fsdp: str | None, tp: str) -> Params:
    """PartitionSpecs mirroring :func:`moe_init` (no leading stack axis)."""
    from jax.sharding import PartitionSpec as P

    if cfg.expert_sharding == "ep":
        expert_in = P(tp, fsdp, None)     # [E, D, ff]: experts over model axis
        expert_out = P(tp, None, fsdp)    # [E, ff, D]
    else:  # 'tp': shard d_ff inside every expert
        expert_in = P(None, fsdp, tp)
        expert_out = P(None, tp, fsdp)
    p = {
        "router": {"w": P(fsdp, None)},
        "gate_w": expert_in,
        "up_w": expert_in,
        "down_w": expert_out,
    }
    if cfg.shared_expert:
        p["shared"] = {
            "gate": {"w": P(fsdp, tp)},
            "up": {"w": P(fsdp, tp)},
            "down": {"w": P(tp, fsdp)},
        }
    return p
