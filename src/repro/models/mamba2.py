"""Mamba2 (SSD -- state-space duality) language model, scan-stacked.

Implements the chunked SSD algorithm of Dao & Gu (2024): within a chunk the
recurrence is materialised as a masked attention-like quadratic form (MXU
friendly); across chunks a tiny [H, P, N] state is carried by a scan. Decode
is the O(1) recurrence -- this is why ``long_500k`` runs for mamba2 while
full-attention models are skipped.

Shapes: B batch, S seq, H heads, P headdim, N d_state, G B/C groups.
The per-head B/C tensors are never materialised (einsums keep the G axis),
which keeps activation memory linear in G, not H.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers

__all__ = ["Mamba2Config", "Mamba2"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 128
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"
    act_batch_axes: tuple[str, ...] | None = None
    attn_sharding: str | None = None  # accepted for uniform overrides; no-op

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        d, di, h = self.d_model, self.d_inner, self.n_heads
        in_proj = d * (2 * di + 2 * self.n_groups * self.d_state + h)
        conv = self.d_conv * self.conv_dim + self.conv_dim
        per_layer = in_proj + conv + 3 * h + di + di * d + 2 * d
        return self.vocab * d + d + self.n_layers * per_layer

    def active_param_count(self) -> int:
        return self.param_count()


def _segsum(x: jax.Array) -> jax.Array:
    """segsum(x)[..., i, j] = sum_{k=j+1..i} x_k (i >= j), -inf above diag."""
    q = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # [..., i, j]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


class Mamba2:
    def __init__(self, cfg: Mamba2Config):
        self.cfg = cfg

    # ------------------------------------------------------------------ init

    def _init_layer(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        pd = cfg.pdtype
        d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
        dt = jnp.exp(
            jax.random.uniform(k3, (cfg.n_heads,))
            * (math.log(0.1) - math.log(0.001)) + math.log(0.001)
        )
        return {
            "norm": layers.rms_norm_init(cfg.d_model, pd),
            "in_proj": layers.dense_init(k1, cfg.d_model, d_in_proj, dtype=pd),
            "conv_w": (jax.random.normal(k2, (cfg.d_conv, cfg.conv_dim))
                       / math.sqrt(cfg.d_conv)).astype(pd),
            "conv_b": jnp.zeros((cfg.conv_dim,), pd),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(jnp.float32),
            "D": jnp.ones((cfg.n_heads,), jnp.float32),
            "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
            "gated_norm": layers.rms_norm_init(cfg.d_inner, pd),
            "out_proj": layers.dense_init(k4, cfg.d_inner, cfg.d_model, dtype=pd),
        }

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_e, k_l, k_h = jax.random.split(key, 3)
        lkeys = jax.random.split(k_l, cfg.n_layers)
        return {
            "embed": (jax.random.normal(k_e, (cfg.vocab, cfg.d_model)) * 0.02
                      ).astype(cfg.pdtype),
            "layers": jax.vmap(self._init_layer)(lkeys),
            "final_norm": layers.rms_norm_init(cfg.d_model, cfg.pdtype),
            "lm_head": (jax.random.normal(k_h, (cfg.d_model, cfg.vocab))
                        / math.sqrt(cfg.d_model)).astype(cfg.pdtype),
        }

    # ------------------------------------------------------------- SSD core

    def _split_proj(self, p: Params, u: jax.Array):
        cfg = self.cfg
        zxbcdt = layers.dense(p["in_proj"], u)
        z, xbc, dt = jnp.split(
            zxbcdt,
            [cfg.d_inner, cfg.d_inner + cfg.conv_dim],
            axis=-1,
        )
        return z, xbc, dt

    def _conv(self, p: Params, xbc: jax.Array, conv_state: jax.Array | None):
        """Depthwise causal conv over S; optionally seeded by a decode state.

        xbc: [B, S, conv_dim]. conv_state: [B, d_conv-1, conv_dim] or None.
        Returns (activated conv output, new conv state)."""
        cfg = self.cfg
        w = p["conv_w"].astype(xbc.dtype)  # [d_conv, conv_dim]
        pad = cfg.d_conv - 1
        if conv_state is None:
            xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
        else:
            xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        out = sum(
            xp[:, i : i + xbc.shape[1]] * w[i]
            for i in range(cfg.d_conv)
        ) + p["conv_b"].astype(xbc.dtype)
        new_state = xp[:, -pad:] if pad > 0 else xp[:, :0]
        return jax.nn.silu(out), new_state

    def _ssd_chunked(self, p, x, b_mat, c_mat, dt, h0=None):
        """Chunked SSD scan.

        x: [B, S, H, P]; b_mat/c_mat: [B, S, G, N]; dt: [B, S, H] (softplus'd).
        h0: optional initial state [B, H, P, N]. Returns (y [B,S,H,P], h_last).
        """
        cfg = self.cfg
        bsz, s, h, pdim = x.shape
        g, n = b_mat.shape[2], b_mat.shape[3]
        hg = h // g
        q = min(cfg.chunk, s)
        while s % q:  # odd lengths (smoke tests): largest divisor <= chunk
            q -= 1
        nc = s // q

        a = -jnp.exp(p["A_log"].astype(jnp.float32))          # [H]
        dta = dt.astype(jnp.float32) * a                       # [B, S, H]
        # reshape into chunks
        xq = x.reshape(bsz, nc, q, g, hg, pdim)
        bq = b_mat.reshape(bsz, nc, q, g, n)
        cq = c_mat.reshape(bsz, nc, q, g, n)
        dtq = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
        dtaq = dta.reshape(bsz, nc, q, h)
        cum = jnp.cumsum(dtaq, axis=2)                         # [B, nc, Q, H]

        # --- intra-chunk (diagonal block): masked quadratic form
        lmat = jnp.exp(_segsum(dtaq.transpose(0, 1, 3, 2)))    # [B, nc, H, Q, Q]
        lmat = lmat.reshape(bsz, nc, g, hg, q, q)
        scores = jnp.einsum("bcign,bcjgn->bcgij", cq.astype(jnp.float32),
                            bq.astype(jnp.float32))            # [B, nc, G, Q, Q]
        dtx = xq.astype(jnp.float32) * dtq.reshape(bsz, nc, q, g, hg)[..., None]
        y_diag = jnp.einsum("bcgij,bcghij,bcjghp->bcighp", scores, lmat, dtx)

        # --- chunk end-states
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [B, nc, Q, H]
        dte = decay_to_end.reshape(bsz, nc, q, g, hg)
        s_end = jnp.einsum("bcjghp,bcjgh,bcjgn->bcghpn", dtx, dte, bq.astype(jnp.float32))

        # --- inter-chunk recurrence over the tiny state
        total_decay = jnp.exp(cum[:, :, -1, :])                # [B, nc, H]

        def step(h_prev, xs):
            s_e, dec = xs  # [B, G, Hg, P, N], [B, H]
            d = dec.reshape(bsz, g, hg)[..., None, None]
            h_new = h_prev * d + s_e
            return h_new, h_prev

        if h0 is None:
            h0 = jnp.zeros((bsz, g, hg, pdim, n), jnp.float32)
        else:
            h0 = h0.reshape(bsz, g, hg, pdim, n).astype(jnp.float32)
        h_last, h_prevs = jax.lax.scan(
            step,
            h0,
            (s_end.transpose(1, 0, 2, 3, 4, 5), total_decay.transpose(1, 0, 2)),
        )
        h_prevs = h_prevs.transpose(1, 0, 2, 3, 4, 5)          # [B, nc, G, Hg, P, N]

        # --- inter-chunk contribution
        decay_in = jnp.exp(cum).reshape(bsz, nc, q, g, hg)     # decay from chunk start
        y_off = jnp.einsum(
            "bcign,bcghpn,bcigh->bcighp",
            cq.astype(jnp.float32), h_prevs, decay_in,
        )

        y = (y_diag + y_off).reshape(bsz, s, h, pdim)
        y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
        return y.astype(x.dtype), h_last.reshape(bsz, h, pdim, n)

    def _mixer(self, p: Params, u: jax.Array, state=None):
        """One mamba2 block (post-norm residual handled by caller).

        state: None (training) or dict(conv, ssm) for decode.
        Returns (out [B, S, D], new_state or None)."""
        cfg = self.cfg
        bsz, s, _ = u.shape
        z, xbc, dt = self._split_proj(p, u)
        conv_state = state["conv"] if state is not None else None
        xbc, new_conv = self._conv(p, xbc, conv_state)
        x, b_mat, c_mat = jnp.split(
            xbc, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state], axis=-1
        )
        x = x.reshape(bsz, s, cfg.n_heads, cfg.headdim)
        b_mat = b_mat.reshape(bsz, s, cfg.n_groups, cfg.d_state)
        c_mat = c_mat.reshape(bsz, s, cfg.n_groups, cfg.d_state)
        dt = jax.nn.softplus(
            dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )  # [B, S, H]

        h0 = state["ssm"] if state is not None else None
        y, h_last = self._ssd_chunked(p, x, b_mat, c_mat, dt, h0=h0)
        y = y.reshape(bsz, s, cfg.d_inner)
        y = layers.rms_norm(p["gated_norm"], y * jax.nn.silu(z))
        out = layers.dense(p["out_proj"], y)
        new_state = None
        if state is not None:
            new_state = {"conv": new_conv.astype(state["conv"].dtype),
                         "ssm": h_last.astype(state["ssm"].dtype)}
        return out, new_state

    # --------------------------------------------------------------- forward

    def _constrain(self, h):
        if self.cfg.act_batch_axes is None:
            return h
        return jax.lax.with_sharding_constraint(h, P(self.cfg.act_batch_axes, None, None))

    def hidden(self, params: Params, tokens: jax.Array,
               *, embeds_override=None, positions=None) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        del positions  # SSMs carry position in state
        h = params["embed"][tokens].astype(cfg.cdtype)
        if embeds_override is not None:
            h = embeds_override.astype(cfg.cdtype)
        h = self._constrain(h)

        def body(h, p_layer):
            out, _ = self._mixer(p_layer, layers.rms_norm(p_layer["norm"], h))
            return self._constrain(h + out), None

        if cfg.remat in ("full", "dots"):
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["layers"])
        return layers.rms_norm(params["final_norm"], h), jnp.float32(0.0)

    def unembed(self, params: Params, h: jax.Array) -> jax.Array:
        logits = h @ params["lm_head"].astype(h.dtype)
        if self.cfg.act_batch_axes is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, P(self.cfg.act_batch_axes, None, "model"))
        return logits

    def forward(self, params: Params, tokens: jax.Array,
                *, embeds_override=None, positions=None) -> tuple[jax.Array, jax.Array]:
        h, aux = self.hidden(params, tokens, embeds_override=embeds_override,
                             positions=positions)
        return self.unembed(params, h), aux

    # -------------------------------------------------------------- serving

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        del max_len  # state size is O(1) in sequence length -- the point of SSMs
        return {
            "conv": jnp.zeros(
                (cfg.n_layers, batch, cfg.d_conv - 1, cfg.conv_dim), dtype
            ),
            "ssm": jnp.zeros(
                (cfg.n_layers, batch, cfg.n_heads, cfg.headdim, cfg.d_state),
                jnp.float32,
            ),
        }

    def forward_with_cache(self, params: Params, tokens: jax.Array,
                           cache: Params, cache_index: jax.Array,
                           *, last_only: bool = False):
        """Chunked prefill / decode. tokens [B, S] with S % chunk == 0 or S==1."""
        cfg = self.cfg
        del cache_index  # state carries all history; no positions needed
        h = params["embed"][tokens].astype(cfg.cdtype)

        def body(h, xs):
            p_layer, state = xs
            out, new_state = self._mixer(
                p_layer, layers.rms_norm(p_layer["norm"], h), state
            )
            return h + out, new_state

        (h), new_cache = jax.lax.scan(
            body, h, (params["layers"], {"conv": cache["conv"], "ssm": cache["ssm"]})
        )
        h = layers.rms_norm(params["final_norm"], h)
        if last_only:
            h = h[:, -1:]
        return h @ params["lm_head"].astype(h.dtype), new_cache

    # ---------------------------------------------------------------- specs

    def param_pspecs(self, *, fsdp: str | None = "data", tp: str = "model") -> Params:
        def stack(t):
            return jax.tree.map(lambda s: P(None, *s), t,
                                is_leaf=lambda x: isinstance(x, P))

        layer = {
            "norm": {"scale": P(None)},
            "in_proj": {"w": P(fsdp, tp)},
            "conv_w": P(None, tp),
            "conv_b": P(tp),
            "A_log": P(None),
            "D": P(None),
            "dt_bias": P(None),
            "gated_norm": {"scale": P(tp)},
            "out_proj": {"w": P(tp, fsdp)},
        }
        return {
            "embed": P(tp, fsdp),
            "layers": stack(layer),
            "final_norm": {"scale": P(None)},
            "lm_head": P(fsdp, tp),
        }

    def cache_pspecs(self, *, batch_axes, seq_axis=None, head_axis=None) -> Params:
        # SSM state: shard heads over TP (80 % 16 == 0), batch over DP.
        del seq_axis, head_axis  # no sequence axis in an SSM cache
        return {
            "conv": P(None, batch_axes, None, None),
            "ssm": P(None, batch_axes, "model", None, None),
        }
