"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings [B, T_enc, D] (what the two conv layers + GELU
would produce). The backbone is faithful: pre-LN transformer encoder
(bidirectional), decoder with causal self-attention + cross-attention, GELU
MLPs, LayerNorm with bias. Sinusoidal positions are used for both stacks so
the assigned (artificially long) decoder shapes lower cleanly; noted in
DESIGN.md as a hardware-adaptation change (Whisper's learned 448-position
table does not extend to 32k).

Cross-attention K/V are computed once from the encoder output and cached --
decode then only runs causal self-attention + cached cross-attention.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers

__all__ = ["WhisperConfig", "Whisper"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500  # encoder positions (30 s of audio at 50 Hz)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"
    act_batch_axes: tuple[str, ...] | None = None
    attn_sharding: str | None = None

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        d, ff = self.d_model, self.d_ff
        attn = 4 * d * d
        mlp = 2 * d * ff + d + ff
        enc = self.n_enc_layers * (attn + mlp + 4 * d)
        dec = self.n_dec_layers * (2 * attn + mlp + 6 * d)
        return self.vocab * d + enc + dec + 4 * d

    def active_param_count(self) -> int:
        return self.param_count()


class Whisper:
    def __init__(self, cfg: WhisperConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init

    def _attn_init(self, key):
        cfg = self.cfg
        return layers.attention_init(
            key, cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.d_head,
            bias=True, dtype=cfg.pdtype,
        )

    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "attn": self._attn_init(k1),
            "ln2": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "mlp": layers.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.pdtype),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "self_attn": self._attn_init(k1),
            "ln_x": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "cross_attn": self._attn_init(k2),
            "ln2": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "mlp": layers.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.pdtype),
        }

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_e, k_enc, k_dec = jax.random.split(key, 3)
        enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
        dec_keys = jax.random.split(k_dec, cfg.n_dec_layers)
        return {
            "embed": (jax.random.normal(k_e, (cfg.vocab, cfg.d_model)) * 0.02
                      ).astype(cfg.pdtype),
            "enc_layers": jax.vmap(self._enc_layer_init)(enc_keys),
            "enc_final_ln": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
            "dec_layers": jax.vmap(self._dec_layer_init)(dec_keys),
            "dec_final_ln": layers.layer_norm_init(cfg.d_model, cfg.pdtype),
        }

    # ----------------------------------------------------------- components

    def _mha(self, p, q_x, kv_x, mask):
        """Full multi-head attention with separate query/key-value streams."""
        cfg = self.cfg
        b, sq, _ = q_x.shape
        sk = kv_x.shape[1]
        q = layers.dense(p["q"], q_x).reshape(b, sq, cfg.n_heads, cfg.d_head)
        k = layers.dense(p["k"], kv_x).reshape(b, sk, cfg.n_heads, cfg.d_head)
        v = layers.dense(p["v"], kv_x).reshape(b, sk, cfg.n_heads, cfg.d_head)
        out = layers.attention_scores(q, k, v, mask)
        return layers.dense(p["o"], out.reshape(b, sq, cfg.n_heads * cfg.d_head))

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """frames: [B, T_enc, D] precomputed frame embeddings (stub output)."""
        cfg = self.cfg
        b, t, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        h = frames.astype(cfg.cdtype) + layers.sinusoidal_positions(
            pos, cfg.d_model, cfg.cdtype
        )
        full_mask = jnp.ones((b, 1, t, t), bool)

        def body(h, p_l):
            h = h + self._mha(p_l["attn"], layers.layer_norm(p_l["ln1"], h),
                              layers.layer_norm(p_l["ln1"], h), full_mask)
            h = h + layers.gelu_mlp(p_l["mlp"], layers.layer_norm(p_l["ln2"], h))
            return h, None

        if cfg.remat in ("full", "dots"):
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return layers.layer_norm(params["enc_final_ln"], h)

    # --------------------------------------------------------------- decoder

    def _decoder(self, params, tokens, enc_out, *, cache=None, cache_index=None,
                 last_only: bool = False, return_hidden: bool = False):
        cfg = self.cfg
        b, s = tokens.shape
        base = cache_index if cache_index is not None else 0
        pos = base + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h = params["embed"][tokens].astype(cfg.cdtype)
        h = h + layers.sinusoidal_positions(pos, cfg.d_model, cfg.cdtype)
        t_enc = enc_out.shape[1]
        cross_mask = jnp.ones((b, 1, s, t_enc), bool)

        def body(h, xs):
            if cache is not None:
                p_l, cache_l = xs
            else:
                p_l, cache_l = xs, None
            # causal self-attention (cached for decode)
            kv = (cache_l["k"], cache_l["v"]) if cache_l is not None else None
            attn_pspecs = None
            if cfg.act_batch_axes is not None and cfg.attn_sharding is not None:
                spec = P(cfg.act_batch_axes, None, "model", None)
                attn_pspecs = (spec, spec)
            attn_out, new_kv = layers.gqa_attention(
                p_l["self_attn"], layers.layer_norm(p_l["ln1"], h), pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_heads, d_head=cfg.d_head,
                use_rope=False, kv_cache=kv, cache_index=cache_index,
                attn_pspecs=attn_pspecs,
            )
            h = h + attn_out
            # cross-attention to the encoder output
            h = h + self._mha(
                p_l["cross_attn"], layers.layer_norm(p_l["ln_x"], h),
                enc_out, cross_mask,
            )
            h = h + layers.gelu_mlp(p_l["mlp"], layers.layer_norm(p_l["ln2"], h))
            new_cache_l = (
                {"k": new_kv[0], "v": new_kv[1]} if cache_l is not None else None
            )
            return h, new_cache_l

        if cfg.remat in ("full", "dots") and cache is None:
            body = jax.checkpoint(body)
        xs = (params["dec_layers"], cache) if cache is not None \
            else params["dec_layers"]
        h, new_cache = jax.lax.scan(body, h, xs)
        h = layers.layer_norm(params["dec_final_ln"], h)
        if last_only:
            h = h[:, -1:]
        if return_hidden:
            return h, new_cache
        return self.unembed(params, h), new_cache

    def unembed(self, params: Params, h: jax.Array) -> jax.Array:
        logits = h @ params["embed"].T.astype(h.dtype)  # tied
        if self.cfg.act_batch_axes is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, P(self.cfg.act_batch_axes, None, "model"))
        return logits

    def hidden(self, params: Params, tokens: jax.Array, *,
               frames: jax.Array, positions=None):
        del positions
        enc_out = self.encode(params, frames)
        h, _ = self._decoder(params, tokens, enc_out, return_hidden=True)
        return h, jnp.float32(0.0)

    # ----------------------------------------------------------- public API

    def forward(self, params: Params, tokens: jax.Array, *,
                frames: jax.Array, positions=None):
        """Training forward: (frames, decoder tokens) -> logits."""
        del positions
        enc_out = self.encode(params, frames)
        logits, _ = self._decoder(params, tokens, enc_out)
        return logits, jnp.float32(0.0)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        kv = (cfg.n_dec_layers, batch, max_len, cfg.n_heads, cfg.d_head)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}

    def forward_with_cache(self, params, tokens, cache, cache_index, *,
                           enc_out: jax.Array, last_only: bool = False):
        """Prefill/decode against a precomputed encoder output."""
        return self._decoder(
            params, tokens, enc_out, cache=cache, cache_index=cache_index,
            last_only=last_only,
        )

    # ---------------------------------------------------------------- specs

    def param_pspecs(self, *, fsdp: str | None = "data", tp: str = "model") -> Params:
        def stack(t):
            return jax.tree.map(lambda s: P(None, *s), t,
                                is_leaf=lambda x: isinstance(x, P))

        ln = {"scale": P(None), "bias": P(None)}
        attn = {
            "q": {"w": P(fsdp, tp), "b": P(tp)},
            "k": {"w": P(fsdp, tp), "b": P(tp)},
            "v": {"w": P(fsdp, tp), "b": P(tp)},
            "o": {"w": P(tp, fsdp)},
        }
        mlp = {
            "up": {"w": P(fsdp, tp), "b": P(tp)},
            "down": {"w": P(tp, fsdp), "b": P(None)},
        }
        enc = {"ln1": ln, "attn": attn, "ln2": ln, "mlp": mlp}
        dec = {"ln1": ln, "self_attn": attn, "ln_x": ln,
               "cross_attn": attn, "ln2": ln, "mlp": mlp}
        return {
            "embed": P(tp, fsdp),
            "enc_layers": stack(enc),
            "enc_final_ln": ln,
            "dec_layers": stack(dec),
            "dec_final_ln": ln,
        }

    def cache_pspecs(self, *, batch_axes, seq_axis=None, head_axis=None) -> Params:
        spec = P(None, batch_axes, seq_axis, head_axis, None)
        return {"k": spec, "v": spec}
