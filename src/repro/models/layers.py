"""Shared neural-network layers (pure JAX, dict-pytree parameters).

Conventions:
* parameters are nested dicts of jnp arrays; a parallel dict of
  ``jax.sharding.PartitionSpec`` is produced by each model's ``param_pspecs``.
* layer stacks are *scanned*: per-layer params carry a leading [L] axis, so a
  62-layer model compiles one layer body (key for dry-run compile times and
  for production compile times alike).
* compute dtype and parameter dtype are independent; reductions (softmax,
  norms, CE) accumulate in f32.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rms_norm_init",
    "rms_norm",
    "nonparam_layer_norm",
    "rope",
    "attention_scores",
    "causal_window_mask",
    "attention_init",
    "gqa_attention",
    "swiglu_init",
    "swiglu",
    "cross_entropy",
]

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# basics


def dense_init(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: float | None = None,
) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p: Params = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rms_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def nonparam_layer_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def layer_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def gelu_mlp_init(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, d_model, d_ff, bias=True, dtype=dtype),
        "down": dense_init(k2, d_ff, d_model, bias=True, dtype=dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


def sinusoidal_positions(
    positions: jax.Array, d_model: int, dtype=jnp.float32
) -> jax.Array:
    """[B, S] positions -> [B, S, D] sinusoidal embeddings (Whisper-style)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# attention


def rope(
    x: jax.Array,            # [B, S, H, Dh]
    positions: jax.Array,    # [B, S] int32
    theta: jax.Array | float = 10_000.0,
) -> jax.Array:
    """Rotary position embedding; ``theta`` may be traced (per-layer bases)."""
    dh = x.shape[-1]
    half = dh // 2
    log_theta = jnp.log(jnp.asarray(theta, jnp.float32))
    freqs = jnp.exp(-log_theta * (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def attention_scores(
    q: jax.Array,             # [B, S_q, H, Dh]
    k: jax.Array,             # [B, S_k, Hkv, Dh]
    v: jax.Array,             # [B, S_k, Hkv, Dh]
    mask: jax.Array,          # [B, 1, S_q, S_k] bool (True = attend)
) -> jax.Array:
    """Grouped-query scaled-dot-product attention core. f32 softmax."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(dh)
    logits = jnp.where(mask[:, :, None, :, :], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, dh)


def causal_window_mask(
    q_pos: jax.Array,   # [B, S_q]
    k_pos: jax.Array,   # [B, S_k]
    k_valid: jax.Array | None,  # [B, S_k] bool or None
    window: jax.Array | int,    # <=0: full causal; >0: sliding window size
) -> jax.Array:
    """[B, 1, S_q, S_k] mask: causal, optionally windowed, optionally masking
    invalid (unwritten cache) keys. ``window`` may be a traced scalar, which is
    how per-layer 5:1 local/global patterns (gemma3) run under a layer scan."""
    d = q_pos[:, :, None] - k_pos[:, None, :]          # [B, S_q, S_k]
    m = d >= 0
    w = jnp.asarray(window, jnp.int32)
    m = m & ((w <= 0) | (d < w))
    if k_valid is not None:
        m = m & k_valid[:, None, :]
    return m[:, None]


# Above this many query positions, attention switches to the streaming
# (flash-style) path: O(S) memory instead of materialising [B, H, S_q, S_k].
FLASH_THRESHOLD = 2048
_Q_CHUNK = 512
_K_CHUNK = 1024


def _streaming_attention(
    q: jax.Array,        # [B, S_q, H, Dh]
    k: jax.Array,        # [B, S_k, Hkv, Dh]
    v: jax.Array,        # [B, S_k, Hkv, Dh]
    q_pos: jax.Array,    # [B, S_q]
    k_pos: jax.Array,    # [B, S_k]
    k_len: jax.Array,    # scalar: number of valid keys
    window: jax.Array | int,
) -> jax.Array:
    """Online-softmax attention: one scan over *key* blocks with all query
    rows resident -- the pure-JAX equivalent of flash attention. Peak memory
    is the [B, Hkv, G, S_q, Kc] tile (never [S, S]), so 32k/500k prefill
    lowers with O(S) activation memory.

    SPMD note: the query dimension stays whole, so a sequence-sharding
    constraint on ``q`` (context parallelism) partitions every tensor in the
    loop along S_q and the scan carries no cross-device traffic. A q-block
    outer loop would instead serialise the sharded dimension (lax.scan
    iterations cannot be spread across devices)."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    kc = min(_K_CHUNK, sk)
    nk = sk // kc
    assert sk % kc == 0, (sk, kc)
    scale = 1.0 / math.sqrt(dh)
    w = jnp.asarray(window, jnp.int32)

    qf = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    kb = k.reshape(b, nk, kc, hkv, dh).astype(jnp.float32)
    vb = v.reshape(b, nk, kc, hkv, dh).astype(jnp.float32)
    kp = k_pos.reshape(b, nk, kc)

    def k_block(carry, ys):
        m, denom, acc = carry
        k_j, v_j, kp_j = ys  # [B, kc, Hkv, Dh], ..., [B, kc]
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_j) * scale
        d = q_pos[:, None, None, :, None] - kp_j[:, None, None, None, :]
        mask = (d >= 0) & ((w <= 0) | (d < w))
        mask = mask & (kp_j[:, None, None, None, :] < k_len)
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p_ = jnp.exp(logits - m_new[..., None])
        denom = denom * corr + p_.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p_, v_j)
        return (m_new, denom, acc), None

    init = (
        jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32),
        jnp.zeros((b, hkv, g, sq), jnp.float32),
        jnp.zeros((b, hkv, g, sq, dh), jnp.float32),
    )
    (m, denom, acc), _ = jax.lax.scan(
        k_block, init,
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         kp.transpose(1, 0, 2)),
    )
    out = acc / jnp.maximum(denom[..., None], 1e-30)   # [B, Hkv, G, Sq, Dh]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


def gqa_attention(
    p: Params,
    x: jax.Array,             # [B, S, D]
    positions: jax.Array,     # [B, S]
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: jax.Array | float = 10_000.0,
    window: jax.Array | int = 0,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,  # [B, S_max, Hkv, Dh]
    cache_index: jax.Array | None = None,   # scalar: #valid cache entries
    use_rope: bool = True,
    attn_pspecs: tuple | None = None,       # (q_spec, kv_spec) PartitionSpecs
    cache_mode: str = "inplace",  # 'inplace' | 'append_slice' | 'fresh_only'
    use_pallas: bool = False,     # fused flash kernel (full-seq path only)
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """GQA attention with optional sliding window and KV cache.

    Without a cache: causal (optionally windowed) self-attention. With a
    cache: attends over cache + this call's K/V. Long query blocks
    automatically take the streaming path (see ``_streaming_attention``).

    Cache modes: ``inplace`` writes the fresh K/V into the cache inside this
    call (simple, but inside a layer scan the whole cache double-buffers
    through ys); ``append_slice`` (decode) attends over concat(cache, fresh)
    and returns only the fresh slices -- the caller merges them into the
    cache with ONE top-level dynamic-update (aliasable by donation);
    ``fresh_only`` (prefill from an empty cache) ignores stale cache contents
    entirely and also returns slices.

    ``attn_pspecs`` pins the attention-activation layout: head-parallel when
    KV heads divide the TP axis, otherwise *context parallel* (queries
    sequence-sharded, K/V replicated) -- without the pin, XLA resolves
    indivisible head counts by re-reducing every streaming block (tens of
    thousands of all-reduces per step for kv=2 archs like qwen2).
    Returns (output [B, S, D], updated cache or fresh slices or None).
    """
    b, s, _ = x.shape
    q = dense(p["q"], x).reshape(b, s, n_heads, d_head)
    k = dense(p["k"], x).reshape(b, s, n_kv, d_head)
    v = dense(p["v"], x).reshape(b, s, n_kv, d_head)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    if attn_pspecs is not None and s >= FLASH_THRESHOLD:
        q_spec, kv_spec = attn_pspecs
        q = jax.lax.with_sharding_constraint(q, q_spec)
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)

    if kv_cache is None or cache_mode == "fresh_only":
        new_cache = None if kv_cache is None else (k, v)
        k_full, v_full = k, v
        k_pos = positions
        k_len = (jnp.int32(s) + 0 * positions[0, 0] if cache_index is None
                 else cache_index + s)
    elif cache_mode == "append_slice":
        ck, cv = kv_cache
        s_max = ck.shape[1]
        k_full = jnp.concatenate([ck.astype(q.dtype), k], axis=1)
        v_full = jnp.concatenate([cv.astype(q.dtype), v], axis=1)
        k_pos = jnp.concatenate([
            jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32), (b, s_max)),
            positions,
        ], axis=1)
        # valid: cache entries below cache_index + the fresh positions;
        # implemented by clamping invalid cache slots past every query.
        k_valid_len = cache_index  # cache part
        k_pos = jnp.where(
            (jnp.arange(s_max + s) < s_max)[None, :]
            & (k_pos >= k_valid_len), jnp.int32(2**30), k_pos)
        k_len = jnp.int32(2**30)  # validity folded into k_pos above
        new_cache = (k, v)
    else:  # 'inplace'
        ck, cv = kv_cache
        s_max = ck.shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.astype(ck.dtype), cache_index, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.astype(cv.dtype), cache_index, 1)
        new_cache = (ck, cv)
        k_full, v_full = ck.astype(q.dtype), cv.astype(q.dtype)
        k_pos = jnp.broadcast_to(
            jnp.arange(s_max, dtype=jnp.int32), (b, s_max))
        k_len = cache_index + s

    if use_pallas and kv_cache is None and s >= FLASH_THRESHOLD:
        # Fused kernel path: positions are canonical arange in the
        # full-sequence forward, which is what the kernel's block-index
        # positions assume.
        from repro.kernels.flash_attention import flash_attention_pallas

        out = flash_attention_pallas(
            q, k_full, v_full, jnp.asarray(window, jnp.int32), k_len)
    elif s >= FLASH_THRESHOLD:
        out = _streaming_attention(
            q, k_full, v_full, positions, k_pos, k_len, window)
        if attn_pspecs is not None:
            out = jax.lax.with_sharding_constraint(out, attn_pspecs[0])
    else:
        k_valid = jnp.broadcast_to(k_pos[0] < k_len, k_pos.shape)
        mask = causal_window_mask(positions, k_pos, k_valid, window)
        out = attention_scores(q, k_full, v_full, mask)

    out = out.reshape(b, s, n_heads * d_head)
    return dense(p["o"], out), new_cache


def attention_init(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv: int,
    d_head: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "q": dense_init(k1, d_model, n_heads * d_head, bias=bias, dtype=dtype),
        "k": dense_init(k2, d_model, n_kv * d_head, bias=bias, dtype=dtype),
        "v": dense_init(k3, d_model, n_kv * d_head, bias=bias, dtype=dtype),
        "o": dense_init(k4, n_heads * d_head, d_model, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# feed-forward


def swiglu_init(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# ---------------------------------------------------------------------------
# loss


def cross_entropy(
    logits: jax.Array,   # [B, S, V]
    labels: jax.Array,   # [B, S] int32
    mask: jax.Array | None = None,  # [B, S] bool
) -> jax.Array:
    """Mean next-token cross entropy, f32 accumulation."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def chunked_cross_entropy(
    unembed_fn,
    h: jax.Array,        # [B, S, D] final hidden states
    labels: jax.Array,   # [B, S]
    chunk: int = 1024,
) -> jax.Array:
    """CE without ever materialising the full [B, S, V] logits.

    The unembedding + log-softmax runs per sequence chunk inside a scan, so
    peak memory is [B, chunk, V] -- the difference between 300 GB and 1 GB of
    logits for a 152k-vocab model at 4k x 256. This is the production-
    standard formulation (the unembed weight gradient accumulates across
    chunks automatically through the scan's autodiff)."""
    b, s, _ = h.shape
    if s % chunk != 0:
        chunk = s  # smoke-scale inputs: single chunk
    nc = s // chunk
    hc = h.reshape(b, nc, chunk, h.shape[-1]).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(total, xs):
        h_i, y_i = xs
        logits = unembed_fn(h_i).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_i[..., None], axis=-1)[..., 0]
        return total + (logz - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, yc))
    return total / (b * s)
