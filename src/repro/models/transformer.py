"""Decoder-only transformer family (dense / GQA / SWA / MoE), scan-stacked.

One configurable implementation covers seven of the ten assigned
architectures: h2o-danube (GQA+SWA), gemma3 (5:1 local:global pattern,
dual rope bases), olmo (non-parametric LN), qwen2 (QKV bias, tied embeddings),
llama4-maverick (interleaved MoE, 128e top-1 + shared expert), grok-1
(MoE 8e top-2), and the internvl2 language backbone.

Design points:
* **Scan over layer groups.** Per-layer params carry a leading [n_groups]
  axis; a 62-layer model compiles one group body. Heterogeneous layer
  patterns are data, not code: per-layer window sizes and rope bases are
  scanned arrays (gemma3's 5:1 pattern), and MoE/dense interleaving is a
  static sub-layer list inside the group (llama4's alternation).
* **KV cache as scan ys/xs** so prefill/decode reuse the same body.
* f32 softmax/norm/CE islands inside a bf16 compute stream.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.moe import MoEConfig, moe_apply, moe_init, moe_pspecs

__all__ = ["TransformerConfig", "Transformer"]

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    # rope base used by *global* (window == 0) layers when a local:global
    # pattern is present (gemma3: 10k local / 1M global).
    rope_theta_global: float | None = None
    # cycled over layers; 0 = full causal attention, > 0 = sliding window
    window_pattern: tuple[int, ...] = (0,)
    qkv_bias: bool = False
    norm: str = "rms"  # 'rms' | 'nonparam' (olmo)
    moe: MoEConfig | None = None
    tie_embeddings: bool = False
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"  # 'none' | 'full' | 'dots'
    # Optional activation sharding constraint axes (DP axes for batch dim).
    act_batch_axes: tuple[str, ...] | None = None
    # Attention activation layout: 'heads' (KV heads divide the TP axis) or
    # 'seq' (context parallel: queries sequence-sharded, K/V replicated).
    attn_sharding: str | None = None
    # Use the fused Pallas flash-attention kernel for full-sequence forward
    # passes (kernels/flash_attention.py). Off by default: on multi-device
    # meshes wrap the model in shard_map before enabling (Pallas calls are
    # per-device programs); on a single device or inside shard_map it is a
    # 1:1 drop-in for the jnp streaming path.
    use_pallas_attention: bool = False

    def __post_init__(self) -> None:
        if self.n_layers % self.group_size != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by the "
                f"MoE interleave group {self.group_size}"
            )
        if self.n_heads % self.n_kv != 0:
            raise ValueError(f"{self.name}: n_heads must divide by n_kv")

    @property
    def group_size(self) -> int:
        return self.moe.interleave if self.moe is not None else 1

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    def sub_is_moe(self, i: int) -> bool:
        """Within a group, the *last* sub-layer is the MoE one."""
        return self.moe is not None and i == self.group_size - 1

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    # -- per-layer pattern arrays (shaped [n_groups, group_size]) ------------

    def window_array(self) -> jnp.ndarray:
        pat = self.window_pattern
        w = [pat[i % len(pat)] for i in range(self.n_layers)]
        return jnp.asarray(w, jnp.int32).reshape(self.n_groups, self.group_size)

    def theta_array(self) -> jnp.ndarray:
        pat = self.window_pattern
        tg = self.rope_theta_global or self.rope_theta
        th = [
            tg if pat[i % len(pat)] == 0 and self.rope_theta_global else self.rope_theta
            for i in range(self.n_layers)
        ]
        return jnp.asarray(th, jnp.float32).reshape(self.n_groups, self.group_size)

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline accounting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.d_head * 2 + d * self.n_kv * self.d_head * 2
        per_dense = attn + 3 * d * ff + 2 * d
        n = v * d + d  # embed + final norm
        if not self.tie_embeddings:
            n += d * v
        if self.moe is None:
            return n + self.n_layers * per_dense
        g = self.group_size
        moe_ffn = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        if self.moe.shared_expert:
            moe_ffn += 3 * d * self.moe.d_ff
        per_group = (g - 1) * per_dense + (attn + moe_ffn + 2 * d)
        return n + self.n_groups * per_group

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        attn = d * self.n_heads * self.d_head * 2 + d * self.n_kv * self.d_head * 2
        per_dense = attn + 3 * d * self.d_ff + 2 * d
        active_ffn = self.moe.top_k * 3 * d * self.moe.d_ff
        if self.moe.shared_expert:
            active_ffn += 3 * d * self.moe.d_ff
        per_moe = attn + active_ffn + 2 * d
        g = self.group_size
        n = self.vocab * d + d + (0 if self.tie_embeddings else d * self.vocab)
        return n + self.n_groups * ((g - 1) * per_dense + per_moe)


class Transformer:
    """Functional model: all methods are static given a config."""

    def __init__(self, cfg: TransformerConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        pd = cfg.pdtype
        k_embed, k_layers, k_head = jax.random.split(key, 3)

        def init_group(k: jax.Array) -> Params:
            g: Params = {}
            for i in range(cfg.group_size):
                k, k_attn, k_ffn = jax.random.split(k, 3)
                sub: Params = {
                    "attn": layers.attention_init(
                        k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head,
                        bias=cfg.qkv_bias, dtype=pd,
                    ),
                }
                if cfg.norm == "rms":
                    sub["ln1"] = layers.rms_norm_init(cfg.d_model, pd)
                    sub["ln2"] = layers.rms_norm_init(cfg.d_model, pd)
                if cfg.sub_is_moe(i):
                    sub["moe"] = moe_init(k_ffn, cfg.d_model, cfg.moe, pd)
                else:
                    sub["ffn"] = layers.swiglu_init(k_ffn, cfg.d_model, cfg.d_ff, pd)
                g[f"sub_{i}"] = sub
            return g

        group_keys = jax.random.split(k_layers, cfg.n_groups)
        params: Params = {
            "embed": (
                jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02
            ).astype(pd),
            "layers": jax.vmap(init_group)(group_keys),
        }
        if cfg.norm == "rms":
            params["final_norm"] = layers.rms_norm_init(cfg.d_model, pd)
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                / math.sqrt(cfg.d_model)
            ).astype(pd)
        return params

    # ----------------------------------------------------------------- norms

    def _norm(self, sub: Params, which: str, x: jax.Array) -> jax.Array:
        if self.cfg.norm == "rms":
            return layers.rms_norm(sub[which], x)
        return layers.nonparam_layer_norm(x)

    def _constrain(self, h: jax.Array) -> jax.Array:
        axes = self.cfg.act_batch_axes
        if axes is None:
            return h
        return jax.lax.with_sharding_constraint(h, P(axes, None, None))

    def _attn_pspecs(self):
        cfg = self.cfg
        if cfg.act_batch_axes is None or cfg.attn_sharding is None:
            return None
        b = cfg.act_batch_axes
        if cfg.attn_sharding == "heads":
            spec = P(b, None, "model", None)
            return (spec, spec)
        return (P(b, "model", None, None), P(b, None, None, None))

    # ------------------------------------------------------------ group body

    def _group_body(self, with_cache: bool, cache_mode: str = "inplace"):
        cfg = self.cfg

        def body(carry, xs):
            if with_cache:
                h, aux, positions, cache_index = carry
                params_g, win_g, th_g, cache_g = xs
            else:
                h, aux, positions = carry
                params_g, win_g, th_g = xs
                cache_g = None
            new_cache_g = {}
            for i in range(cfg.group_size):
                sub = params_g[f"sub_{i}"]
                kv = None
                idx = None
                if with_cache:
                    kv = (cache_g[f"sub_{i}"]["k"], cache_g[f"sub_{i}"]["v"])
                    idx = cache_index
                attn_out, new_kv = layers.gqa_attention(
                    sub["attn"], self._norm(sub, "ln1", h), positions,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.d_head,
                    rope_theta=th_g[i], window=win_g[i],
                    kv_cache=kv, cache_index=idx, cache_mode=cache_mode,
                    attn_pspecs=self._attn_pspecs(),
                    use_pallas=cfg.use_pallas_attention,
                )
                h = self._constrain(h + attn_out)
                hn = self._norm(sub, "ln2", h)
                if cfg.sub_is_moe(i):
                    y, a = moe_apply(sub["moe"], hn, cfg.moe,
                                     act_axes=cfg.act_batch_axes)
                    aux = aux + a
                else:
                    y = layers.swiglu(sub["ffn"], hn)
                h = self._constrain(h + y)
                if with_cache:
                    new_cache_g[f"sub_{i}"] = {"k": new_kv[0], "v": new_kv[1]}
            if with_cache:
                return (h, aux, positions, cache_index), new_cache_g
            return (h, aux, positions), None

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
        return body

    # --------------------------------------------------------------- forward

    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = params["embed"][tokens].astype(cfg.cdtype)
        if cfg.embed_scale:
            h = h * math.sqrt(cfg.d_model)
        return h

    def _unembed(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = h @ params["embed"].T.astype(h.dtype)
        else:
            logits = h @ params["lm_head"].astype(h.dtype)
        if cfg.act_batch_axes is not None:
            # Keep logits vocab-sharded over TP ('model'): CE reduces over the
            # sharded vocab axis with a psum instead of all-gathering logits.
            logits = jax.lax.with_sharding_constraint(
                logits, P(cfg.act_batch_axes, None, "model")
            )
        return logits

    def hidden(
        self,
        params: Params,
        tokens: jax.Array,                   # [B, S] int32
        *,
        embeds_override: jax.Array | None = None,  # [B, S, D] (VLM/audio stubs)
        positions: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward up to the final norm (no unembedding).

        Returns (h [B, S, D], moe aux loss). Losses use this with
        ``layers.chunked_cross_entropy`` so [B, S, V] logits never exist."""
        cfg = self.cfg
        b, s = tokens.shape
        h = self._embed(params, tokens)
        if embeds_override is not None:
            h = embeds_override.astype(cfg.cdtype)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h = self._constrain(h)

        body = self._group_body(with_cache=False)
        (h, aux, _), _ = jax.lax.scan(
            body,
            (h, jnp.float32(0.0), positions),
            (params["layers"], self.cfg.window_array(), self.cfg.theta_array()),
        )
        if cfg.norm == "rms":
            h = layers.rms_norm(params["final_norm"], h)
        else:
            h = layers.nonparam_layer_norm(h)
        return h, aux

    def unembed(self, params: Params, h: jax.Array) -> jax.Array:
        return self._unembed(params, h)

    def forward(
        self,
        params: Params,
        tokens: jax.Array,
        *,
        embeds_override: jax.Array | None = None,
        positions: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward. Returns (logits [B, S, V], moe aux loss)."""
        h, aux = self.hidden(
            params, tokens, embeds_override=embeds_override, positions=positions
        )
        return self._unembed(params, h), aux

    # ------------------------------------------------------------- serving

    def init_cache(
        self, batch: int, max_len: int, dtype=jnp.bfloat16
    ) -> Params:
        cfg = self.cfg
        kv_shape = (cfg.n_groups, batch, max_len, cfg.n_kv, cfg.d_head)
        cache: Params = {}
        for i in range(cfg.group_size):
            cache[f"sub_{i}"] = {
                "k": jnp.zeros(kv_shape, dtype),
                "v": jnp.zeros(kv_shape, dtype),
            }
        return cache

    def forward_with_cache(
        self,
        params: Params,
        tokens: jax.Array,        # [B, S] (S=1 for decode, chunk for prefill)
        cache: Params,
        cache_index: jax.Array,   # scalar int32: number of valid cache slots
        *,
        last_only: bool = False,  # prefill: unembed only the last position
        embeds_override: jax.Array | None = None,  # VLM/audio stub inputs
    ) -> tuple[jax.Array, Params]:
        cfg = self.cfg
        b, s = tokens.shape
        h = self._embed(params, tokens)
        if embeds_override is not None:
            h = embeds_override.astype(cfg.cdtype)
        positions = cache_index + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s)
        )
        # Decode appends slices (concat attention); prefill-from-empty
        # attends fresh-only. Either way the scan emits [G, B, s, Hkv, Dh]
        # K/V *slices* and the cache is merged with one top-level dynamic
        # update -- avoiding the full-cache double-buffer a scan-ys cache
        # costs (measured: -70% decode memory term on 62-layer gemma3).
        # Exception: context-parallel archs shard the cache's *sequence*
        # axis, and concat along a sharded axis reshards every layer --
        # those keep the in-place path (measured: concat tripled grok's
        # decode collective term).
        if s > 1:
            cache_mode = "fresh_only"
        elif cfg.attn_sharding == "seq":
            cache_mode = "inplace"
        else:
            cache_mode = "append_slice"
        body = self._group_body(with_cache=True, cache_mode=cache_mode)
        (h, _, _, _), slices = jax.lax.scan(
            body,
            (h, jnp.float32(0.0), positions, cache_index),
            (params["layers"], cfg.window_array(), cfg.theta_array(), cache),
        )
        if cache_mode == "inplace":
            new_cache = slices  # body already wrote into the cache copies
        else:
            new_cache = {}
            for key_, sub in slices.items():
                new_cache[key_] = {
                    name: jax.lax.dynamic_update_slice(
                        cache[key_][name],
                        val.astype(cache[key_][name].dtype),
                        (0, 0, cache_index, 0, 0),
                    )
                    for name, val in sub.items()
                }
        if cfg.norm == "rms":
            h = layers.rms_norm(params["final_norm"], h)
        else:
            h = layers.nonparam_layer_norm(h)
        if last_only:
            h = h[:, -1:]
        return self._unembed(params, h), new_cache

    # -------------------------------------------------------------- specs

    def param_pspecs(
        self, *, fsdp: str | None = "data", tp: str = "model"
    ) -> Params:
        """PartitionSpec tree mirroring init_params (leading group axis)."""
        cfg = self.cfg

        def stack(spec_tree):
            return jax.tree.map(
                lambda s: P(None, *s), spec_tree,
                is_leaf=lambda x: isinstance(x, P),
            )

        attn = {
            "q": {"w": P(fsdp, tp)},
            "k": {"w": P(fsdp, tp)},
            "v": {"w": P(fsdp, tp)},
            "o": {"w": P(tp, fsdp)},
        }
        if cfg.qkv_bias:
            for name in ("q", "k", "v"):
                attn[name]["b"] = P(tp)
        sub_dense = {
            "attn": attn,
            "ffn": {
                "gate": {"w": P(fsdp, tp)},
                "up": {"w": P(fsdp, tp)},
                "down": {"w": P(tp, fsdp)},
            },
        }
        sub_moe = {
            "attn": attn,
            "moe": moe_pspecs(cfg.moe, fsdp, tp) if cfg.moe else {},
        }
        if cfg.norm == "rms":
            for t in (sub_dense, sub_moe):
                t["ln1"] = {"scale": P(None)}
                t["ln2"] = {"scale": P(None)}

        group = {
            f"sub_{i}": (sub_moe if cfg.sub_is_moe(i) else sub_dense)
            for i in range(cfg.group_size)
        }
        specs: Params = {
            "embed": P(tp, fsdp),
            "layers": stack(group),
        }
        if cfg.norm == "rms":
            specs["final_norm"] = {"scale": P(None)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(fsdp, tp)
        return specs

    def cache_pspecs(
        self,
        *,
        batch_axes: tuple[str, ...] | None,
        seq_axis: str | None = None,
        head_axis: str | None = None,
    ) -> Params:
        """Cache specs: [G, B, S, Hkv, Dh].

        Decode policy (see train/steps.py): batch over DP axes plus either KV
        heads over TP (when n_kv divides the TP extent) or the sequence over
        TP (few-KV-head archs, and the batch=1 long-context cell)."""
        spec = P(None, batch_axes, seq_axis, head_axis, None)
        return {
            f"sub_{i}": {"k": spec, "v": spec}
            for i in range(self.cfg.group_size)
        }
