"""Pallas TPU kernel: tiled gather-matvec spike delivery.

The paper's *deliver* phase dominates state propagation (§3, Discussion) and
its irregular memory access is the subject of the §2.3 cache model. NEST walks
per-synapse pointer chains; the TPU-native rethink is dense and delay-resolved:

* connectivity is rectangular ``src/w/delay [N, K]`` (fixed in-degree),
* a grid over target tiles keeps each ``[TILE_N, K]`` synapse block in VMEM
  together with the *entire* source spike vector (1 f32/neuron -- even a full
  131k-neuron area is 512 KiB),
* for each delay slot ``j`` in the compile-time window ``[steps_lo,
  steps_lo + r_span)`` the kernel reduces ``w * spk[src] * [delay == j]`` over
  K in one VPU pass, emitting ``contrib[TILE_N, r_span]``.

The engine then rolls ``contrib`` into the ring buffer at
``slot = (t + steps_lo + j) % R``. The separation of *intra* and *inter*
tables (paper §4.1.2) shows up here as two kernel invocations with different
``(src, w, delay)`` sets and different spike sources (the subgroup-gathered
area vector vs. the globally gathered [D, N] block), each with its own narrow
delay window -- which is what keeps ``r_span`` (and the wasted compare work)
small per pathway.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spike_deliver_pallas", "delay_resolved_contrib", "TILE_N"]

TILE_N = 128  # target-neuron rows per grid step; [TILE_N, K] stays in VMEM


def delay_resolved_contrib(vals, j, r_span: int):
    """Reduce synapse values over K once per slot of the delay window.

    ``vals [N, K]`` are the per-synapse contributions (w * spike), ``j [N, K]``
    the slot offsets in ``[0, r_span)``. One reduction over K per slot;
    ``r_span`` is a small compile-time constant (per-pathway delay width), so
    this unrolls into r_span masked row-sums -- no MXU, pure VPU. Shared by
    this kernel and the fused superstep kernel (:mod:`repro.kernels.cycle`).
    """
    cols = []
    for r in range(r_span):
        cols.append(jnp.sum(jnp.where(j == r, vals, 0.0), axis=1))
    return jnp.stack(cols, axis=1)


def _kernel(spk_ref, src_ref, w_ref, d_ref, out_ref, *, steps_lo: int, r_span: int):
    spk = spk_ref[...]            # [N_src] f32, whole source vector in VMEM
    idx = src_ref[...]            # [TILE_N, K]
    vals = w_ref[...] * spk[idx]  # gather + scale, one VPU pass
    j = d_ref[...] - steps_lo     # slot offsets in [0, r_span)
    out_ref[...] = delay_resolved_contrib(vals, j, r_span)


@functools.partial(
    jax.jit, static_argnames=("steps_lo", "r_span", "tile_n", "interpret")
)
def spike_deliver_pallas(
    spikes: jax.Array,  # [N_src] f32
    src: jax.Array,     # [N, K] int32
    w: jax.Array,       # [N, K] f32
    delay: jax.Array,   # [N, K] int32
    *,
    steps_lo: int,
    r_span: int,
    tile_n: int = TILE_N,
    interpret: bool = True,
) -> jax.Array:
    """Delay-resolved delivery contributions ``[N, r_span]``.

    N must be a multiple of ``tile_n`` (use ops.spike_deliver for padding).
    Semantics match :func:`repro.kernels.ref.spike_deliver_ref`.
    """
    n, k = src.shape
    if n % tile_n != 0:
        raise ValueError(f"N={n} must be a multiple of tile_n={tile_n}")
    n_src = spikes.shape[0]
    grid = (n // tile_n,)
    kernel = functools.partial(_kernel, steps_lo=steps_lo, r_span=r_span)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src,), lambda i: (0,)),       # full spike vector
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),  # synapse tiles
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, r_span), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r_span), w.dtype),
        interpret=interpret,
    )(spikes, src, w, delay)
