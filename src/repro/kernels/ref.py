"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every kernel test sweeps shapes/dtypes
and asserts allclose (bit-exact for f32 grid weights) against these functions.
They are deliberately written as straight-line jnp with no tiling so they stay
obviously correct.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lif_update_ref", "spike_deliver_ref"]


def lif_update_ref(
    v: jax.Array,
    i_syn: jax.Array,
    refrac: jax.Array,
    i_in: jax.Array,
    alive: jax.Array,
    *,
    p11: float,
    p21: float,
    p22: float,
    v_th: float,
    v_reset: float,
    t_ref_steps: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One exact-propagator iaf_psc_exp step (oracle for kernels.lif_update).

    Mirrors :func:`repro.core.neuron.lif_update` but takes raw propagator
    scalars so the kernel and the oracle share no code.
    """
    refractory = refrac > 0
    i_new = i_syn * p11 + i_in
    v_prop = v * p22 + i_syn * p21
    v_new = jnp.where(refractory, v_reset, v_prop)
    spikes = (v_new >= v_th) & alive & ~refractory
    v_out = jnp.where(spikes, v_reset, v_new)
    refrac_out = jnp.where(
        spikes, jnp.int32(t_ref_steps), jnp.maximum(refrac - 1, 0)
    )
    return v_out, i_new, refrac_out, spikes


def spike_deliver_ref(
    spikes: jax.Array,   # [N_src] f32 (0/1 spike indicator)
    src: jax.Array,      # [N, K] int32 indices into spikes
    w: jax.Array,        # [N, K] f32 synaptic weights
    delay: jax.Array,    # [N, K] int32 delays (steps)
    *,
    steps_lo: int,
    r_span: int,
) -> jax.Array:
    """Delay-resolved delivery contributions (oracle for kernels.spike_deliver).

    Returns ``contrib[N, r_span]`` with
    ``contrib[n, j] = sum_k w[n,k] * spikes[src[n,k]] * [delay[n,k] == steps_lo + j]``.

    The engine adds ``contrib[:, j]`` into ring slot ``(t + steps_lo + j) % R``.
    """
    vals = w * spikes[src]  # [N, K]
    j = delay - steps_lo    # [N, K], target slot offset
    onehot = jax.nn.one_hot(j, r_span, dtype=vals.dtype)  # [N, K, r_span]
    return jnp.einsum("nk,nkr->nr", vals, onehot)
