"""Pallas TPU kernel: fused flash attention (GQA, causal, sliding-window).

The §Roofline baseline shows the LM cells are memory-term dominated because
the pure-jnp streaming attention materialises its [B, H, Sq, Kc] logits tiles
in HBM between the dot and the softmax ops (XLA does not fuse through dots).
This kernel is the fix: the grid walks (batch, kv-head, q-block) x k-blocks
sequentially, and the logits tile, the running max/denominator and the output
accumulator all live in VMEM scratch -- HBM traffic is exactly q + k + v + o.

Per q-block of size Bq and k-block Bk, VMEM holds:
  q [G, Bq, Dh] + k/v [Bk, Dh] + logits [G, Bq, Bk] + acc [G, Bq, Dh]
With G = H/Hkv <= 8, Bq = Bk = 512, Dh = 128: ~5 MB -- comfortably < 16 MB.

Semantics match ``repro.models.layers._streaming_attention`` (the jnp
oracle): causal masking, optional sliding window (0 = full), k-length bound.
Validated bit-tight in interpret mode (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "BQ", "BK"]

BQ = 512   # query rows per grid step
BK = 512   # key rows per inner step

_NEG = -1e30


def _kernel(w_ref, klen_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, nk: int,
            bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], _NEG)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0].astype(jnp.float32)         # [G, Bq, Dh]
    k = k_ref[0, 0].astype(jnp.float32)         # [Bk, Dh]
    v = v_ref[0, 0].astype(jnp.float32)         # [Bk, Dh]
    logits = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ()))) * scale  # [G, Bq, Bk]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (1, bq, 1), 1)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
    d = q_pos - k_pos
    w = w_ref[0]
    mask = (d >= 0) & ((w <= 0) | (d < w)) & (k_pos < klen_ref[0])
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_scr[...]                          # [G, Bq]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[..., None])       # [G, Bq, Bk]
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())))  # [G, Bq, Dh]
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_pallas(
    q: jax.Array,        # [B, Sq, H, Dh]
    k: jax.Array,        # [B, Sk, Hkv, Dh]
    v: jax.Array,        # [B, Sk, Hkv, Dh]
    window: jax.Array,   # scalar int32 (0 = full causal)
    k_len: jax.Array,    # scalar int32: number of valid keys
    *,
    bq: int = BQ,
    bk: int = BK,
    interpret: bool = True,
) -> jax.Array:
    """Fused GQA flash attention. Sq % bq == 0, Sk % bk == 0 required
    (production shapes are powers of two; the ops wrapper pads otherwise)."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(dh)

    # layout: [B, Hkv, G, Sq, Dh] so one grid cell owns one (b, kv-head).
    qg = q.reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)   # [B, Hkv, Sk, Dh]
    vg = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, nk=nk, bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, qi, ki: (0,)),  # window
            pl.BlockSpec((1,), lambda b_, h_, qi, ki: (0,)),  # k_len
            pl.BlockSpec((1, 1, g, bq, dh),
                         lambda b_, h_, qi, ki: (b_, h_, 0, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, bq, dh),
                               lambda b_, h_, qi, ki: (b_, h_, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(window, jnp.int32).reshape(1),
        jnp.asarray(k_len, jnp.int32).reshape(1),
        qg, kg, vg,
    )
    # [B, Hkv, G, Sq, Dh] -> [B, Sq, H, Dh]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
