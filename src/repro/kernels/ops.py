"""jit'd public wrappers around the Pallas kernels (+ the event-driven path).

These functions handle padding to kernel tile sizes, select interpret mode
automatically (interpret=True unless running on real TPU), and provide the
*event-driven* delivery variant -- the beyond-paper optimization that exploits
spatiotemporal sparsity (at 2.5 spikes/s and 0.1 ms steps only ~0.025 % of
neurons fire per cycle, so dense delivery does ~4000x more multiply work than
the events require). See EXPERIMENTS.md §Perf for the measured effect.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import lif_update as _lif
from repro.kernels import spike_deliver as _dlv

__all__ = [
    "default_interpret",
    "lif_update",
    "spike_deliver",
    "apply_contrib",
    "event_deliver",
]


def default_interpret() -> bool:
    """interpret=True everywhere except on real TPU devices."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int = 0, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("p11", "p21", "p22", "v_th", "v_reset", "t_ref_steps", "tile"),
)
def lif_update(
    v, i_syn, refrac, i_in, alive,
    *, p11, p21, p22, v_th, v_reset, t_ref_steps, tile: int | None = None,
):
    """Fused LIF step over arbitrary-shape state (flattens + pads)."""
    shape = v.shape
    tile = tile or min(_lif.TILE, max(128, v.size))
    flat = lambda x: _pad_to(x.reshape(-1), tile)
    v_o, i_o, r_o, s_o = _lif.lif_update_pallas(
        flat(v), flat(i_syn), flat(refrac), flat(i_in),
        flat(alive.astype(jnp.int8)),
        p11=p11, p21=p21, p22=p22, v_th=v_th, v_reset=v_reset,
        t_ref_steps=t_ref_steps, tile=tile, interpret=default_interpret(),
    )
    n = v.size
    unflat = lambda x: x[:n].reshape(shape)
    return unflat(v_o), unflat(i_o), unflat(r_o), unflat(s_o) != 0


@functools.partial(jax.jit, static_argnames=("steps_lo", "r_span", "tile_n"))
def spike_deliver(
    spikes, src, w, delay, *, steps_lo: int, r_span: int, tile_n: int | None = None
):
    """Delay-resolved contributions [N, r_span] for arbitrary N (pads rows)."""
    n = src.shape[0]
    tile_n = tile_n or min(_dlv.TILE_N, n)
    src_p = _pad_to(src, tile_n)
    w_p = _pad_to(w, tile_n)
    d_p = _pad_to(delay, tile_n, value=steps_lo)  # pad rows contribute w=0
    out = _dlv.spike_deliver_pallas(
        spikes, src_p, w_p, d_p,
        steps_lo=steps_lo, r_span=r_span, tile_n=tile_n,
        interpret=default_interpret(),
    )
    return out[:n]


def apply_contrib(
    ring: jax.Array,     # [N, R]
    contrib: jax.Array,  # [N, r_span]
    t: jax.Array,
    steps_lo: int,
) -> jax.Array:
    """Roll delay-resolved contributions into ring slots (t+steps_lo+j) % R."""
    r = ring.shape[-1]
    r_span = contrib.shape[-1]
    slots = jnp.mod(t + steps_lo + jnp.arange(r_span), r)  # [r_span]
    return ring.at[:, slots].add(contrib)


@functools.partial(jax.jit, static_argnames=("s_max",))
def event_deliver(
    ring: jax.Array,      # [N_tgt, R]
    spikes: jax.Array,    # [N_src] bool
    tgt_out: jax.Array,   # [N_src, K_out] int32 target ids (N_tgt = no target)
    w_out: jax.Array,     # [N_src, K_out] f32
    d_out: jax.Array,     # [N_src, K_out] int32 delays (steps)
    t: jax.Array,
    *,
    s_max: int,
) -> jax.Array:
    """Event-driven delivery: compact fired sources, scatter their targets.

    Work is O(s_max * K_out) instead of O(N * K); with brain-scale rates this
    is a >1000x multiply-reduction. ``s_max`` is the static event-buffer bound
    (cf. NEST's spike-register resizing -- here sizing is static; the engine
    asserts the spike count stays below the bound).

    Exactness: weights live on the 1/256 grid, so scatter order is irrelevant.
    """
    n_tgt, r = ring.shape
    n_src, k_out = tgt_out.shape
    fired = jnp.nonzero(spikes.reshape(-1), size=s_max, fill_value=n_src)[0]
    # Pad row: index n_src into tgt/w/d -> use guarded gather with mask.
    valid = fired < n_src
    safe = jnp.where(valid, fired, 0)
    tgts = jnp.where(valid[:, None], tgt_out[safe], n_tgt)    # [s_max, K_out]
    vals = jnp.where(valid[:, None], w_out[safe], 0.0)
    slots = jnp.mod(t + d_out[safe], r)
    # Scatter-add into an [N_tgt + 1, R] buffer; last row absorbs padding.
    buf = jnp.zeros((n_tgt + 1, r), ring.dtype)
    buf = buf.at[tgts.reshape(-1), slots.reshape(-1)].add(vals.reshape(-1))
    return ring + buf[:n_tgt]
