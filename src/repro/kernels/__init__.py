"""Pallas TPU kernels.

Paper hot spots (the phases NEST optimizes):
* ``lif_update``       -- fused neuron state update (the *update* phase)
* ``spike_deliver``    -- tiled gather-matvec delivery (the *deliver* phase)

Beyond-paper (the LM stack's dominant memory term, see EXPERIMENTS §Perf):
* ``flash_attention``  -- fused GQA flash attention (VMEM-resident tiles)

``ops`` holds the jit'd public wrappers (+ the event-driven delivery path);
``ref`` holds the pure-jnp oracles used by the kernel test sweeps.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
