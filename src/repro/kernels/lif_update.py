"""Pallas TPU kernel: fused LIF (iaf_psc_exp) state update.

The paper's *update* phase is one of the three per-cycle compute phases
(Fig. 3). A naive jnp chain (decay -> integrate -> threshold -> reset ->
refractory bookkeeping) makes ~6 HBM round trips over the state arrays; this
kernel fuses them into one pass: each [TILE] block of neuron state is loaded
into VMEM once, updated, and written once. The state layout is a flat [N]
vector (the engines flatten [A, n_pad]), padded to the tile size.

VPU-bound, so the tile is sized in (8 x 128) register-file multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lif_update_pallas", "lif_step_math", "TILE"]

# 8 sublanes x 128 lanes x 8 = one comfortably VMEM-resident f32 block per
# state array (6 arrays live at once: v, i_syn, refrac, i_in, alive + outs).
TILE = 8 * 128 * 8


def lif_step_math(
    v, i_syn, refrac, i_in, alive,
    *, p11: float, p21: float, p22: float,
    v_th: float, v_reset: float, t_ref_steps: int,
):
    """One exact-propagator LIF step on in-register values.

    The shared cycle body of this kernel and the fused superstep kernel
    (:mod:`repro.kernels.cycle`); bit-identical to the jnp chain in
    ``repro.core.neuron.lif_update``. ``alive`` is bool; returns
    ``(v', i_syn', refrac', spikes bool)``.
    """
    refractory = refrac > 0
    i_new = i_syn * p11 + i_in
    v_prop = v * p22 + i_syn * p21
    v_new = jnp.where(refractory, v_reset, v_prop)
    spikes = (v_new >= v_th) & alive & ~refractory
    v_out = jnp.where(spikes, v_reset, v_new)
    refrac_out = jnp.where(
        spikes, jnp.int32(t_ref_steps), jnp.maximum(refrac - 1, 0)
    )
    return v_out, i_new, refrac_out, spikes


def _kernel(
    v_ref, i_syn_ref, refrac_ref, i_in_ref, alive_ref,
    v_out_ref, i_out_ref, refrac_out_ref, spike_out_ref,
    *, p11: float, p21: float, p22: float,
    v_th: float, v_reset: float, t_ref_steps: int,
):
    v_out, i_out, refrac_out, spikes = lif_step_math(
        v_ref[...], i_syn_ref[...], refrac_ref[...], i_in_ref[...],
        alive_ref[...] != 0,
        p11=p11, p21=p21, p22=p22, v_th=v_th, v_reset=v_reset,
        t_ref_steps=t_ref_steps,
    )
    v_out_ref[...] = v_out
    i_out_ref[...] = i_out
    refrac_out_ref[...] = refrac_out
    spike_out_ref[...] = spikes.astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=(
        "p11", "p21", "p22", "v_th", "v_reset", "t_ref_steps",
        "tile", "interpret",
    ),
)
def lif_update_pallas(
    v: jax.Array,
    i_syn: jax.Array,
    refrac: jax.Array,
    i_in: jax.Array,
    alive: jax.Array,  # int8 (0/1)
    *,
    p11: float,
    p21: float,
    p22: float,
    v_th: float,
    v_reset: float,
    t_ref_steps: int,
    tile: int = TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused LIF step over flat [N] state. N must be a multiple of ``tile``
    (use :func:`repro.kernels.ops.lif_update` for automatic padding)."""
    n = v.shape[0]
    if n % tile != 0:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    grid = (n // tile,)
    bs = pl.BlockSpec((tile,), lambda i: (i,))
    kernel = functools.partial(
        _kernel, p11=p11, p21=p21, p22=p22,
        v_th=v_th, v_reset=v_reset, t_ref_steps=t_ref_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[bs] * 5,
        out_specs=(bs, bs, bs, bs),
        out_shape=(
            jax.ShapeDtypeStruct((n,), v.dtype),
            jax.ShapeDtypeStruct((n,), i_syn.dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int8),
        ),
        interpret=interpret,
    )(v, i_syn, refrac, i_in, alive)
