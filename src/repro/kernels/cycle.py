"""Pallas TPU kernel: the fused D-cycle superstep (update + intra delivery).

The engines' structure-aware window runs D local cycles between two global
exchanges. The split kernels (``lif_update``, ``spike_deliver``) still pay one
HBM round trip over the state and the live ring slots *per cycle*; this kernel
fuses the whole window: each grid step loads one area's membrane state and its
live window buffer ``fut [n, W]`` into VMEM ONCE and keeps them there across
the D unrolled cycles -- per-window instead of per-cycle traffic, the
von-Neumann-bottleneck refactoring of Pronold et al. (arXiv:2109.11358)
applied to the innermost loop.

The cycle body reuses the exact math of the split kernels:
:func:`repro.kernels.lif_update.lif_step_math` for the update and
:func:`repro.kernels.spike_deliver.delay_resolved_contrib` for the
delay-resolved intra deposit, plus the counter-based Poisson drive
(:func:`repro.core.neuron.counter_uniform`) recomputed in-kernel -- so
trajectories are bit-identical to the unfused engines (weights on the 1/256
grid; same FMA contraction under jit).

Window-static slot indexing: the live buffer covers relative slots
``[0, W)`` with ``W = D + max_intra_delay``; cycle ``s`` consumes column
``s`` and deposits at columns ``s + delay < W`` -- every index is a static
offset, no ring phase arithmetic in the hot loop. The engine supplies
``fut`` from the blocked ring read and merges columns ``[D, W)`` back
afterwards; the lumped inter exchange stays outside the kernel (it is the
communication step the paper's schedule isolates).

Grid: one program per area -- intra connectivity is area-local, so each
program is self-contained. Sized for areas whose state + tables fit VMEM
(the reference/benchmark scales); production-size areas would add an inner
neuron tiling with a cross-tile spike exchange per cycle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.neuron import counter_uniform
from repro.kernels.lif_update import lif_step_math
from repro.kernels.spike_deliver import delay_resolved_contrib

__all__ = ["superstep_lif_pallas", "superstep_iaf_pallas"]


def _deposit_window(fut, spk, src, w, j, s: int, steps_lo: int, r_span: int):
    """Intra deposit of cycle ``s``'s spikes at static window offsets."""
    if r_span == 0 or src.shape[-1] == 0:
        return fut
    vals = w * spk.astype(jnp.float32)[src]          # [n, K] gather + scale
    contrib = delay_resolved_contrib(vals, j, r_span)
    return fut.at[:, s + steps_lo: s + steps_lo + r_span].add(contrib)


def _lif_kernel(
    t0_ref, v_ref, i_ref, refrac_ref, fut_ref, p_ref, gid_ref, alive_ref,
    src_ref, w_ref, d_ref,
    v_out, i_out, refrac_out, fut_out, spk_out,
    *, d_win: int, steps_lo: int, r_span: int,
    p11: float, p21: float, p22: float,
    v_th: float, v_reset: float, t_ref_steps: int,
    seed: int, w_ext: float,
):
    t0 = t0_ref[0]
    v = v_ref[0]
    i_syn = i_ref[0]
    refrac = refrac_ref[0]
    fut = fut_ref[0]                     # [n, W] live window slots, VMEM
    p = p_ref[0]                         # per-cycle drive probability
    gids = gid_ref[0]
    alive = alive_ref[0] != 0
    src = src_ref[0]
    w = w_ref[0]
    j = d_ref[0] - steps_lo
    for s in range(d_win):               # unrolled; every slot index static
        u = counter_uniform(seed, t0 + s, gids)
        drive = (u < p).astype(jnp.float32) * w_ext
        v, i_syn, refrac, spk = lif_step_math(
            v, i_syn, refrac, fut[:, s] + drive, alive,
            p11=p11, p21=p21, p22=p22, v_th=v_th, v_reset=v_reset,
            t_ref_steps=t_ref_steps,
        )
        spk_out[0, s] = spk.astype(jnp.int8)
        fut = _deposit_window(fut, spk, src, w, j, s, steps_lo, r_span)
    v_out[0] = v
    i_out[0] = i_syn
    refrac_out[0] = refrac
    fut_out[0] = fut


def _iaf_kernel(
    cd_ref, fut_ref, interval_ref, alive_ref, src_ref, w_ref, d_ref,
    cd_out, fut_out, spk_out,
    *, d_win: int, steps_lo: int, r_span: int,
):
    cd = cd_ref[0]
    fut = fut_ref[0]
    interval = interval_ref[0]
    alive = alive_ref[0] != 0
    src = src_ref[0]
    w = w_ref[0]
    j = d_ref[0] - steps_lo
    for s in range(d_win):
        spk = (cd == 0) & alive
        cd = jnp.where(spk, interval - 1, cd - 1)
        spk_out[0, s] = spk.astype(jnp.int8)
        fut = _deposit_window(fut, spk, src, w, j, s, steps_lo, r_span)
    cd_out[0] = cd
    fut_out[0] = fut


def _specs(a: int, n: int, k: int, w_width: int, d_win: int):
    """BlockSpecs shared by both variants: one area per grid step."""
    row = pl.BlockSpec((1, n), lambda i: (i, 0))
    fut = pl.BlockSpec((1, n, w_width), lambda i: (i, 0, 0))
    syn = pl.BlockSpec((1, n, k), lambda i: (i, 0, 0))
    spk = pl.BlockSpec((1, d_win, n), lambda i: (i, 0, 0))
    return row, fut, syn, spk


@functools.partial(
    jax.jit,
    static_argnames=(
        "d_win", "steps_lo", "r_span", "p11", "p21", "p22", "v_th",
        "v_reset", "t_ref_steps", "seed", "w_ext", "interpret",
    ),
)
def superstep_lif_pallas(
    v: jax.Array,        # [A, n] f32
    i_syn: jax.Array,    # [A, n] f32
    refrac: jax.Array,   # [A, n] int32
    fut: jax.Array,      # [A, n, W] f32 live window slots (rel [0, W))
    drive_p: jax.Array,  # [A, n] f32 per-cycle Bernoulli drive probability
    gids: jax.Array,     # [A, n] int32 global neuron ids (drive counter)
    alive: jax.Array,    # [A, n] int8
    src: jax.Array,      # [A, n, K] int32 intra sources (within-area index)
    w: jax.Array,        # [A, n, K] f32
    delay: jax.Array,    # [A, n, K] int32
    t0: jax.Array,       # [1] int32 window-start cycle
    *,
    d_win: int,
    steps_lo: int,
    r_span: int,
    p11: float, p21: float, p22: float,
    v_th: float, v_reset: float, t_ref_steps: int,
    seed: int, w_ext: float,
    interpret: bool = True,
):
    """Fused LIF window: returns ``(v, i_syn, refrac, fut, spikes[A, D, n])``."""
    a, n = v.shape
    w_width = fut.shape[-1]
    k = src.shape[-1]
    row, futs, syn, spks = _specs(a, n, k, w_width, d_win)
    t0s = pl.BlockSpec((1,), lambda i: (0,))
    kernel = functools.partial(
        _lif_kernel, d_win=d_win, steps_lo=steps_lo, r_span=r_span,
        p11=p11, p21=p21, p22=p22, v_th=v_th, v_reset=v_reset,
        t_ref_steps=t_ref_steps, seed=seed, w_ext=w_ext,
    )
    return pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[t0s, row, row, row, futs, row, row, row, syn, syn, syn],
        out_specs=(row, row, row, futs, spks),
        out_shape=(
            jax.ShapeDtypeStruct((a, n), v.dtype),
            jax.ShapeDtypeStruct((a, n), i_syn.dtype),
            jax.ShapeDtypeStruct((a, n), jnp.int32),
            jax.ShapeDtypeStruct((a, n, w_width), fut.dtype),
            jax.ShapeDtypeStruct((a, d_win, n), jnp.int8),
        ),
        interpret=interpret,
    )(t0, v, i_syn, refrac, fut, drive_p, gids, alive, src, w, delay)


@functools.partial(
    jax.jit,
    static_argnames=("d_win", "steps_lo", "r_span", "interpret"),
)
def superstep_iaf_pallas(
    countdown: jax.Array,  # [A, n] int32
    fut: jax.Array,        # [A, n, W] f32
    interval: jax.Array,   # [A, n] int32 firing interval (steps)
    alive: jax.Array,      # [A, n] int8
    src: jax.Array,        # [A, n, K] int32
    w: jax.Array,          # [A, n, K] f32
    delay: jax.Array,      # [A, n, K] int32
    *,
    d_win: int,
    steps_lo: int,
    r_span: int,
    interpret: bool = True,
):
    """Fused ignore-and-fire window: ``(countdown, fut, spikes[A, D, n])``."""
    a, n = countdown.shape
    w_width = fut.shape[-1]
    k = src.shape[-1]
    row, futs, syn, spks = _specs(a, n, k, w_width, d_win)
    kernel = functools.partial(
        _iaf_kernel, d_win=d_win, steps_lo=steps_lo, r_span=r_span)
    return pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[row, futs, row, row, syn, syn, syn],
        out_specs=(row, futs, spks),
        out_shape=(
            jax.ShapeDtypeStruct((a, n), jnp.int32),
            jax.ShapeDtypeStruct((a, n, w_width), fut.dtype),
            jax.ShapeDtypeStruct((a, d_win, n), jnp.int8),
        ),
        interpret=interpret,
    )(countdown, fut, interval, alive, src, w, delay)
