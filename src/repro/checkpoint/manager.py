"""Checkpointing: atomic, async-capable, elastic-restore.

Format: one ``step_<N>/`` directory holding ``arrays.npz`` (leaves keyed by
flattened tree paths) + ``manifest.json`` (tree structure, shapes, dtypes,
mesh metadata). Writes go to ``<dir>.tmp`` and are renamed atomically -- a
crash mid-write never corrupts the latest checkpoint. ``AsyncWriter`` moves
serialisation off the training thread (device -> host copy happens
synchronously, which is the required consistency point anyway).

Elastic restore: the hierarchical trainer's state has a leading [n_pods]
axis; ``elastic_pod_resize`` re-targets a checkpoint to a different pod count
(mean-then-broadcast), so recovery from a lost pod or a scale-up needs no
retraining. The SNN engine's per-area state re-partitions the same way via
``core.partition.elastic_reshard_plan``.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save",
    "restore",
    "read_manifest",
    "latest_step",
    "AsyncWriter",
    "elastic_pod_resize",
]


# numpy's savez cannot serialise ml_dtypes types (bf16, fp8); store them as
# same-width unsigned views and record the true dtype in the manifest.
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    dtypes = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[str(arr.dtype)])
        out[key] = arr
    return out, dtypes


def _unview(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _VIEW_DTYPES:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def save(directory: str, step: int, tree: Any, *, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    # A leftover .tmp from a crashed writer must not leak stale files into
    # this write: the atomic rename would promote whatever the dead writer
    # left behind alongside the fresh arrays.
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": list(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def read_manifest(directory: str, step: int | None = None) -> tuple[dict, int]:
    """Read a checkpoint's manifest without loading its arrays.

    The cheap pre-flight for resume paths: config hashes, mesh metadata and
    window-phase records live in ``manifest['extra']``, so compatibility can
    be checked (and a clear error raised) before any state is materialised.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f), step


def restore(directory: str, like: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kpath, leaf in flat:
        key = "/".join(str(p) for p in kpath)
        arr = _unview(data[key], manifest["dtypes"].get(key, ""))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != expected "
                f"{leaf.shape} (use elastic_pod_resize for pod-count changes)"
            )
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    ), step


class AsyncWriter:
    """Background checkpoint writer with a bounded queue (backpressure).

    Transient I/O failures (``OSError``: full disks, flaky network mounts,
    preempted blob stores) are retried up to ``retries`` times with
    exponential backoff before the error is surfaced on the next
    ``submit``/``close`` -- a long run should degrade through a hiccup, not
    die on it. ``save_fn`` injects the underlying writer (the fault-injection
    harness in :mod:`repro.core.faults` uses it to exercise the retry path
    deterministically).
    """

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        *,
        retries: int = 3,
        backoff_s: float = 0.05,
        save_fn: Callable[..., str] | None = None,
    ):
        self.directory = directory
        self.keep = keep
        self.retries = retries
        self.backoff_s = backoff_s
        self.retry_count = 0  # total transient failures retried (observability)
        self._save = save_fn or save
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list[Exception] = []

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                self._save_with_retry(step, host_tree, extra)
                self._gc()
            except Exception as e:  # surfaced on next submit/close
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _save_with_retry(self, step: int, host_tree: Any, extra) -> None:
        for attempt in range(self.retries + 1):
            try:
                self._save(self.directory, step, host_tree, extra=extra)
                return
            except OSError:
                if attempt == self.retries:
                    raise  # retries exhausted: surface on next submit/close
                self.retry_count += 1
                time.sleep(self.backoff_s * (2 ** attempt))

    def _gc(self) -> None:
        entries = os.listdir(self.directory)
        # Sweep orphaned .tmp dirs (a crashed writer's partial output) so a
        # resumed run's directory converges back to `keep` clean checkpoints.
        # Anything .tmp here is dead: this worker writes serially, so no
        # in-flight write of our own can be visible during _gc.
        for d in entries:
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)
        steps = sorted(
            int(d.split("_")[1])
            for d in entries
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    def submit(self, step: int, tree: Any, extra: dict | None = None) -> None:
        if self._errors:
            raise self._errors.pop(0)
        # Device -> host copy is the consistency point; do it now, serialise
        # in the background.
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree, extra))

    def close(self) -> None:
        self._q.put(None)
        self._worker.join()
        if self._errors:
            raise self._errors.pop(0)


def elastic_pod_resize(tree_pods: Any, new_n_pods: int) -> Any:
    """Re-target per-pod replicated state to a different pod count.

    Leaves carry a leading [n_pods] axis; resizing averages the replicas
    (the slow-tier sync point) and re-broadcasts -- the same operation the
    D-step sync performs, so resuming after a pod loss is semantically one
    early sync.
    """
    def resize(x):
        mean = np.asarray(x, dtype=np.float32).mean(axis=0)
        out = np.broadcast_to(mean[None], (new_n_pods,) + mean.shape)
        return jnp.asarray(out, dtype=x.dtype)

    return jax.tree.map(resize, tree_pods)
