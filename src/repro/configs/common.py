"""Shared plumbing for architecture configs: shapes, bundles, input specs."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["ShapeSpec", "SHAPES", "Bundle", "lm_input_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int

    def reduced(self) -> "ShapeSpec":
        """Smoke-test scale: same kind, tiny extent."""
        return ShapeSpec(self.name, self.kind,
                         seq_len=min(self.seq_len, 32),
                         global_batch=min(self.global_batch, 2))


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class Bundle:
    """Uniform wrapper every architecture exposes to the launcher/dry-run.

    ``model`` provides init_params/forward/param_pspecs (+ cache methods);
    ``extra_inputs`` maps additional forward kwargs (stub frontends) to
    shape-builders ``(batch, seq) -> ShapeDtypeStruct``.
    """

    arch_id: str
    family: str
    model: Any
    cfg: Any
    extra_inputs: dict[str, Callable[[int, int], jax.ShapeDtypeStruct]] = \
        dataclasses.field(default_factory=dict)
    # Optimizer-moment dtype hint: bf16 for the giants so optimizer state fits
    # the per-chip HBM budget (see EXPERIMENTS.md §Dry-run memory table).
    moment_dtype: str = "float32"

    def loss(self, params, batch) -> jax.Array:
        """Mean next-token CE (+ MoE aux) on a {'tokens','labels',...} batch.

        Uses the hidden-state API + chunked CE so the full [B, S, V] logits
        are never materialised (see layers.chunked_cross_entropy)."""
        from repro.models import layers

        extras = {k: batch[k] for k in self.extra_inputs}
        h, aux = self.model.hidden(params, batch["tokens"], **extras)
        ce = layers.chunked_cross_entropy(
            lambda hc: self.model.unembed(params, hc), h, batch["labels"]
        )
        return ce + 0.01 * aux

    def input_specs(self, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for a *training* batch of this shape."""
        b, s = shape.global_batch, shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        for name, make in self.extra_inputs.items():
            specs[name] = make(b, s)
        return specs

    def decode_input_specs(self, shape: ShapeSpec) -> dict[str, Any]:
        """Specs for one decode step: a single new token + the filled cache."""
        b = shape.global_batch
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def lm_input_specs() -> dict[str, Callable[[int, int], jax.ShapeDtypeStruct]]:
    return {}
