"""Architecture registry: ``--arch <id>`` resolution for the launcher.

Ten assigned architectures + the paper's own models (MAM / MAM-benchmark,
which live in repro.core and are registered here for the dry-run runner).
"""

from __future__ import annotations

import importlib
from typing import Any

from repro.configs.common import SHAPES, Bundle, ShapeSpec

__all__ = ["ARCH_MODULES", "list_archs", "get_arch", "arch_cells", "SHAPES"]

# arch id -> module name under repro.configs
ARCH_MODULES: dict[str, str] = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "gemma3-27b": "gemma3_27b",
    "olmo-1b": "olmo_1b",
    "qwen2-0.5b": "qwen2_0_5b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "grok-1-314b": "grok_1_314b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "internvl2-76b": "internvl2_76b",
}


def list_archs() -> list[str]:
    return list(ARCH_MODULES)


def _module(arch_id: str):
    if arch_id not in ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")


def get_arch(arch_id: str, reduced: bool = False, **overrides) -> Bundle:
    return _module(arch_id).make_bundle(reduced=reduced, **overrides)


def arch_skips(arch_id: str) -> dict[str, str]:
    return dict(_module(arch_id).SKIPS)


def arch_cells(arch_id: str) -> list[tuple[ShapeSpec, str | None]]:
    """All four shapes with skip reasons (None = runnable)."""
    skips = arch_skips(arch_id)
    return [(shape, skips.get(name)) for name, shape in SHAPES.items()]
