"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 -- Mamba2 backbone + shared attention block.
[arXiv:2411.15242; verified tier: hf]

38 = 6 applications x 6-layer period + 2 trailing mamba layers. The shared
block's KV cache is small (one block, 6 application points), so ``long_500k``
runs (sequence axis of the shared-block cache shards over the model axis).
"""

from __future__ import annotations

from repro.configs.common import Bundle
from repro.models.zamba2 import Zamba2, Zamba2Config

ARCH_ID = "zamba2-1.2b"
FAMILY = "hybrid"
SKIPS: dict[str, str] = {}  # hybrid with O(1) mamba state: all shapes run


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = Zamba2Config(
            name=ARCH_ID + "-smoke", n_layers=8, d_model=64, vocab=512,
            n_heads=4, n_kv=4, d_head=16, d_ff=128, period=3,
            d_state=16, headdim=16, chunk=8, **overrides,
        )
    else:
        cfg = Zamba2Config(
            name=ARCH_ID, n_layers=38, d_model=2048, vocab=32000,
            n_heads=32, n_kv=32, d_head=64, d_ff=8192, period=6,
            d_state=64, headdim=64, chunk=256,
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
            **overrides,
        )
    return Bundle(arch_id=ARCH_ID, family=FAMILY, model=Zamba2(cfg), cfg=cfg)
