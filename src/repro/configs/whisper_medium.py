"""whisper-medium [audio]: 24L (enc) + 24L (dec) d_model=1024 16H d_ff=4096
vocab=51865 -- encoder-decoder, conv frontend STUBBED (input_specs supplies
precomputed frame embeddings). [arXiv:2212.04356; verified tier: unverified]

Vocab padded 51865 -> 51872 for 16-way TP. The assigned decoder shapes
(4k/32k) exceed Whisper's physical 448-token decoder; they exercise the
backbone as assigned -- see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import Bundle
from repro.models.whisper import Whisper, WhisperConfig

ARCH_ID = "whisper-medium"
FAMILY = "audio"
SKIPS = {
    "long_500k": "enc-dec audio model; 500k-token decode not defined for the "
    "family (30 s inputs, 448-token transcripts)",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = WhisperConfig(
            name=ARCH_ID + "-smoke", n_enc_layers=2, n_dec_layers=2,
            d_model=64, n_heads=4, d_ff=128, vocab=512, n_frames=16,
            **overrides,
        )
    else:
        cfg = WhisperConfig(
            name=ARCH_ID, n_enc_layers=24, n_dec_layers=24, d_model=1024,
            n_heads=16, d_ff=4096, vocab=51872, n_frames=1500,
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="dots",
            **overrides,
        )

    def frames_spec(batch: int, seq: int) -> jax.ShapeDtypeStruct:
        del seq  # encoder length is fixed by the 30 s audio window
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )

    return Bundle(
        arch_id=ARCH_ID, family=FAMILY, model=Whisper(cfg), cfg=cfg,
        extra_inputs={"frames": frames_spec},
    )
