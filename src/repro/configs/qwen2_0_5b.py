"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936
-- GQA with QKV bias, tied embeddings. [arXiv:2407.10671; verified tier: hf]
"""

from __future__ import annotations

from repro.configs.common import Bundle
from repro.models.transformer import Transformer, TransformerConfig

ARCH_ID = "qwen2-0.5b"
FAMILY = "dense"
SKIPS = {
    "long_500k": "full attention; 500k dense-KV decode out of scope",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv=2, d_head=16, d_ff=128, vocab=512, qkv_bias=True,
            tie_embeddings=True, **overrides,
        )
    else:
        cfg = TransformerConfig(
            name=ARCH_ID, n_layers=24, d_model=896, n_heads=14, n_kv=2,
            d_head=64, d_ff=4864, vocab=151936, qkv_bias=True,
            tie_embeddings=True,
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="dots",
            **overrides,
        )
    return Bundle(arch_id=ARCH_ID, family=FAMILY, model=Transformer(cfg), cfg=cfg)
