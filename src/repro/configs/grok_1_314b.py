"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8e top-2 on every layer. [hf:xai-org/grok-1; verified tier: unverified]

8 experts < the 16-way model axis, so experts use TP sharding (d_ff sharded
inside every expert) rather than EP -- see models/moe.py.
"""

from __future__ import annotations

from repro.configs.common import Bundle
from repro.models.moe import MoEConfig
from repro.models.transformer import Transformer, TransformerConfig

ARCH_ID = "grok-1-314b"
FAMILY = "moe"
SKIPS = {
    "long_500k": "full attention; 500k dense-KV decode out of scope",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv=2, d_head=16, d_ff=256, vocab=512,
            moe=MoEConfig(n_experts=4, top_k=2, d_ff=128,
                          expert_sharding="tp"),
            **overrides,
        )
    else:
        cfg = TransformerConfig(
            name=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv=8,
            d_head=128, d_ff=32768, vocab=131072,
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768,
                          expert_sharding="tp"),
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
            **overrides,
        )
    return Bundle(
        arch_id=ARCH_ID, family=FAMILY, model=Transformer(cfg), cfg=cfg,
        moment_dtype="bfloat16",
    )
