"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 -- llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf -- verified tier: hf]
"""

from __future__ import annotations

from repro.configs.common import Bundle
from repro.models.transformer import Transformer, TransformerConfig

ARCH_ID = "h2o-danube-1.8b"
FAMILY = "dense"
SKIPS = {
    "long_500k": "SWA-trained dense transformer treated as full-attention "
    "family per assignment; 500k dense-KV decode out of scope",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv=2, d_head=8, d_ff=128, vocab=512,
            window_pattern=(16,),  # keep the SWA code path exercised
            **overrides,
        )
    else:
        cfg = TransformerConfig(
            name=ARCH_ID, n_layers=24, d_model=2560, n_heads=32, n_kv=8,
            d_head=80, d_ff=6912, vocab=32000,
            window_pattern=(4096,),  # mistral-style sliding window
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="dots",
            **overrides,
        )
    return Bundle(arch_id=ARCH_ID, family=FAMILY, model=Transformer(cfg), cfg=cfg)
