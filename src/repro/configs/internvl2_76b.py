"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 -- InternViT frontend STUBBED (input_specs supplies precomputed
patch embeddings), InternLM2/llama-3-70B-class language backbone.
[arXiv:2404.16821; verified tier: unverified]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.common import Bundle
from repro.models.internvl import InternVL, InternVLConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "internvl2-76b"
FAMILY = "vlm"
SKIPS = {
    "long_500k": "full attention backbone; 500k dense-KV decode out of scope",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        bb = TransformerConfig(
            name=ARCH_ID + "-smoke-bb", n_layers=2, d_model=64, n_heads=4,
            n_kv=2, d_head=16, d_ff=128, vocab=512, **overrides,
        )
        cfg = InternVLConfig(name=ARCH_ID + "-smoke", backbone=bb,
                             d_vit=32, n_patches=4)
    else:
        bb = TransformerConfig(
            name=ARCH_ID + "-bb", n_layers=80, d_model=8192, n_heads=64,
            n_kv=8, d_head=128, d_ff=28672, vocab=128256,
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
            **overrides,
        )
        cfg = InternVLConfig(name=ARCH_ID, backbone=bb,
                             d_vit=1024, n_patches=256)

    def patches_spec(batch: int, seq: int) -> jax.ShapeDtypeStruct:
        del seq
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_vit), jnp.dtype(cfg.cdtype)
        )

    return Bundle(
        arch_id=ARCH_ID, family=FAMILY, model=InternVL(cfg), cfg=cfg,
        extra_inputs={"patch_embeds": patches_spec},
        moment_dtype="bfloat16",
    )
