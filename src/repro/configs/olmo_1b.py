"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304
-- non-parametric LayerNorm. [arXiv:2402.00838; verified tier: hf]
"""

from __future__ import annotations

from repro.configs.common import Bundle
from repro.models.transformer import Transformer, TransformerConfig

ARCH_ID = "olmo-1b"
FAMILY = "dense"
SKIPS = {
    "long_500k": "full attention; 500k dense-KV decode out of scope",
}


def make_bundle(reduced: bool = False, **overrides) -> Bundle:
    if reduced:
        cfg = TransformerConfig(
            name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv=4, d_head=16, d_ff=128, vocab=512, norm="nonparam",
            tie_embeddings=True, **overrides,
        )
    else:
        cfg = TransformerConfig(
            name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv=16,
            d_head=128, d_ff=8192, vocab=50304, norm="nonparam",
            tie_embeddings=True,
            param_dtype="bfloat16", compute_dtype="bfloat16", remat="dots",
            **overrides,
        )
    return Bundle(arch_id=ARCH_ID, family=FAMILY, model=Transformer(cfg), cfg=cfg)
